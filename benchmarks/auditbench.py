"""Audit ledger cost on the hot serve path (DESIGN.md §14).

Tamper-evident accounting must be effectively free where the paper's
steady-state workload lives: the acceptance bar is <5% attributable
wall-clock overhead with a live :class:`AuditLedger` versus
:data:`NULL_LEDGER` on the 90%-warm cohort path — the worst case for the
ledger, since warm hits do near-zero compute but still emit the durable
delivery + provenance pair.

Methodology mirrors ``obsbench.py``: both modes run the same pre-warmed
cohort through a fresh broker+journal deployment, interleaved over several
repetitions so CPU drift hits both alike; the asserted number is the
*attributable* overhead — records-per-run × microbenchmarked per-append
cost (durable appends priced separately, they fsync) ÷ serve wall — with
the raw end-to-end walls reported alongside as evidence. Also reports raw
append and verify throughput (records/s) for the chain mechanics
themselves. Writes ``BENCH_audit.json``.
"""
from __future__ import annotations

import copy
import json
import tempfile
import time
from pathlib import Path

from repro.audit import AuditLedger
from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.lake import ResultLake
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock

N_STUDIES = 10
N_IMAGES = 6
WARM_RATE = 0.9
REPS = 5  # interleaved repetitions; min wall per mode is reported
MAX_OVERHEAD = 0.05
STUDY_ID = "IRB-AUD"
N_MICRO = 20_000


def _append_costs_us(td: Path) -> tuple[float, float, float]:
    """Microbenchmark one chained append: buffered (lake_hit-class) and
    durable (delivery-class, pays the fsync), plus verify throughput over
    the resulting chain. Returns (buffered_us, durable_us, verify_per_s)."""
    led = AuditLedger(td / "micro.audit")
    t0 = time.perf_counter()
    for i in range(N_MICRO):
        led.append("lake_hit", lake_key="k" * 32, nbytes=i)
    buffered = (time.perf_counter() - t0) / N_MICRO
    led.flush()

    n_durable = 200  # fsyncs are slow; a small sample bounds them fine
    t0 = time.perf_counter()
    for i in range(n_durable):
        led.append("delivery", key=f"IRB-AUD/A{i:04d}", etag="e" * 16,
                   temp="warm", worker="bench")
    durable = (time.perf_counter() - t0) / n_durable

    t0 = time.perf_counter()
    problems = led.verify()
    verify_per_s = len(led) / (time.perf_counter() - t0)
    assert problems == [], problems
    led.close()
    return buffered * 1e6, durable * 1e6, verify_per_s


def _corpus():
    gen = StudyGenerator(78)
    source = StudyStore("lake")
    mrns = {}
    for i in range(N_STUDIES):
        acc = f"AU{i:03d}"
        s = gen.gen_study(acc, modality="CT", n_images=N_IMAGES)
        source.put_study(acc, s)
        mrns[acc] = s.mrn
    total_bytes = sum(source.get_study(a).nbytes() for a in mrns)
    return source, mrns, total_bytes


def _stack(source, result_lake, journal_path, ledger):
    """One deployment with the audit plane threaded end to end
    (ledger=None means every component falls back to NULL_LEDGER)."""
    clock = SimClock()
    broker = Broker(clock, visibility_timeout=300.0, ledger=ledger)
    journal = Journal(journal_path)
    result_lake.ledger = ledger if ledger is not None else result_lake.ledger
    pipeline = DeidPipeline(recompress=True, lake=result_lake, ledger=ledger)
    service = DeidService(
        broker, source, journal, result_lake=result_lake, pipeline=pipeline,
        ledger=ledger,
    )
    service.register_study(STUDY_ID, TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(
            wid, pipeline, source, dest, journal, ledger=ledger
        ),
    )
    return service, pool


def run() -> dict:
    source, mrns, total_bytes = _corpus()
    accs = list(mrns)
    n_warm = int(round(WARM_RATE * len(accs)))
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        buffered_us, durable_us, verify_per_s = _append_costs_us(td)

        # pre-warm the result lake to 90% (not timed, not audited)
        warm_lake = ResultLake(max_bytes=1 << 30)
        svc0, pool0 = _stack(source, warm_lake, td / "warm.jsonl", None)
        svc0.submit_cohort(STUDY_ID, accs[:n_warm], mrns)
        pool0.drain()
        svc0.planner.resolve()

        walls: dict[str, list[float]] = {"null": [], "audited": []}
        n_records = n_durable = 0
        run_i = 0
        for _rep in range(REPS):
            for mode in ("null", "audited"):
                run_i += 1
                ledger = (
                    AuditLedger(td / f"run{run_i}.audit")
                    if mode == "audited" else None
                )
                lake = copy.deepcopy(warm_lake)
                service, pool = _stack(
                    source, lake, td / f"run{run_i}.jsonl", ledger
                )
                t0 = time.perf_counter()
                ticket = service.submit_cohort(STUDY_ID, accs, mrns)
                pool.drain()
                service.planner.resolve()
                walls[mode].append(time.perf_counter() - t0)
                assert ticket.done()
                if ledger is not None:
                    assert ledger.verify() == []
                    n_records = len(ledger)
                    n_syncs = ledger.syncs
                    ledger.close()

    plain, audited = min(walls["null"]), min(walls["audited"])
    # attributable overhead: what the ledger itself costs on this path —
    # every record pays the buffered append, and each GROUP COMMIT (the
    # worker's delivery+provenance pair, a cohort admission's warm hits)
    # pays one fsync. The raw end-to-end delta rides along as evidence but
    # is scheduler-noise bound on shared CI cores.
    sync_us = max(durable_us - buffered_us, 0.0)
    attributable_s = (n_records * buffered_us + n_syncs * sync_us) * 1e-6
    overhead = attributable_s / plain
    return {
        "warm_rate": WARM_RATE,
        "wall_null_s": plain,
        "wall_audited_s": audited,
        "end_to_end_delta_pct": (audited - plain) / plain * 100.0,
        "append_cost_us": buffered_us,
        "durable_append_cost_us": durable_us,
        "append_per_s": 1e6 / buffered_us,
        "verify_per_s": verify_per_s,
        "overhead_pct": overhead * 100.0,
        "records_per_run": n_records,
        "syncs_per_run": n_syncs,
        "mb_s_audited": total_bytes / audited / 1e6,
    }


def main(json_path: str | None = "BENCH_audit.json") -> list[str]:
    r = run()
    assert r["overhead_pct"] < MAX_OVERHEAD * 100.0, (
        f"audit ledger overhead {r['overhead_pct']:.2f}% exceeds the "
        f"{MAX_OVERHEAD:.0%} budget on the {WARM_RATE:.0%}-warm cohort path"
    )
    lines = [
        f"audit_null,{r['wall_null_s']*1e6:.0f},warm={WARM_RATE}",
        f"audit_on,{r['wall_audited_s']*1e6:.0f},"
        f"records={r['records_per_run']};syncs={r['syncs_per_run']};"
        f"MBps={r['mb_s_audited']:.1f}",
        f"audit_append,{r['append_cost_us']:.2f},"
        f"per_s={r['append_per_s']:.0f};durable_us={r['durable_append_cost_us']:.1f}",
        f"audit_verify,{1e6/r['verify_per_s']:.2f},"
        f"per_s={r['verify_per_s']:.0f};"
        f"overhead_pct={r['overhead_pct']:.4f};"
        f"end_to_end_delta_pct={r['end_to_end_delta_pct']:.2f}",
    ]
    if json_path:
        payload = {
            "source": "benchmarks/auditbench.py",
            "n_studies": N_STUDIES,
            "n_images": N_IMAGES,
            "reps": REPS,
            "max_overhead_pct": MAX_OVERHEAD * 100.0,
            **r,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
