"""Autoscaling behaviour benchmark (paper §Method c-d): drain a Table-1-sized
request under the backlog/delivery-window policy; report instance trajectory,
makespan vs the SLA window, and modeled cost."""
from __future__ import annotations

import time

from repro.queueing import Autoscaler, AutoscalerConfig, Broker
from repro.utils.timing import SimClock


def run(total_bytes: float = 3e12, n_messages: int = 5000, window_s: float = 3600.0) -> dict:
    clock = SimClock()
    broker = Broker(clock, visibility_timeout=600)
    cfg = AutoscalerConfig(delivery_window=window_s, per_instance_throughput=160e6, max_instances=64)
    scaler = Autoscaler(broker, cfg, clock)
    per_msg = total_bytes / n_messages
    for i in range(n_messages):
        broker.publish(f"m{i}", {}, nbytes=int(per_msg))

    # event-driven drain: each tick, n instances each clear one message's bytes
    peak = 0
    while not broker.empty():
        n = scaler.tick()
        peak = max(peak, n)
        work = min(n, broker.stats().available)
        for _ in range(work):
            msg = broker.pull("sim")[0]
            broker.ack(msg.msg_id)
        clock.advance(per_msg / cfg.per_instance_throughput)
    scaler.tick()
    return {
        "makespan_s": clock.now(),
        "window_s": window_s,
        "met_sla": clock.now() <= window_s * 1.05,
        "peak_instances": peak,
        "scale_events": len(scaler.events),
        "cost_usd": scaler.cost_usd(),
        "instance_seconds": scaler.instance_seconds,
    }


def main() -> list[str]:
    t0 = time.perf_counter()
    r = run()
    us = (time.perf_counter() - t0) * 1e6
    return [
        f"autoscale_3TB,{us:.0f},makespan_min={r['makespan_s']/60:.1f};window_min={r['window_s']/60:.0f};"
        f"sla={'met' if r['met_sla'] else 'missed'};peak_instances={r['peak_instances']};"
        f"events={r['scale_events']};cost=${r['cost_usd']:.2f}"
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
