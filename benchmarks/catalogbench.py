"""Catalog scan throughput + pruning + end-to-end query-to-cold-bytes
(DESIGN.md §8).

Two sections, both written to ``BENCH_catalog.json`` (uploaded by CI next to
the other BENCH artifacts):

* **scan** — a synthetic metadata-only catalog (no pixels: rows are cheap,
  volume is the point) ingested in StudyDate order so sealed blocks carry
  tight zone maps. Three date-range queries at ~1% / ~10% / ~50% row
  selectivity are timed through the numpy oracle scan (no pruning — the
  baseline) and the production path (zone-map pruning + jnp/Pallas bitmap
  combine). Wall-clock is noisy on shared CPU, so each cell is the minimum
  of interleaved repetitions; the deterministic signals are the pruning
  ratio (blocks total / blocks scanned) and the matched-row counts, which
  are asserted equal across paths.
* **e2e** — the paper's actual workflow at small scale: a real corpus with
  pixels, ``DeidService.submit_query`` -> planner -> autoscaled pool, per
  selectivity tier. Reports matched instances, cold bytes published, and
  the query->drained wall time on a fresh deployment each.
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

SCAN_ACCESSIONS = 128
SCAN_ROWS_PER = 256          # 32k rows
SCAN_BLOCK_ROWS = 512
SELECTIVITIES = (0.01, 0.10, 0.50)
REPS = 3
E2E_STUDIES = 12
E2E_IMAGES = 2
STUDY_ID = "IRB-CATBENCH"

_MODALITIES = ["CT", "MR", "DX", "US", "CR", "PT"]
_MAKES = ["GE Medical", "Siemens", "Philips", "Canon"]
_MODELS = ["Optima CT660", "MAGNETOM Aera", "Epiq 7", "DRX-1"]
_PARTS = ["CHEST", "HEAD", "ABDOMEN", "KNEE"]


def _scan_catalog():
    from repro.catalog import StudyCatalog

    rng = np.random.default_rng(2718)
    n = SCAN_ACCESSIONS * SCAN_ROWS_PER
    dates = np.sort(
        20150000
        + rng.integers(1, 6, n) * 10000
        + rng.integers(1, 13, n) * 100
        + rng.integers(1, 29, n)
    )
    cat = StudyCatalog(block_rows=SCAN_BLOCK_ROWS)
    i = 0
    for a in range(SCAN_ACCESSIONS):
        rows = []
        for _ in range(SCAN_ROWS_PER):
            rows.append(
                {
                    "modality": _MODALITIES[int(rng.integers(len(_MODALITIES)))],
                    "body_part": _PARTS[int(rng.integers(len(_PARTS)))],
                    "manufacturer": _MAKES[int(rng.integers(len(_MAKES)))],
                    "model": _MODELS[int(rng.integers(len(_MODELS)))],
                    "study_date": int(dates[i]),
                    "bits_stored": int(rng.choice([8, 12, 16])),
                    "rows": 512,
                    "cols": 512,
                    "nbytes": int(rng.integers(10_000, 600_000)),
                    "burned_in": int(rng.random() < 0.1),
                    "burned_in_detected": int(rng.random() < 0.08),
                }
            )
            i += 1
        cat.ingest_rows(f"SC{a:04d}", rows, etag=str(a))
    return cat, dates


def run_scan() -> list[dict]:
    from repro.catalog import Range

    cat, dates = _scan_catalog()
    n = len(dates)
    queries = {
        f: Range("study_date", int(dates[0]), int(dates[max(int(f * n) - 1, 0)]))
        for f in SELECTIVITIES
    }
    walls: dict[float, dict[str, list[float]]] = {
        f: {"oracle": [], "vectorized": []} for f in SELECTIVITIES
    }
    facts: dict[float, dict] = {}
    for rep in range(REPS + 1):  # rep 0 warms jit caches, not timed
        for f, q in queries.items():
            t0 = time.perf_counter()
            full = cat.select(q, mode="oracle", prune=False)
            t1 = time.perf_counter()
            pruned = cat.select(q, mode="auto", prune=True)
            t2 = time.perf_counter()
            assert pruned.instance_counts == full.instance_counts
            if rep:
                walls[f]["oracle"].append(t1 - t0)
                walls[f]["vectorized"].append(t2 - t1)
            blocks_total = pruned.blocks_scanned + pruned.blocks_pruned
            facts[f] = {
                "matched_rows": pruned.total_instances,
                "achieved_selectivity": pruned.total_instances / n,
                "blocks_total": blocks_total,
                "blocks_scanned": pruned.blocks_scanned,
                "pruning_ratio": blocks_total / max(pruned.blocks_scanned, 1),
            }
    rows = []
    for f in SELECTIVITIES:
        wo = min(walls[f]["oracle"])
        wv = min(walls[f]["vectorized"])
        rows.append(
            {
                "selectivity": f,
                "n_rows": n,
                "oracle_wall_s": wo,
                "oracle_rows_per_s": n / wo,
                "vectorized_wall_s": wv,
                # pruning means the production path *scans* fewer rows; its
                # rows/s is still reported over the full catalog it answered for
                "vectorized_rows_per_s": n / wv,
                **facts[f],
            }
        )
    return rows


def run_e2e() -> list[dict]:
    from repro.catalog import Range, StudyCatalog
    from repro.core import DeidPipeline, TrustMode
    from repro.dicom.generator import StudyGenerator
    from repro.lake import ResultLake
    from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
    from repro.queueing.server import DeidService
    from repro.storage.object_store import StudyStore
    from repro.utils.timing import SimClock

    gen = StudyGenerator(31415)
    source = StudyStore("lake")
    catalog = StudyCatalog(block_rows=8)
    source.attach_catalog(catalog)
    mrns = {}
    for i in range(E2E_STUDIES):
        acc = f"EB{i:03d}"
        s = gen.gen_study(acc, n_images=E2E_IMAGES)
        source.put_study(acc, s)
        mrns[acc] = s.mrn

    dates = sorted(
        r["study_date"] for a in mrns for r in _study_rows(source, a)
    )
    n = len(dates)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        for i, f in enumerate(SELECTIVITIES):
            query = Range("study_date", dates[0], dates[max(int(f * n) - 1, 0)])
            clock = SimClock()
            broker = Broker(clock, visibility_timeout=300.0)
            journal = Journal(Path(td) / f"e2e{i}.jsonl")
            lake = ResultLake(max_bytes=1 << 30)
            pipeline = DeidPipeline(recompress=False, lake=lake)
            service = DeidService(
                broker, source, journal,
                result_lake=lake, pipeline=pipeline, catalog=catalog,
            )
            service.register_study(STUDY_ID, TrustMode.POST_IRB)
            dest = StudyStore("researcher")
            pool = WorkerPool(
                broker,
                Autoscaler(broker, AutoscalerConfig(), clock),
                lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
            )
            t0 = time.perf_counter()
            selection, ticket = service.submit_query(STUDY_ID, query, mrns)
            pool.drain()
            service.planner.resolve()
            wall = time.perf_counter() - t0
            assert ticket.done() and not ticket.failed
            cold_bytes = sum(source.study_nbytes(a) or 0 for a in ticket.cold)
            rows.append(
                {
                    "target_selectivity": f,
                    "matched_accessions": len(selection.accessions),
                    "matched_instances": selection.total_instances,
                    "achieved_selectivity": selection.total_instances / n,
                    "cold_published": len(ticket.cold),
                    "cold_bytes_published": cold_bytes,
                    "published_bytes_delivered": dest.store.bytes_written,
                    "wall_s": wall,
                    "selection_digest": selection.digest[:16],
                }
            )
    return rows


def _study_rows(source, accession):
    from repro.catalog import rows_from_study

    return rows_from_study(source.get_study(accession))


def main(json_path: str | None = "BENCH_catalog.json") -> list[str]:
    scan = run_scan()
    e2e = run_e2e()
    lines = []
    for r in scan:
        lines.append(
            f"catalog_scan_s{int(r['selectivity']*100):02d},"
            f"{r['vectorized_wall_s']*1e6:.0f},"
            f"oracle_rows_s={r['oracle_rows_per_s']:.0f};"
            f"vec_rows_s={r['vectorized_rows_per_s']:.0f};"
            f"pruning_ratio={r['pruning_ratio']:.2f};"
            f"matched={r['matched_rows']}"
        )
    for r in e2e:
        lines.append(
            f"catalog_e2e_s{int(r['target_selectivity']*100):02d},"
            f"{r['wall_s']*1e6:.0f},"
            f"matched={r['matched_instances']};cold={r['cold_published']};"
            f"cold_bytes={r['cold_bytes_published']}"
        )
    if json_path:
        payload = {
            "source": "benchmarks/catalogbench.py",
            "scan_rows": SCAN_ACCESSIONS * SCAN_ROWS_PER,
            "scan": scan,
            "e2e": e2e,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
