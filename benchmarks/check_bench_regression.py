"""CI gate: fail the build when a freshly measured observability benchmark
regresses against the committed baseline.

Compares headline numbers from fresh ``BENCH_obs.json`` / ``BENCH_slo.json``
/ ``BENCH_audit.json`` (written into a scratch dir by the CI job) against
the checked-in copies at the repo root. Each gated metric declares a direction: ``lower`` metrics
(costs) may not exceed baseline × (1 + tol); ``higher`` metrics
(throughputs) may not fall below baseline × (1 − tol). The default
tolerance is deliberately generous (50%) because shared CI runners swing
wall-clock numbers hard — the gate exists to catch order-of-magnitude
regressions (an accidentally quadratic fold, a span-cost blowup), not 5%
drift. Override with ``BENCH_REGRESSION_TOLERANCE=0.2`` etc.

Exit codes follow ``check_fused_gate.py``: 0 pass, 1 regression,
2 missing/malformed inputs.

    python benchmarks/check_bench_regression.py <fresh_dir> [file ...]

Extra arguments restrict the gate to those BENCH files (each CI job gates
only what it freshly measured); with none, every gated file must be present.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

# (file, metric, direction) — direction is what "good" looks like
GATED = (
    ("BENCH_obs.json", "overhead_pct", "lower"),
    ("BENCH_obs.json", "span_cost_us", "lower"),
    ("BENCH_slo.json", "us_per_observation", "lower"),
    ("BENCH_slo.json", "fold_spans_per_s", "higher"),
    ("BENCH_audit.json", "overhead_pct", "lower"),
    ("BENCH_audit.json", "append_per_s", "higher"),
    ("BENCH_audit.json", "verify_per_s", "higher"),
)


def _load(path: Path) -> dict | None:
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return None


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("bench-gate: usage: check_bench_regression.py <fresh_dir> [file ...]")
        return 2
    fresh_dir = Path(argv[0])
    only = set(argv[1:])
    unknown = only - {fname for fname, _, _ in GATED}
    if unknown:
        print(f"bench-gate: FAIL — no gated metrics for {sorted(unknown)}")
        return 2
    gated = [g for g in GATED if not only or g[0] in only]
    tol = float(os.environ.get("BENCH_REGRESSION_TOLERANCE", "0.5"))

    failures = 0
    for fname, metric, direction in gated:
        base_doc = _load(REPO_ROOT / fname)
        fresh_doc = _load(fresh_dir / fname)
        if base_doc is None or fresh_doc is None:
            missing = fname if base_doc is None else f"{fresh_dir / fname}"
            print(f"bench-gate: FAIL — cannot read {missing}")
            return 2
        if metric not in base_doc or metric not in fresh_doc:
            print(f"bench-gate: FAIL — {fname} missing metric {metric!r}")
            return 2
        base, fresh = float(base_doc[metric]), float(fresh_doc[metric])
        if direction == "lower":
            ok = fresh <= base * (1.0 + tol)
        else:
            ok = fresh >= base * (1.0 - tol)
        mark = "ok" if ok else "FAIL"
        failures += 0 if ok else 1
        print(f"bench-gate: {fname}:{metric} fresh={fresh:.4g} "
              f"baseline={base:.4g} ({direction} is better, tol {tol:.0%}) {mark}")
    if failures:
        print(f"bench-gate: FAIL — {failures} metric(s) regressed beyond "
              "tolerance; rerun locally or raise BENCH_REGRESSION_TOLERANCE "
              "if the runner is noisy")
        return 1
    print("bench-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
