"""CI gate: fail the build if the fused pipelined path loses to the serial
oracle on any modality.

Reads the ``speedup`` map from ``BENCH_fused.json`` (written by
``benchmarks/table1_throughput.py``) and exits non-zero if any modality
falls below the threshold. The threshold defaults to 1.0 — the pipelined
path must never be slower than the per-instance path it replaced (the PR-8
ultrasound regression is exactly what this catches) — and can be relaxed
for noisy runners via ``FUSED_GATE_MIN_SPEEDUP``.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_fused.json"
REQUIRED_MODALITIES = ("CT", "US", "DX")


def main() -> int:
    threshold = float(os.environ.get("FUSED_GATE_MIN_SPEEDUP", "1.0"))
    if not BENCH_JSON.exists():
        print(f"fused-gate: FAIL — {BENCH_JSON.name} not found "
              "(run benchmarks/table1_throughput.py first)")
        return 2
    speedup = json.loads(BENCH_JSON.read_text()).get("speedup", {})
    missing = [m for m in REQUIRED_MODALITIES if m not in speedup]
    if missing:
        print(f"fused-gate: FAIL — modalities missing from speedup map: {missing}")
        return 2
    failures = {m: s for m, s in speedup.items() if s < threshold}
    for m in REQUIRED_MODALITIES:
        mark = "FAIL" if m in failures else "ok"
        print(f"fused-gate: {m} batched/serial = {speedup[m]:.3f} "
              f"(min {threshold:.2f}) {mark}")
    if failures:
        print("fused-gate: FAIL — pipelined path lost to the serial oracle; "
              "see benchmarks/table1_throughput.py")
        return 1
    print("fused-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
