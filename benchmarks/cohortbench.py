"""Cohort throughput vs result-lake hit rate (DESIGN.md §6).

The paper's "on-demand" claim lives or dies on repeat traffic: overlapping
cohort requests must not redo work. This benchmark runs the same cohort
through the full stack (planner admission -> broker -> autoscaled pool ->
lake write-back) against a shared result lake pre-warmed to 0% / 50% / 90%,
each timed run on a *fresh* broker+journal deployment so every hit is served
by the content-addressed lake rather than the journal's runtime dedup.

Writes ``BENCH_cohort.json`` (uploaded by CI next to ``BENCH_fused.json``)
so the cohort-serving trajectory is recorded per PR. Wall-clock here is
noisy (shared CPU, throughput drifts over minutes), so the hit rates are
measured *interleaved* over several repetitions and the per-rate minimum is
reported — the same discipline as ``table1_throughput.py``. The fully stable
signals are the instrumentation counters: published messages and kernel
dispatches collapse to the cold slice only.
"""
from __future__ import annotations

import copy
import json
import tempfile
import time
from pathlib import Path

from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.lake import ResultLake
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock

N_STUDIES = 10
N_IMAGES = 6
HIT_RATES = (0.0, 0.5, 0.9)
REPS = 3  # interleaved repetitions; min wall per rate is reported
STUDY_ID = "IRB-BENCH"


def _corpus():
    gen = StudyGenerator(77)
    source = StudyStore("lake")
    mrns = {}
    for i in range(N_STUDIES):
        acc = f"CB{i:03d}"
        s = gen.gen_study(acc, modality="CT", n_images=N_IMAGES)
        source.put_study(acc, s)
        mrns[acc] = s.mrn
    total_bytes = sum(source.get_study(a).nbytes() for a in mrns)
    return source, mrns, total_bytes


def _stack(source, result_lake, journal_path):
    """One deployment: broker + journal + lake-aware pipeline + pool."""
    clock = SimClock()
    broker = Broker(clock, visibility_timeout=300.0)
    journal = Journal(journal_path)
    pipeline = DeidPipeline(recompress=True, lake=result_lake)
    service = DeidService(
        broker, source, journal, result_lake=result_lake, pipeline=pipeline
    )
    service.register_study(STUDY_ID, TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
    )
    return broker, pipeline, service, pool


def run() -> list[dict]:
    source, mrns, total_bytes = _corpus()
    accs = list(mrns)
    with tempfile.TemporaryDirectory() as td:
        # pre-warm one lake per hit rate (not timed)
        prewarmed: dict[float, ResultLake] = {}
        for h in HIT_RATES:
            lake = ResultLake(max_bytes=1 << 30)
            n_warm = int(round(h * len(accs)))
            if n_warm:
                _, _, svc0, pool0 = _stack(
                    source, lake, Path(td) / f"warm{int(h*100)}.jsonl"
                )
                svc0.submit_cohort(STUDY_ID, accs[:n_warm], mrns)
                pool0.drain()
                svc0.planner.resolve()
            prewarmed[h] = lake

        # timed runs, hit rates interleaved so CPU drift hits all rates alike;
        # each rep gets a snapshot of the pre-warmed lake (the timed run's own
        # cold slice must not warm the next rep) and a fresh broker+journal
        walls: dict[float, list[float]] = {h: [] for h in HIT_RATES}
        counters: dict[float, dict] = {}
        run_i = 0
        for rep in range(REPS):
            for h in HIT_RATES:
                run_i += 1
                lake = copy.deepcopy(prewarmed[h])
                broker, pipeline, service, pool = _stack(
                    source, lake, Path(td) / f"run{run_i}.jsonl"
                )
                t0 = time.perf_counter()
                ticket = service.submit_cohort(STUDY_ID, accs, mrns)
                pool.drain()
                service.planner.resolve()
                walls[h].append(time.perf_counter() - t0)
                assert ticket.done()
                if rep == 0:  # counters are deterministic across reps
                    counters[h] = {
                        "lake_hits": service.planner.stats.lake_hits,
                        "published": broker.total_published,
                        "dispatches": pipeline.executor.stats.dispatches,
                        "lake_stored_mb": lake.stored_bytes() / 1e6,
                    }

    cold_wall = min(walls[HIT_RATES[0]])
    rows = []
    for h in HIT_RATES:
        wall = min(walls[h])
        rows.append(
            {
                "hit_rate": h,
                "wall_s": wall,
                "mb_s": total_bytes / wall / 1e6,
                "speedup_vs_cold": cold_wall / wall,
                **counters[h],
            }
        )
    return rows


def main(json_path: str | None = "BENCH_cohort.json") -> list[str]:
    rows = run()
    lines = []
    for r in rows:
        lines.append(
            f"cohort_h{int(r['hit_rate']*100)},{r['wall_s']*1e6:.0f},"
            f"MBps={r['mb_s']:.1f};speedup_vs_cold={r['speedup_vs_cold']:.2f};"
            f"lake_hits={r['lake_hits']};published={r['published']};"
            f"dispatches={r['dispatches']}"
        )
    if json_path:
        payload = {
            "source": "benchmarks/cohortbench.py",
            "n_studies": N_STUDIES,
            "n_images": N_IMAGES,
            "rows": rows,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
