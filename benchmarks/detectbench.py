"""Text-band detector throughput + unknown-device cohort end-to-end cost
(DESIGN.md §9).

Two sections, both written to ``BENCH_detect.json`` (uploaded by CI next to
the other BENCH artifacts):

* **kernel** — a synthetic uint16 batch with seeded glyph bands, profiled
  through the numpy oracle (``ref.row_hits_np``, the host fast path) and the
  Pallas kernel (``ops.row_hit_profile``; interpret mode on CPU — a
  correctness stand-in, so the "speedup" column is honest about being < 1
  off-accelerator). Wall-clock is min-of-interleaved-reps; the deterministic
  signal is that both paths emit bit-identical profiles (asserted).
* **e2e** — the unknown-device story at small scale: a corpus where half the
  studies come from novel (manufacturer, model) variants, served through
  ``DeidService -> CohortPlanner -> WorkerPool`` with a registry-first
  policy. Reports detector scans/detections, unknown lookups, wall time,
  then the cache-identity behavior: a warm resubmit under the same policy
  (all hits) and a resubmit after a policy edit (all cold — the fingerprint
  forced a cold serve).
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

KERNEL_BATCH = 4
KERNEL_SHAPE = (512, 512)
REPS = 3
E2E_STUDIES = 8
E2E_IMAGES = 2
STUDY_ID = "IRB-DETBENCH"


def run_kernel() -> list[dict]:
    from repro.kernels.textdetect import ops, ref

    rng = np.random.default_rng(97)
    H, W = KERNEL_SHAPE
    imgs = (rng.random((KERNEL_BATCH, H, W)) * 2000).astype(np.uint16)
    imgs[:, 10:40, ::3] = 4095   # seeded banner
    imgs[:, 400:420, ::3] = 4095
    thresh = 4095 * 0.6

    # parity before timing: the two paths must agree bit for bit
    hits_o = ref.row_hits_np(imgs, thresh, (32, 128))
    hits_k = ops.row_hit_profile(imgs, thresh=thresh, tile=(32, 128))
    assert np.array_equal(hits_o, hits_k)

    walls = {"oracle": [], "pallas": []}
    for rep in range(REPS + 1):  # rep 0 warms jit caches, not timed
        t0 = time.perf_counter()
        ref.row_hits_np(imgs, thresh, (32, 128))
        t1 = time.perf_counter()
        ops.row_hit_profile(imgs, thresh=thresh, tile=(32, 128))
        t2 = time.perf_counter()
        if rep:
            walls["oracle"].append(t1 - t0)
            walls["pallas"].append(t2 - t1)
    wo, wp = min(walls["oracle"]), min(walls["pallas"])
    n_rows = KERNEL_BATCH * H
    import jax

    return [
        {
            "batch": KERNEL_BATCH,
            "shape": list(KERNEL_SHAPE),
            "backend": jax.default_backend(),
            "oracle_wall_s": wo,
            "oracle_rows_per_s": n_rows / wo,
            "pallas_wall_s": wp,
            "pallas_rows_per_s": n_rows / wp,
            # > 1 on accelerators; < 1 on CPU where Pallas runs interpreted
            "pallas_speedup": wo / wp,
        }
    ]


def run_e2e() -> dict:
    from repro.core import DeidPipeline, TrustMode
    from repro.detect import DetectorPolicy
    from repro.dicom.generator import StudyGenerator
    from repro.lake import ResultLake
    from repro.queueing import (
        Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool,
    )
    from repro.queueing.server import DeidService
    from repro.storage.object_store import StudyStore
    from repro.utils.timing import SimClock

    gen = StudyGenerator(4242)
    source = StudyStore("lake")
    mrns = {}
    unknown = 0
    for i in range(E2E_STUDIES):
        acc = f"DB{i:03d}"
        dev = gen.unknown_device(acc, "CT") if i % 2 == 0 else None
        unknown += dev is not None
        s = gen.gen_study(acc, modality="CT", n_images=E2E_IMAGES, device=dev)
        source.put_study(acc, s)
        mrns[acc] = s.mrn
    lake = ResultLake(max_bytes=1 << 30)

    def deployment(tag: str, policy: DetectorPolicy, td: str):
        clock = SimClock()
        broker = Broker(clock, visibility_timeout=300.0)
        journal = Journal(Path(td) / f"{tag}.jsonl")
        pipeline = DeidPipeline(recompress=False, lake=lake, detector_policy=policy)
        service = DeidService(
            broker, source, journal, result_lake=lake, pipeline=pipeline
        )
        service.register_study(STUDY_ID, TrustMode.POST_IRB)
        dest = StudyStore("researcher")
        pool = WorkerPool(
            broker,
            Autoscaler(broker, AutoscalerConfig(), clock),
            lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
        )
        return service, pool, pipeline

    with tempfile.TemporaryDirectory() as td:
        service, pool, pipeline = deployment("cold", DetectorPolicy(), td)
        t0 = time.perf_counter()
        ticket = service.submit_cohort(STUDY_ID, list(mrns), mrns)
        pool.drain()
        service.planner.resolve()
        cold_wall = time.perf_counter() - t0
        assert ticket.done() and not ticket.failed
        st = pipeline.scrub.detect_stats
        ex = pipeline.executor.stats

        warm = service.submit_cohort(STUDY_ID, list(mrns), mrns)
        assert not warm.cold

        edited, pool2, _ = deployment(
            "edited", DetectorPolicy(row_frac=0.05), td
        )
        after = edited.submit_cohort(STUDY_ID, list(mrns), mrns)
        pool2.drain()
        edited.planner.resolve()

        return {
            "studies": E2E_STUDIES,
            "images_per_study": E2E_IMAGES,
            "unknown_device_studies": unknown,
            "cold_wall_s": cold_wall,
            "cold_published": len(ticket.cold),
            "unknown_lookups": st.unknown_lookups,
            "detector_runs": st.detector_runs,
            "detector_detected": st.detected,
            "detect_dispatches": ex.detect_dispatches,
            "warm_hits_same_policy": len(warm.hits),
            "cold_after_policy_change": len(after.cold),
            "warm_hits_after_policy_change": len(after.hits),
        }


def main(json_path: str | None = "BENCH_detect.json") -> list[str]:
    kernel = run_kernel()
    e2e = run_e2e()
    lines = []
    for r in kernel:
        lines.append(
            f"detect_kernel,{r['pallas_wall_s']*1e6:.0f},"
            f"oracle_rows_s={r['oracle_rows_per_s']:.0f};"
            f"pallas_rows_s={r['pallas_rows_per_s']:.0f};"
            f"speedup={r['pallas_speedup']:.3f};backend={r['backend']}"
        )
    lines.append(
        f"detect_e2e_cold,{e2e['cold_wall_s']*1e6:.0f},"
        f"unknown={e2e['unknown_device_studies']};"
        f"runs={e2e['detector_runs']};detected={e2e['detector_detected']}"
    )
    lines.append(
        "detect_e2e_policy_change,0,"
        f"warm_same={e2e['warm_hits_same_policy']};"
        f"cold_after_edit={e2e['cold_after_policy_change']}"
    )
    if json_path:
        payload = {
            "source": "benchmarks/detectbench.py",
            "kernel": kernel,
            "e2e": e2e,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
