"""Fleet-level SLA / cost benchmark over the deterministic simulator.

Reproduces the *shape* of the paper's Table 1: as the requested backlog grows,
the autoscaler provisions more instances, holds the delivery window, and the
dollar cost scales with bytes — not with wall time. Because the fleet runs on
the SimClock, every number here is exact and replayable from the seed; there
is no shared-CPU noise to average away (the wall_s column is the only
real-time figure, reported for CI trend-watching).

Each row drains one cohort request over a growing study count through the
real service -> broker -> autoscaled pool -> lake stack, then a 90%-warm
replay storm row shows the repeat-traffic regime. Writes ``BENCH_fleet.json``
(uploaded by CI next to the other BENCH files).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.sim import ChaosSchedule, CohortArrival, FleetConfig, FleetSim, ReplayStorm

SEED = 17
BACKLOG_STUDIES = (4, 8, 16)
IMAGES_PER_STUDY = 2
# scaled-down Table-1 regime: a ~1 MB study takes ~21 s per instance, so the
# 90 s window forces the autoscaler to widen the pool as the backlog grows
# (90 rather than 60: a worker holds a study for a whole 21 s round, so the
# window must absorb one round of scheduling granularity)
WINDOW_S = 90.0
THROUGHPUT = 50e3


def _one_shot_traffic(corpus, study_id="IRB-T1"):
    return [CohortArrival(t=0.0, study_id=study_id, accessions=tuple(corpus))]


def _run(cfg: FleetConfig, traffic, tmpdir: Path, tag: str) -> dict:
    t0 = time.perf_counter()
    sim = FleetSim(cfg, traffic, tmpdir / f"{tag}.jsonl", ChaosSchedule.quiet())
    report = sim.run()
    wall = time.perf_counter() - t0
    assert report.ok(), [v.detail for v in report.violations]
    backlog = sum(sim.source.get_study(a).nbytes() for a in sim.mrns)
    peak = max((n for _, n in sim.pool.autoscaler.tick_log), default=0)
    return {
        "tag": tag,
        "seed": cfg.seed,
        "studies": cfg.n_studies,
        "backlog_mb": round(backlog / 1e6, 3),
        "cohorts": report.metrics["cohorts"],
        "sla_attainment": report.metrics["sla_attainment"],
        "sim_minutes": report.metrics["sim_minutes"],
        "max_latency_s": report.metrics["max_latency_s"],
        "peak_instances": peak,
        "instance_seconds": report.metrics["instance_seconds"],
        "cost_usd": report.metrics["cost_usd"],
        "processed": report.metrics["processed"],
        "lake_hit_rate": report.metrics["lake_hit_rate"],
        "log_digest": report.log_digest,
        "wall_s": round(wall, 3),
    }


def run(tmpdir: Path) -> list[dict]:
    rows = []
    for n in BACKLOG_STUDIES:
        cfg = FleetConfig(
            seed=SEED, n_studies=n, images_per_study=IMAGES_PER_STUDY,
            delivery_window=WINDOW_S, worker_throughput=THROUGHPUT,
        )
        corpus = [f"SIM{i:04d}" for i in range(n)]
        rows.append(_run(cfg, _one_shot_traffic(corpus), tmpdir, f"cold_n{n}"))

    # repeat-traffic regime: 90%-warm storm over the largest corpus
    n = BACKLOG_STUDIES[-1]
    cfg = FleetConfig(
        seed=SEED, n_studies=n, images_per_study=IMAGES_PER_STUDY,
        delivery_window=WINDOW_S, worker_throughput=THROUGHPUT,
    )
    corpus = [f"SIM{i:04d}" for i in range(n)]
    storm = ReplayStorm(
        warm_fraction=0.9, base_size=n, n_replays=3, cohort_size=min(10, n)
    ).schedule(corpus, SEED)
    rows.append(_run(cfg, storm, tmpdir, f"storm90_n{n}"))
    return rows


def main(json_path: str | None = "BENCH_fleet.json") -> list[str]:
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        rows = run(Path(td))
    lines = [
        f"fleet_{r['tag']},{r['sim_minutes']*60*1e6:.0f},"
        f"sla={r['sla_attainment']:.2f};cost_usd={r['cost_usd']:.4f};"
        f"peak_instances={r['peak_instances']};backlog_mb={r['backlog_mb']:.1f};"
        f"hit_rate={r['lake_hit_rate']:.2f}"
        for r in rows
    ]
    if json_path:
        payload = {
            "source": "benchmarks/fleetbench.py",
            "seed": SEED,
            "window_s": WINDOW_S,
            "rows": rows,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
