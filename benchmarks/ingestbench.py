"""Change-feed ingest benchmark (DESIGN.md §10).

Three measurements, all exact and replayable from the seed:

* ``drain`` — raw pooler->applier throughput: commit a burst of PACS
  mutations and drain them into the lake + catalog through the real
  checkpointed handoff (events/s is the only wall-time figure, for CI
  trend-watching; the effect counts are deterministic).
* ``chaos`` — a full feed-chaos fleet run (pooler crashes mid-batch, feed
  outage, duplicate/out-of-order delivery): reports checkpoint-replay
  recovery time and asserts zero invariant violations.
* ``redeid`` — incremental re-de-identification amplification: mutate k of n
  already-delivered source studies and resubmit the cohort. Amplification is
  re-deids / mutations and must be exactly 1.0 — the untouched studies ride
  the warm path.

Writes ``BENCH_ingest.json`` (uploaded by CI next to the other BENCH files).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

SEED = 23
DRAIN_EVENTS = 64
REDEID_STUDIES = 6
REDEID_MUTATED = 2


def _drain_row(tmpdir: Path) -> dict:
    from repro.catalog import StudyCatalog
    from repro.dicom.generator import StudyGenerator
    from repro.ingest import ChangePooler, Checkpoint, IngestApplier, PacsFeed
    from repro.queueing.broker import Broker
    from repro.storage.object_store import StudyStore
    from repro.utils.timing import SimClock

    clock = SimClock()
    feed = PacsFeed(SEED, images_per_study=1)
    store = StudyStore("lake", key=b"k")
    store.attach_catalog(StudyCatalog())
    gen = StudyGenerator(SEED)
    for i in range(4):
        acc = f"ACC{i:04d}"
        study = gen.gen_study(acc, modality="CT", n_images=1)
        store.put_study(acc, study)
        feed.adopt(acc, study)
    broker = Broker(clock, visibility_timeout=60.0)
    ckpt = Checkpoint(tmpdir / "drain.ckpt")
    pooler = ChangePooler(feed, broker, ckpt, clock, seed=SEED, batch=16)
    applier = IngestApplier(broker, feed, store, ckpt)
    # 4 creates then an update burst cycling over the whole inventory: the
    # drain exercises both the create path and burst-collapse dedup
    for i in range(DRAIN_EVENTS):
        if i < 4:
            feed.commit("create", f"PACS{i:04d}")
        else:
            feed.commit("update", f"ACC{i % 4:04d}")
    t0 = time.perf_counter()
    applied = 0
    while pooler.behind() or not broker.empty():
        clock.advance(30.0)
        pooler.poll_once()
        applied += len(applier.drain())
    wall = time.perf_counter() - t0
    assert not pooler.behind() and broker.empty()
    return {
        "tag": "drain",
        "seed": SEED,
        "committed_events": feed.last_seq,
        "applied": applier.stats.applied,
        "effect_deduped": applier.stats.effect_deduped,
        "checkpoint_floor": ckpt.floor(),
        "events_per_s": round(feed.last_seq / max(wall, 1e-9), 1),
        "wall_s": round(wall, 4),
    }


def _chaos_row(tmpdir: Path) -> dict:
    from repro.sim import BurstyTraffic, ChaosSchedule, FleetConfig, FleetSim

    corpus = [f"SIM{i:04d}" for i in range(6)]
    traffic = BurstyTraffic(
        n_bursts=2, cohorts_per_burst=2, cohort_size=3
    ).schedule(corpus, SEED)
    chaos = ChaosSchedule.seeded(
        SEED, 600.0, corpus,
        crash_events=1, reingests=2, lease_storms=1,
        pooler_crashes=2, feed_outages=1, feed_faults=1,
    )
    cfg = FleetConfig(
        seed=SEED, n_studies=6, images_per_study=1, feed_mutations=12
    )
    t0 = time.perf_counter()
    sim = FleetSim(cfg, traffic, tmpdir / "chaos.jsonl", chaos)
    report = sim.run()
    wall = time.perf_counter() - t0
    assert report.ok(), [v.detail for v in report.violations]
    return {
        "tag": "chaos",
        "seed": SEED,
        "feed_events": report.metrics["feed_events"],
        "feed_applied": report.metrics["feed_applied"],
        "pooler_crashes": report.metrics["pooler_crashes"],
        "pooler_recovery_s": report.metrics.get("pooler_recovery_s", 0.0),
        "feed_redelivered": report.metrics["feed_redelivered"],
        "feed_outage_polls": report.metrics["feed_outage_polls"],
        "violations": len(report.violations),
        "log_digest": report.log_digest,
        "wall_s": round(wall, 3),
    }


def _redeid_row(tmpdir: Path) -> dict:
    from repro.core import DeidPipeline, TrustMode
    from repro.dicom.generator import StudyGenerator
    from repro.lake.store import ResultLake
    from repro.queueing.autoscaler import Autoscaler, AutoscalerConfig
    from repro.queueing.broker import Broker
    from repro.queueing.journal import Journal
    from repro.queueing.server import DeidService
    from repro.queueing.worker import DeidWorker, WorkerPool
    from repro.storage.object_store import StudyStore
    from repro.utils.timing import SimClock

    clock = SimClock()
    gen = StudyGenerator(SEED)
    store = StudyStore("lake", key=b"k")
    mrns = {}
    for i in range(REDEID_STUDIES):
        acc = f"ACC{i:04d}"
        s = gen.gen_study(acc, modality="CT", n_images=1)
        store.put_study(acc, s)
        mrns[acc] = s.mrn
    broker = Broker(clock, visibility_timeout=60.0)
    journal = Journal(tmpdir / "redeid.jsonl")
    lake = ResultLake(max_bytes=1 << 30)
    pipeline = DeidPipeline(recompress=False, lake=lake)
    service = DeidService(
        broker, store, journal, result_lake=lake, pipeline=pipeline
    )
    service.register_study("IRB-B", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    workers = []

    def make_worker(wid):
        w = DeidWorker(wid, pipeline, store, dest, journal)
        workers.append(w)
        return w

    pool = WorkerPool(
        broker, Autoscaler(broker, AutoscalerConfig(), clock), make_worker
    )
    service.submit_cohort("IRB-B", list(mrns), mrns)
    pool.drain()
    cold_processed = sum(w.processed for w in workers)
    # mutate k source studies (re-acquired bytes, same patients)
    mutated = list(mrns)[:REDEID_MUTATED]
    for acc in mutated:
        new = StudyGenerator(SEED + 99).gen_study(acc, modality="CT", n_images=1)
        new.mrn = mrns[acc]
        store.put_study(acc, new)
    t0 = time.perf_counter()
    service.submit_cohort("IRB-B", list(mrns), mrns)
    pool.drain()
    wall = time.perf_counter() - t0
    re_deids = sum(w.processed for w in workers) - cold_processed
    amplification = re_deids / REDEID_MUTATED
    assert amplification == 1.0, amplification
    assert journal.supersessions == REDEID_MUTATED
    assert sum(w.evicted_stale for w in workers) == REDEID_MUTATED
    return {
        "tag": "redeid",
        "seed": SEED,
        "studies": REDEID_STUDIES,
        "mutated": REDEID_MUTATED,
        "re_deids": re_deids,
        "amplification": amplification,
        "stale_refreshes": service.planner.stats.stale_refreshes,
        "supersessions": journal.supersessions,
        "evicted_stale": sum(w.evicted_stale for w in workers),
        "wall_s": round(wall, 4),
    }


def run(tmpdir: Path) -> list[dict]:
    return [_drain_row(tmpdir), _chaos_row(tmpdir), _redeid_row(tmpdir)]


def main(json_path: str | None = "BENCH_ingest.json") -> list[str]:
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        rows = run(Path(td))
    by_tag = {r["tag"]: r for r in rows}
    lines = [
        (
            f"ingest_drain,{by_tag['drain']['wall_s'] * 1e6:.0f},"
            f"events_per_s={by_tag['drain']['events_per_s']:.0f};"
            f"applied={by_tag['drain']['applied']};"
            f"deduped={by_tag['drain']['effect_deduped']}"
        ),
        (
            f"ingest_chaos,{by_tag['chaos']['wall_s'] * 1e6:.0f},"
            f"crashes={by_tag['chaos']['pooler_crashes']:.0f};"
            f"recovery_s={by_tag['chaos']['pooler_recovery_s']:.1f};"
            f"violations={by_tag['chaos']['violations']}"
        ),
        (
            f"ingest_redeid,{by_tag['redeid']['wall_s'] * 1e6:.0f},"
            f"amplification={by_tag['redeid']['amplification']:.2f};"
            f"mutated={by_tag['redeid']['mutated']};"
            f"re_deids={by_tag['redeid']['re_deids']}"
        ),
    ]
    if json_path:
        payload = {
            "source": "benchmarks/ingestbench.py",
            "seed": SEED,
            "rows": rows,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
