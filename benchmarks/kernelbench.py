"""Kernel micro-benchmarks: Pallas (interpret, CPU) vs numpy reference, plus
the TPU roofline each kernel targets. Host timings validate correctness-path
cost; the derived column reports the kernel's v5e bound (all three kernels
are HBM-streaming: bound = 819 GB/s / bytes-touched-per-byte)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.scrub import numpy_blank
from repro.dicom import codec
from repro.kernels.fused.ops import fused_scrub_residuals
from repro.kernels.jls.ops import jls_residuals
from repro.kernels.phi_detect.ops import edge_density
from repro.kernels.scrub.ops import pack_rects, scrub_images
from repro.launch import hw


def _time(fn, n=3):
    fn()  # warm/compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def main() -> list[str]:
    rng = np.random.default_rng(0)
    imgs = (rng.random((4, 512, 512)) * 4000).astype(np.uint16)
    rl = [[(0, 0, 512, 22), (300, 22, 212, 80)]] * 4
    rects = pack_rects(rl)
    jimgs = jnp.asarray(imgs)

    lines = []
    nbytes = imgs.nbytes

    t_k = _time(lambda: np.asarray(scrub_images(jimgs, rects)))
    t_n = _time(lambda: [numpy_blank(imgs[i], rl[i]) for i in range(4)])
    # scrub reads+writes each pixel once -> v5e bound = HBM/2
    lines.append(
        f"scrub_kernel,{t_k*1e6:.0f},host_MBps={nbytes/t_k/1e6:.0f};numpy_MBps={nbytes/t_n/1e6:.0f};"
        f"v5e_bound_GBps={hw.HBM_BW/2/1e9:.0f}"
    )

    t_p = _time(lambda: np.asarray(edge_density(jimgs)))
    lines.append(
        f"phi_detect_kernel,{t_p*1e6:.0f},host_MBps={nbytes/t_p/1e6:.0f};"
        f"v5e_bound_GBps={hw.HBM_BW/1e9:.0f}"
    )

    t_j = _time(lambda: np.asarray(jls_residuals(imgs)))
    t_c = _time(lambda: [codec.residuals(imgs[i]) for i in range(4)])
    # jls reads u16, writes s32 residuals -> 1:3 traffic
    lines.append(
        f"jls_kernel,{t_j*1e6:.0f},host_MBps={nbytes/t_j/1e6:.0f};numpy_MBps={nbytes/t_c/1e6:.0f};"
        f"v5e_bound_GBps={hw.HBM_BW/3/1e9:.0f}"
    )

    # fused scrub+JLS: one HBM pass for both bandwidth-bound stages.
    # bytes touched per pixel (u16): staged = scrub(2r+2w) + jls(2r+4w) = 10,
    # fused = 2r + 4w = 6 -> 0.60 of the staged pair's HBM traffic, raising
    # the input-byte roofline from HBM/5 to HBM/3.
    item = imgs.dtype.itemsize
    fused_bpp = item + 4
    staged_bpp = 3 * item + 4
    t_f = _time(lambda: np.asarray(fused_scrub_residuals(jimgs, rects)))
    t_s = _time(lambda: np.asarray(jls_residuals(scrub_images(jimgs, rects))))
    lines.append(
        f"fused_scrub_jls_kernel,{t_f*1e6:.0f},host_MBps={nbytes/t_f/1e6:.0f};"
        f"staged_MBps={nbytes/t_s/1e6:.0f};traffic_ratio={fused_bpp/staged_bpp:.2f};"
        f"v5e_bound_GBps={hw.HBM_BW*item/fused_bpp/1e9:.0f};"
        f"staged_pair_bound_GBps={hw.HBM_BW*item/staged_bpp/1e9:.0f}"
    )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
