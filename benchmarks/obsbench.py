"""Tracer overhead on the hot serve path (DESIGN.md §11).

Observability must be effectively free: the acceptance bar is <5% wall-clock
overhead with a live ``Tracer(WallClock())`` versus the disabled
``NULL_TRACER`` on the 90%-warm cohort path — the paper's steady-state
workload, where per-study compute is smallest and per-span bookkeeping is
proportionally largest (the worst case for tracing).

Methodology mirrors ``cohortbench.py``: both modes run the same pre-warmed
cohort through a fresh broker+journal deployment, *interleaved* over several
repetitions so CPU drift hits both alike, and the per-mode minimum is
compared. The serve path emits only ~a dozen spans per cohort, so the
end-to-end delta is dominated by scheduler noise (±5% swings on a shared CI
core dwarf microseconds of span bookkeeping); the *asserted* number is
therefore the attributable overhead — spans-per-run × microbenchmarked
per-span cost ÷ serve wall — with the raw end-to-end walls reported
alongside as evidence. Writes ``BENCH_obs.json`` plus a sample redacted
Chrome trace (``BENCH_obs_trace.json``, loadable in Perfetto /
chrome://tracing) so every PR records both the overhead number and what a
cold-serve trace looks like.
"""
from __future__ import annotations

import copy
import json
import tempfile
import time
from pathlib import Path

from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.lake import ResultLake
from repro.obs import Redactor, Tracer, to_chrome_trace
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock, WallClock

N_STUDIES = 10
N_IMAGES = 6
WARM_RATE = 0.9
REPS = 5  # interleaved repetitions; min wall per mode is reported
MAX_OVERHEAD = 0.05
STUDY_ID = "IRB-OBS"


def _span_cost_us(n: int = 20_000) -> float:
    """Microbenchmark one open-set-close span cycle (attrs + clock reads),
    the unit the serve path pays ~a dozen times per cohort."""
    tracer = Tracer(WallClock())
    t0 = time.perf_counter()
    for i in range(n):
        with tracer.span("bench.span", key="IRB-OBS/OB000", attempt=1) as sp:
            sp.set(ok=True, nbytes=i)
    per = (time.perf_counter() - t0) / n
    tracer.clear()
    return per * 1e6


def _corpus():
    gen = StudyGenerator(78)
    source = StudyStore("lake")
    mrns = {}
    for i in range(N_STUDIES):
        acc = f"OB{i:03d}"
        s = gen.gen_study(acc, modality="CT", n_images=N_IMAGES)
        source.put_study(acc, s)
        mrns[acc] = s.mrn
    total_bytes = sum(source.get_study(a).nbytes() for a in mrns)
    return source, mrns, total_bytes


def _stack(source, result_lake, journal_path, tracer):
    """One deployment with the observability plane threaded end to end
    (tracer=None means every component falls back to NULL_TRACER)."""
    clock = SimClock()
    broker = Broker(clock, visibility_timeout=300.0, tracer=tracer)
    journal = Journal(journal_path)
    pipeline = DeidPipeline(recompress=True, lake=result_lake, tracer=tracer)
    service = DeidService(
        broker, source, journal, result_lake=result_lake, pipeline=pipeline,
        tracer=tracer,
    )
    service.register_study(STUDY_ID, TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(
            wid, pipeline, source, dest, journal, tracer=tracer
        ),
    )
    return service, pool


def run() -> dict:
    source, mrns, total_bytes = _corpus()
    accs = list(mrns)
    n_warm = int(round(WARM_RATE * len(accs)))
    with tempfile.TemporaryDirectory() as td:
        # pre-warm the result lake to 90% (not timed)
        warm_lake = ResultLake(max_bytes=1 << 30)
        svc0, pool0 = _stack(source, warm_lake, Path(td) / "warm.jsonl", None)
        svc0.submit_cohort(STUDY_ID, accs[:n_warm], mrns)
        pool0.drain()
        svc0.planner.resolve()

        walls: dict[str, list[float]] = {"disabled": [], "traced": []}
        span_count = 0
        sample_trace: dict | None = None
        run_i = 0
        for _rep in range(REPS):
            for mode in ("disabled", "traced"):
                run_i += 1
                tracer = Tracer(WallClock()) if mode == "traced" else None
                lake = copy.deepcopy(warm_lake)
                service, pool = _stack(
                    source, lake, Path(td) / f"run{run_i}.jsonl", tracer
                )
                t0 = time.perf_counter()
                ticket = service.submit_cohort(STUDY_ID, accs, mrns)
                pool.drain()
                service.planner.resolve()
                walls[mode].append(time.perf_counter() - t0)
                assert ticket.done()
                if mode == "traced" and sample_trace is None:
                    span_count = len(tracer.spans())
                    sample_trace = to_chrome_trace(tracer.spans(), Redactor())

    plain, traced = min(walls["disabled"]), min(walls["traced"])
    span_cost = _span_cost_us()
    # attributable overhead: what the tracer itself costs on this path. The
    # raw end-to-end delta rides along as evidence but is scheduler-noise
    # bound (±5% swings dwarf microseconds of span bookkeeping).
    overhead = (span_count * span_cost * 1e-6) / plain
    return {
        "warm_rate": WARM_RATE,
        "wall_disabled_s": plain,
        "wall_traced_s": traced,
        "end_to_end_delta_pct": (traced - plain) / plain * 100.0,
        "span_cost_us": span_cost,
        "overhead_pct": overhead * 100.0,
        "spans_per_run": span_count,
        "mb_s_traced": total_bytes / traced / 1e6,
        "sample_trace": sample_trace,
    }


def main(
    json_path: str | None = "BENCH_obs.json",
    trace_path: str | None = "BENCH_obs_trace.json",
) -> list[str]:
    r = run()
    assert r["overhead_pct"] < MAX_OVERHEAD * 100.0, (
        f"tracer overhead {r['overhead_pct']:.2f}% exceeds the "
        f"{MAX_OVERHEAD:.0%} budget on the {WARM_RATE:.0%}-warm cohort path"
    )
    lines = [
        f"obs_disabled,{r['wall_disabled_s']*1e6:.0f},warm={WARM_RATE}",
        f"obs_traced,{r['wall_traced_s']*1e6:.0f},"
        f"spans={r['spans_per_run']};MBps={r['mb_s_traced']:.1f}",
        f"obs_span_cost,{r['span_cost_us']:.2f},"
        f"overhead_pct={r['overhead_pct']:.4f};"
        f"end_to_end_delta_pct={r['end_to_end_delta_pct']:.2f}",
    ]
    sample = r.pop("sample_trace")
    if trace_path and sample is not None:
        Path(trace_path).write_text(json.dumps(sample) + "\n")
    if json_path:
        payload = {
            "source": "benchmarks/obsbench.py",
            "n_studies": N_STUDIES,
            "n_images": N_IMAGES,
            "reps": REPS,
            "max_overhead_pct": MAX_OVERHEAD * 100.0,
            **r,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
