"""Roofline analysis (deliverable g): aggregate the dry-run JSONs into the
per-(arch x shape x mesh) three-term table, identify the dominant bottleneck,
cross-check MODEL_FLOPS = 6ND (6*N_active*D for MoE) against HLO FLOPs, and
emit EXPERIMENTS.md §Roofline content (experiments/roofline.md)."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.config.model import SHAPES
from repro.config.registry import list_archs
from repro.launch import hw

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT_MD = Path(__file__).resolve().parents[1] / "experiments" / "roofline.md"


def model_flops_per_chip(rec: dict) -> float:
    """6*N(_active)*D per optimizer step / chips — train cells only; decode
    and prefill use 2*N*D (forward only)."""
    shape = SHAPES[rec["shape"]]
    n = rec["active_params"]
    chips = rec["n_chips"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / chips
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens / chips


def load_records() -> list[dict]:
    recs = []
    for path in sorted(DRYRUN_DIR.glob("*.json")):
        try:
            recs.append(json.loads(path.read_text()))
        except json.JSONDecodeError:
            continue
    return recs


def analyze(rec: dict) -> dict:
    r = dict(rec)
    roof = rec.get("roofline") or {}
    terms = {
        "compute": roof.get("compute_s") or 0.0,
        "memory": roof.get("memory_s") or 0.0,
        "collective": roof.get("collective_s") or 0.0,
    }
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    mf = model_flops_per_chip(rec)
    r["model_flops_chip"] = mf
    r["useful_ratio"] = mf / rec["hlo_flops"] if rec.get("hlo_flops") else None
    r["dominant"] = dominant
    r["bound_s"] = bound_s
    # roofline fraction: useful-model-compute time / dominant-term time
    r["roofline_fraction"] = (mf / hw.PEAK_FLOPS_BF16) / bound_s if bound_s else None
    return r


def advice(r: dict) -> str:
    d = r["dominant"]
    if d == "collective":
        return "re-shard to cut resharding/gather traffic (SP boundaries, FSDP gather grouping, larger microbatches)"
    if d == "memory":
        if SHAPES[r["shape"]].kind == "decode":
            return "decode is weight/cache-streaming bound: quantize KV/weights or batch more sequences per step"
        return "reduce remat re-reads / fuse CE head (bf16 chunk logits), bigger attention chunks"
    return "compute-bound: increase per-chip arithmetic intensity is already optimal; tune MXU tiling"


def to_markdown(recs: list[dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    lines = [
        "# Roofline table (from the multi-pod dry-run)",
        "",
        f"v5e terms: compute = HLO_FLOPs/chip / {hw.PEAK_FLOPS_BF16:.0e}; memory = HLO_bytes/chip / {hw.HBM_BW:.0e}; "
        f"collective = ICI bytes / {hw.ICI_BW:.0e} + cross-pod bytes / {hw.DCI_BW:.0e} (per chip).",
        "",
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant | peak GB/dev | 6ND/HLO | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted((analyze(x) for x in ok), key=lambda z: (z["arch"], z["shape"], z["mesh"])):
        roof = r["roofline"]
        lines.append(
            "| {arch} | {shape} | {mesh} | {c:.3g} | {m:.3g} | {k:.3g} | **{dom}** | {gb:.1f} | {ur} | {rf} | {adv} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                c=roof.get("compute_s") or 0, m=roof.get("memory_s") or 0, k=roof.get("collective_s") or 0,
                dom=r["dominant"], gb=r.get("peak_bytes_per_device", 0) / 1e9,
                ur=f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-",
                rf=f"{r['roofline_fraction']:.3f}" if r["roofline_fraction"] else "-",
                adv=advice(r),
            )
        )
    lines.append("")
    lines.append("## Skipped cells (spec'd inapplicability)")
    for r in sorted(skipped, key=lambda z: (z["arch"], z["shape"], z["mesh"])):
        lines.append(f"- {r['arch']} x {r['shape']} ({r['mesh']}): {r['reason']}")
    return "\n".join(lines) + "\n"


def main() -> list[str]:
    t0 = time.perf_counter()
    recs = load_records()
    ok = [analyze(r) for r in recs if r.get("status") == "ok"]
    md = to_markdown(recs)
    OUT_MD.parent.mkdir(parents=True, exist_ok=True)
    OUT_MD.write_text(md)
    us = (time.perf_counter() - t0) * 1e6
    by_dom = {}
    for r in ok:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    fracs = [r["roofline_fraction"] for r in ok if r["roofline_fraction"]]
    out = [
        f"roofline_table,{us:.0f},cells_ok={len(ok)};skipped={sum(r.get('status')=='skipped' for r in recs)};"
        f"dominant={by_dom};median_frac={sorted(fracs)[len(fracs)//2]:.3f}" if fracs else
        f"roofline_table,{us:.0f},cells_ok={len(ok)};no-fractions-yet"
    ]
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
