"""Host/device boundary roofline for the pipelined de-id path (DESIGN.md §12).

The fused kernel moved scrub + residuals + entropy *planning* onto the
device; the host keeps only the final Golomb-Rice word splice. This model
reads the measured per-modality numbers from ``BENCH_fused.json`` and the
TPU v5e constants from :mod:`repro.launch.hw` and answers the boundary
questions:

- **overlap win**: seconds/GB the double-buffered pipeline hides versus the
  serial oracle (``1/serial - 1/batched``), and how close the measured
  speedup sits to the perfect-overlap bound ``(d + h) / max(d, h)`` where
  ``d``/``h`` are the implied device/host stage times (``d = serial -
  batched`` under the host-bound steady state the traces show).
- **feed ratio**: how many host cores one v5e chip's fused scrub+plan pass
  can keep busy — the device roofline (HBM-bound single pass) divided by
  one core's measured pack throughput. This is the §12 argument that the
  *host entropy tail*, not de-id compute, is the post-TPU bottleneck.

Emits ``experiments/roofline.md`` and the usual ``name,us,derived`` CSV.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.launch import hw

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_fused.json"
OUT_MD = Path(__file__).resolve().parents[1] / "experiments" / "roofline.md"


def load_rows() -> list[dict]:
    if not BENCH_JSON.exists():
        return []
    try:
        payload = json.loads(BENCH_JSON.read_text())
    except json.JSONDecodeError:
        return []
    return payload.get("rows", [])


def analyze(row: dict) -> dict:
    """Boundary model for one modality row of BENCH_fused.json."""
    r = dict(row)
    batched = row["measured_mb_s_core"] * 1e6   # bytes/s, pipelined path
    serial = row["serial_mb_s_core"] * 1e6      # bytes/s, per-instance oracle
    # per-byte stage times: in the host-bound steady state the pipelined
    # time IS the host tail h, and the serial path pays d + h, so the
    # device-side share is the difference (clamped: a sub-1.0 row would
    # imply negative d, i.e. the overlap regressed)
    t_batched = 1.0 / batched
    t_serial = 1.0 / serial
    d = max(t_serial - t_batched, 0.0)
    h = t_batched
    r["speedup"] = batched / serial
    r["ideal_overlap"] = (d + h) / max(d, h) if (d + h) else 1.0
    r["overlap_efficiency"] = r["speedup"] / r["ideal_overlap"]
    r["hidden_s_per_gb"] = d * 1e9
    # device roofline: the fused scrub+residual+plan kernel is HBM-bound —
    # read itemsize bytes/pixel, write int32 residual + int32 len/rem words
    dev_gbps = row.get("tpu_fused_gb_s") or (hw.HBM_BW / 2 / 1e9)
    r["device_roofline_gb_s"] = dev_gbps
    r["cores_per_chip"] = dev_gbps * 1e9 / batched
    r["bound"] = "host" if d <= h else "device"
    return r


def to_markdown(rows: list[dict]) -> str:
    lines = [
        "# Host/device boundary roofline (pipelined de-id path)",
        "",
        f"Device terms use v5e constants: HBM {hw.HBM_BW / 1e9:.0f} GB/s, "
        f"peak {hw.PEAK_FLOPS_BF16 / 1e12:.0f} TFLOP/s bf16. Host terms are "
        "measured single-core throughput from BENCH_fused.json.",
        "",
        "| modality | batched MB/s | serial MB/s | speedup | ideal overlap | "
        "overlap eff | hidden s/GB | device GB/s | cores/chip | bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            "| {m} | {b:.1f} | {s:.1f} | {sp:.2f} | {io:.2f} | {oe:.0%} | "
            "{hid:.2f} | {dev:.0f} | {cpc:.0f} | **{bound}** |".format(
                m=r["modality"], b=r["measured_mb_s_core"],
                s=r["serial_mb_s_core"], sp=r["speedup"],
                io=r["ideal_overlap"], oe=r["overlap_efficiency"],
                hid=r["hidden_s_per_gb"], dev=r["device_roofline_gb_s"],
                cpc=r["cores_per_chip"], bound=r["bound"],
            )
        )
    lines += [
        "",
        "Reading: every modality is **host-bound** — the double-buffered "
        "dispatch hides the device stage behind the host Golomb-Rice splice, "
        "so the next lever is host-side (more pack workers per core, or "
        "moving the final unary splice onto the device), not kernel work. "
        "`cores/chip` is how many pack cores one v5e chip's fused pass can "
        "saturate; at fleet scale the chip is never the bottleneck.",
    ]
    return "\n".join(lines) + "\n"


def main() -> list[str]:
    t0 = time.perf_counter()
    rows = [analyze(r) for r in load_rows()]
    if not rows:
        return ["roofline_boundary,-1,no-BENCH_fused.json-yet (run table1_throughput first)"]
    OUT_MD.parent.mkdir(parents=True, exist_ok=True)
    OUT_MD.write_text(to_markdown(rows))
    us = (time.perf_counter() - t0) * 1e6
    host_bound = sum(r["bound"] == "host" for r in rows)
    worst = min(rows, key=lambda r: r["speedup"])
    effs = "/".join("{:.0%}".format(r["overlap_efficiency"]) for r in rows)
    median_cpc = sorted(r["cores_per_chip"] for r in rows)[len(rows) // 2]
    return [
        f"roofline_boundary,{us:.0f},host_bound={host_bound}/{len(rows)};"
        f"min_speedup={worst['speedup']:.2f}@{worst['modality']};"
        f"median_cores_per_chip={median_cpc:.0f};overlap_eff={effs}"
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
