"""Benchmark harness — one entry per paper table/figure + system extensions.
Prints ``name,us_per_call,derived`` CSV (one line per measurement)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        auditbench,
        autoscale,
        catalogbench,
        cohortbench,
        detectbench,
        fleetbench,
        ingestbench,
        kernelbench,
        obsbench,
        roofline,
        slobench,
        table1_throughput,
        table2_rules,
    )

    suites = [
        ("table1_throughput", table1_throughput.main),
        ("table2_rules", table2_rules.main),
        ("cohortbench", cohortbench.main),
        ("catalogbench", catalogbench.main),
        ("detectbench", detectbench.main),
        ("fleetbench", fleetbench.main),
        ("ingestbench", ingestbench.main),
        ("obsbench", obsbench.main),
        ("auditbench", auditbench.main),
        ("slobench", slobench.main),
        ("autoscale", autoscale.main),
        ("kernelbench", kernelbench.main),
        ("roofline", roofline.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for line in fn():
                print(line)
        except Exception:
            failures += 1
            print(f"{name},-1,ERROR")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
