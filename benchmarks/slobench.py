"""SLO engine + critical-path profiler cost model (DESIGN.md §13).

Two questions, one file:

1. **Sensitivity** — how long after a regression starts does the multi-window
   burn-rate alert fire? Measured in *simulated* seconds on a deterministic
   observation stream (1 obs/s, bad fraction ``m`` injected from t=600 via a
   Weyl-style hash pattern, evaluated every second), so the number is
   bit-stable across machines: it characterizes the alerting policy
   (sim-scaled windows from ``default_burn_rules``), not the host CPU.
   Detection delay must shrink monotonically as the regression magnitude
   grows — the defining property of multi-window burn alerting.

2. **Cost** — wall-clock throughput of the two hot loops: observe+evaluate
   on the engine (µs/observation) and span folding on the profiler
   (spans/s over a synthetic cold-serve span stream). These are the numbers
   ``check_bench_regression.py`` gates, with generous tolerance for noisy
   CI runners.

Writes ``BENCH_slo.json``; prints the harness CSV lines.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.obs import CriticalPathProfiler, SloEngine, SloSpec, Tracer, default_burn_rules, trace_id_for
from repro.utils.timing import SimClock

REG_T = 600.0           # regression onset (simulated seconds)
HORIZON = 7200.0        # give the slow (ticket) window room to fire
# injected bad fractions; with objective 0.9 the burn is m/0.1, so the
# smallest magnitude must clear the slow-rule threshold 2.0 (m > 0.2) to
# be detectable at all — 0.25 is the faintest catchable regression here
MAGNITUDES = (0.25, 0.4, 0.6, 1.0)
N_OBS_COST = 50_000
N_SERVES_FOLD = 2_000


def _bad(i: int, magnitude: float) -> bool:
    """Deterministic 'is observation i bad' pattern with density ≈ magnitude
    (Knuth multiplicative hash -> uniform in [0, 1))."""
    return (i * 2654435761 % 1000) / 1000.0 < magnitude


def _engine() -> SloEngine:
    return SloEngine([SloSpec(
        "cold_serve", objective=0.9, threshold=60.0, kind="latency",
        rules=default_burn_rules(1.0 / 60.0),
    )])


def detection_delays() -> dict[str, float]:
    """Simulated seconds from regression onset to the first page for each
    injected bad fraction; -1 when the horizon expires without an alert."""
    out: dict[str, float] = {}
    for m in MAGNITUDES:
        eng = _engine()
        fired_at = -1.0
        i = 0
        t = 0.0
        while t < HORIZON:
            bad = t >= REG_T and _bad(i, m)
            eng.observe("cold_serve", t=t, value=90.0 if bad else 1.0)
            for a in eng.evaluate(t):
                if a.action == "fire" and fired_at < 0:
                    fired_at = a.t - REG_T
            if fired_at >= 0:
                break
            i += 1
            t += 1.0
        out[f"{m:g}"] = fired_at
    return out


def observe_cost_us() -> float:
    """Wall µs per observe()+amortized evaluate() (one evaluate per 30 obs,
    the fleet sim's tick cadence)."""
    eng = _engine()
    t0 = time.perf_counter()
    for i in range(N_OBS_COST):
        eng.observe("cold_serve", t=float(i), value=90.0 if _bad(i, 0.05) else 1.0)
        if i % 30 == 0:
            eng.evaluate(float(i))
    return (time.perf_counter() - t0) / N_OBS_COST * 1e6


def _synthetic_serves(n: int) -> list:
    """A span stream of n complete cold serves (publish->lease->process
    with fetch/deid/deliver children->ack) on a SimClock."""
    clock = SimClock()
    tracer = Tracer(clock)
    for i in range(n):
        key = f"IRB-B/S{i:05d}"
        tid = trace_id_for(key, 1)
        tracer.event("broker.publish", trace_id=tid, key=key, attempt=1)
        clock.advance(0.5)
        tracer.event("broker.lease", trace_id=tid, key=key)
        with tracer.span("worker.process", trace_id=tid, key=key) as proc:
            with tracer.span("worker.fetch", accession=key) as f:
                f.set(nbytes=1 << 20, instances=4, modality="CT")
            with tracer.span("worker.deid", busy_s=0.25):
                pass
            with tracer.span("worker.deliver", datasets=4):
                pass
            proc.set(ok=True, busy_s=0.25)
        tracer.event("broker.ack", trace_id=tid, key=key)
        clock.advance(0.1)
    return tracer.spans()


def fold_throughput() -> tuple[float, int]:
    spans = _synthetic_serves(N_SERVES_FOLD)
    prof = CriticalPathProfiler()
    t0 = time.perf_counter()
    folded = prof.fold(spans)
    wall = time.perf_counter() - t0
    assert folded == N_SERVES_FOLD, f"folded {folded} of {N_SERVES_FOLD}"
    return len(spans) / wall, len(spans)


def run() -> dict:
    delays = detection_delays()
    # policy sanity: bigger regressions must be caught at least as fast,
    # and every magnitude must be caught at all
    vals = [delays[f"{m:g}"] for m in MAGNITUDES]
    assert all(v >= 0 for v in vals), f"undetected regression: {delays}"
    assert all(a >= b for a, b in zip(vals, vals[1:])), (
        f"detection delay not monotone in magnitude: {delays}"
    )
    us_obs = observe_cost_us()
    spans_per_s, n_spans = fold_throughput()
    return {
        "detection_delay_s": delays,
        "us_per_observation": us_obs,
        "fold_spans_per_s": spans_per_s,
        "fold_n_spans": n_spans,
    }


def main(json_path: str | None = "BENCH_slo.json") -> list[str]:
    r = run()
    delays = ";".join(f"m{k}={v:.0f}s" for k, v in r["detection_delay_s"].items())
    lines = [
        f"slo_observe,{r['us_per_observation']:.3f},evaluate_amortized_per_30",
        f"slo_detect,0,{delays}",
        f"slo_fold,{1e6 / r['fold_spans_per_s']:.3f},"
        f"spans_per_s={r['fold_spans_per_s']:.0f}",
    ]
    if json_path:
        payload = {
            "source": "benchmarks/slobench.py",
            "regression_onset_s": REG_T,
            "horizon_s": HORIZON,
            "window_scale": 1.0 / 60.0,
            **r,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
