"""Paper Table 1: de-identification throughput + cost per modality.

The paper ran 8x32-vCPU instances (256 cores) against CT/US/X-Ray requests
(0.68-1.25 GB/s aggregate, $5.68-8.52 per request). This container has one
core, so we measure single-core pipeline throughput on the same modality
mix and model the two deployments:

  * paper fleet   = per-core throughput x 256 cores x 0.85 parallel efficiency
  * TPU v5e scrub = the scrub stage's roofline on one chip (HBM-bound,
    819 GB/s) — the DESIGN.md §3 argument that de-id compute stops being the
    bottleneck after the TPU adaptation.

Cost uses the autoscaler's cost model calibrated to the paper's $/instance-hr.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass
from pathlib import Path

from repro.core import DeidPipeline, PseudonymService, TrustMode, build_request
from repro.dicom.generator import StudyGenerator
from repro.launch import hw
from repro.queueing.autoscaler import AutoscalerConfig

# paper Table 1 rows: (modality, studies, duration_min, aggregate, cost)
PAPER_ROWS = {
    "CT": {"studies": 5000, "bytes": 3.0e12, "duration_min": 45, "agg_gbps": 1.25, "cost": 5.68},
    "US": {"studies": 10000, "bytes": 3.5e12, "duration_min": 60, "agg_gbps": 0.977, "cost": 8.52},
    "DX": {"studies": 100000, "bytes": 2.3e12, "duration_min": 56, "agg_gbps": 0.684, "cost": 7.95},
}

FLEET_CORES = 8 * 32
PARALLEL_EFF = 0.85


@dataclass
class Row:
    modality: str
    measured_mb_s_core: float
    modeled_fleet_gb_s: float
    modeled_duration_min: float
    modeled_cost: float
    paper_gb_s: float
    paper_cost: float
    tpu_scrub_gb_s: float
    tpu_fused_gb_s: float = 0.0     # fused scrub+JLS single-pass roofline
    serial_mb_s_core: float = 0.0   # per-instance oracle path, same studies
    batched_instances: int = 0      # instances that took the fused batch path
    kernel_dispatches: int = 0


def run(n_studies: int = 6, recompress: bool = True, rounds: int = 3) -> list[Row]:
    """Measure the batched (production) and serial (oracle) paths over the
    same studies, interleaved per study — this container's CPU throughput
    drifts over minutes, so two separate sweeps would bias whichever path
    ran first.

    Within a study the two paths ALTERNATE order across rounds: whichever
    path runs second sees the study's pixels already cache-warm from the
    first (a 4-frame study fits in LLC), which used to hand the serial path
    a systematic ~25% advantage on US. Each path gets each position once,
    and the per-study time is the MIN over its rounds — the minimum strips
    scheduler/frequency noise (this box is one contended vCPU), so the
    comparison is warm-vs-warm instead of measuring cache placement."""
    gen = StudyGenerator(7)
    pseudo = PseudonymService("BENCH", TrustMode.POST_IRB, key=b"b" * 32)
    pipe = DeidPipeline(recompress=recompress)
    serial_pipe = DeidPipeline(recompress=recompress, batched=False)
    rows = []
    for modality, paper in PAPER_ROWS.items():
        studies = [
            gen.gen_study(f"T1-{modality}-{i}", modality=modality, n_images=4)
            for i in range(n_studies)
        ]
        nbytes = sum(s.nbytes() for s in studies)
        # warm both pipelines (numpy/jit one-time costs stay out of the timing)
        warm = gen.gen_study(f"T1-{modality}-warm", modality=modality, n_images=1)
        warm_req = build_request(pseudo, warm.accession, warm.mrn)
        pipe.process_study(warm, warm_req)
        serial_pipe.process_study(warm, warm_req)
        stats0 = (pipe.executor.stats.instances, pipe.executor.stats.dispatches)
        best = {"batched": [float("inf")] * n_studies, "serial": [float("inf")] * n_studies}
        n_out = 0
        for r in range(rounds):
            for idx, s in enumerate(studies):
                req = build_request(pseudo, s.accession, s.mrn)
                order = [("batched", pipe), ("serial", serial_pipe)]
                if (idx + r) % 2:
                    order.reverse()
                for tag, p in order:
                    # settle: let the previous measurement's scheduler tail
                    # (pool worker going idle, deferred frees) clear before
                    # starting the next timed section — without this the
                    # second path eats the first one's wind-down (~10-15%
                    # penalty on sub-100ms US studies, one contended vCPU)
                    time.sleep(0.002)
                    t0 = time.perf_counter()
                    outs, _ = p.process_study(s, req)
                    elapsed = time.perf_counter() - t0
                    best[tag][idx] = min(best[tag][idx], elapsed)
                    if tag == "batched" and r == 0:
                        n_out += len(outs)
        dt = sum(best["batched"])
        dt_serial = sum(best["serial"])
        stats1 = (pipe.executor.stats.instances, pipe.executor.stats.dispatches)
        per_core = nbytes / dt
        itemsize = 1 if modality == "US" else 2  # u8 US frames, u16 otherwise
        fleet = per_core * FLEET_CORES * PARALLEL_EFF
        dur_min = paper["bytes"] / fleet / 60
        cfg = AutoscalerConfig()
        # paper deployment: 8 instances for the duration (rate calibrated to
        # Table 1: $5.68 / (8 x 0.75h) ~= $0.85-0.95/instance-hr)
        cost = 8 * (dur_min / 60) * cfg.instance_cost_per_hour
        rows.append(
            Row(
                modality=modality,
                measured_mb_s_core=per_core / 1e6,
                modeled_fleet_gb_s=fleet / 1e9,
                modeled_duration_min=dur_min,
                modeled_cost=cost,
                paper_gb_s=paper["agg_gbps"],
                paper_cost=paper["cost"],
                tpu_scrub_gb_s=hw.HBM_BW / 2 / 1e9,  # read+write each pixel once
                # fused single pass: read dtype + write int32 residuals
                tpu_fused_gb_s=hw.HBM_BW * itemsize / (itemsize + 4) / 1e9,
                serial_mb_s_core=nbytes / dt_serial / 1e6,
                batched_instances=stats1[0] - stats0[0],
                kernel_dispatches=stats1[1] - stats0[1],
            )
        )
    return rows


def main(csv: bool = True, json_path: str | None = "BENCH_fused.json") -> list[str]:
    rows = run()
    lines = []
    for r in rows:
        us_per_mb = 1e6 / max(r.measured_mb_s_core, 1e-9)
        speedup = r.measured_mb_s_core / max(r.serial_mb_s_core, 1e-9)
        lines.append(
            f"table1_{r.modality},{us_per_mb:.1f},"
            f"core_MBps={r.measured_mb_s_core:.1f};serial_MBps={r.serial_mb_s_core:.1f};"
            f"batched_speedup={speedup:.2f};batched_n={r.batched_instances};"
            f"fleet_GBps={r.modeled_fleet_gb_s:.2f};"
            f"paper_GBps={r.paper_gb_s};modeled_cost=${r.modeled_cost:.2f};paper_cost=${r.paper_cost};"
            f"tpu_scrub_GBps={r.tpu_scrub_gb_s:.0f};tpu_fused_GBps={r.tpu_fused_gb_s:.0f}"
        )
    if json_path:
        payload = {
            "source": "benchmarks/table1_throughput.py",
            "rows": [asdict(r) for r in rows],
            "speedup": {
                r.modality: r.measured_mb_s_core / max(r.serial_mb_s_core, 1e-9) for r in rows
            },
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
