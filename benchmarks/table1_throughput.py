"""Paper Table 1: de-identification throughput + cost per modality.

The paper ran 8x32-vCPU instances (256 cores) against CT/US/X-Ray requests
(0.68-1.25 GB/s aggregate, $5.68-8.52 per request). This container has one
core, so we measure single-core pipeline throughput on the same modality
mix and model the two deployments:

  * paper fleet   = per-core throughput x 256 cores x 0.85 parallel efficiency
  * TPU v5e scrub = the scrub stage's roofline on one chip (HBM-bound,
    819 GB/s) — the DESIGN.md §3 argument that de-id compute stops being the
    bottleneck after the TPU adaptation.

Cost uses the autoscaler's cost model calibrated to the paper's $/instance-hr.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core import DeidPipeline, PseudonymService, TrustMode, build_request
from repro.dicom.generator import StudyGenerator
from repro.launch import hw
from repro.queueing.autoscaler import AutoscalerConfig

# paper Table 1 rows: (modality, studies, duration_min, aggregate, cost)
PAPER_ROWS = {
    "CT": {"studies": 5000, "bytes": 3.0e12, "duration_min": 45, "agg_gbps": 1.25, "cost": 5.68},
    "US": {"studies": 10000, "bytes": 3.5e12, "duration_min": 60, "agg_gbps": 0.977, "cost": 8.52},
    "DX": {"studies": 100000, "bytes": 2.3e12, "duration_min": 56, "agg_gbps": 0.684, "cost": 7.95},
}

FLEET_CORES = 8 * 32
PARALLEL_EFF = 0.85


@dataclass
class Row:
    modality: str
    measured_mb_s_core: float
    modeled_fleet_gb_s: float
    modeled_duration_min: float
    modeled_cost: float
    paper_gb_s: float
    paper_cost: float
    tpu_scrub_gb_s: float


def run(n_studies: int = 6, recompress: bool = True) -> list[Row]:
    gen = StudyGenerator(7)
    pseudo = PseudonymService("BENCH", TrustMode.POST_IRB, key=b"b" * 32)
    pipe = DeidPipeline(recompress=recompress)
    rows = []
    for modality, paper in PAPER_ROWS.items():
        studies = [
            gen.gen_study(f"T1-{modality}-{i}", modality=modality, n_images=4)
            for i in range(n_studies)
        ]
        nbytes = sum(s.nbytes() for s in studies)
        t0 = time.perf_counter()
        n_out = 0
        for s in studies:
            req = build_request(pseudo, s.accession, s.mrn)
            outs, manifest = pipe.process_study(s, req)
            n_out += len(outs)
        dt = time.perf_counter() - t0
        per_core = nbytes / dt
        fleet = per_core * FLEET_CORES * PARALLEL_EFF
        dur_min = paper["bytes"] / fleet / 60
        cfg = AutoscalerConfig()
        # paper deployment: 8 instances for the duration (rate calibrated to
        # Table 1: $5.68 / (8 x 0.75h) ~= $0.85-0.95/instance-hr)
        cost = 8 * (dur_min / 60) * cfg.instance_cost_per_hour
        rows.append(
            Row(
                modality=modality,
                measured_mb_s_core=per_core / 1e6,
                modeled_fleet_gb_s=fleet / 1e9,
                modeled_duration_min=dur_min,
                modeled_cost=cost,
                paper_gb_s=paper["agg_gbps"],
                paper_cost=paper["cost"],
                tpu_scrub_gb_s=hw.HBM_BW / 2 / 1e9,  # read+write each pixel once
            )
        )
    return rows


def main(csv: bool = True) -> list[str]:
    lines = []
    for r in run():
        us_per_mb = 1e6 / max(r.measured_mb_s_core, 1e-9)
        lines.append(
            f"table1_{r.modality},{us_per_mb:.1f},"
            f"core_MBps={r.measured_mb_s_core:.1f};fleet_GBps={r.modeled_fleet_gb_s:.2f};"
            f"paper_GBps={r.paper_gb_s};modeled_cost=${r.modeled_cost:.2f};paper_cost=${r.paper_cost};"
            f"tpu_scrub_GBps={r.tpu_scrub_gb_s:.0f}"
        )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
