"""Paper Table 2: ultrasound whitelist coverage (makes, models, resolution
variations) + scrub-script statistics. Reproduces the paper's counts exactly
(the whitelist is the rule base; Table 2 'represents 99% of the manufacturers
in the clinical imaging archive')."""
from __future__ import annotations

import time

from repro.core.rules import emit_scrub_script, parse_scrub_script
from repro.dicom.devices import ULTRASOUND_TABLE2, registry


def run() -> dict:
    reg = registry()
    stats = reg.table2_stats()
    script = emit_scrub_script()
    rules = parse_scrub_script(script)
    return {
        "per_make": stats,
        "paper": ULTRASOUND_TABLE2,
        "total_us_variants": sum(v[1] for v in stats.values()),
        "total_scrub_rules": len(rules),
        "script_lines": script.count("\n"),
    }


def main() -> list[str]:
    t0 = time.perf_counter()
    r = run()
    us = (time.perf_counter() - t0) * 1e6
    lines = []
    mismatches = sum(1 for m in r["paper"] if r["per_make"].get(m) != r["paper"][m])
    lines.append(
        f"table2_whitelist,{us:.0f},makes={len(r['per_make'])};variants={r['total_us_variants']};"
        f"rules={r['total_scrub_rules']};paper_mismatches={mismatches}"
    )
    for make, (models, variants) in sorted(r["per_make"].items()):
        pm, pv = r["paper"][make]
        lines.append(f"table2_{make.replace(' ', '_')},0,models={models}/{pm};variants={variants}/{pv}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
