"""End-to-end driver (the paper's kind: a batched de-identification service).

    PYTHONPATH=src python examples/deid_at_scale.py [--studies 40] [--trace out.jsonl]

Serves a Table-1-style request at simulation scale with everything turned on:
autoscaled worker pool, worker crashes + lease redelivery, stragglers +
speculative re-dispatch, a mid-drain restart resuming from the journal, and
the distributed shard_map scrub farm for the pixel stage. Ends with a
Table-1-style report.
"""
import argparse
import json
from pathlib import Path

from repro.audit import AuditLedger, DisclosureReport
from repro.core import DeidPipeline, TrustMode
from repro.detect import DetectorPolicy
from repro.dicom.generator import StudyGenerator
from repro.distributed import ScrubFarm
from repro.kernels.scrub import ops as scrub_ops
from repro.lake import ResultLake
from repro.queueing import (
    Autoscaler,
    AutoscalerConfig,
    Broker,
    DeidWorker,
    FailureInjector,
    Journal,
    WorkerPool,
)
from repro.queueing.server import DeidService, RequestState
from repro.obs import NULL_TRACER, Redactor, Tracer, export_spans_jsonl, trace_id_for
from repro.storage.object_store import StudyStore
from repro.utils.bytesize import human_bytes
from repro.utils.timing import SimClock


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", type=int, default=40)
    ap.add_argument("--images-per-study", type=int, default=3)
    ap.add_argument("--journal", default="/tmp/deid-at-scale-journal.jsonl")
    ap.add_argument("--trace", metavar="OUT_JSONL", default=None,
                    help="write the run's redacted span JSONL here and print "
                         "a critical-path latency breakdown (DESIGN.md §11)")
    ap.add_argument("--slo", action="store_true",
                    help="run the burn-rate epilogue: a straggler storm in "
                         "the fleet sim fires the cold-serve SLO and the "
                         "health loop scales the pool up — then the same "
                         "seed with the signal off shows the slower "
                         "recovery (DESIGN.md §13)")
    ap.add_argument("--audit", action="store_true",
                    help="thread the tamper-evident audit ledger through the "
                         "run, then verify the hash chain, print the "
                         "accounting-of-disclosures report, and show the "
                         "tamper control failing verify (DESIGN.md §14)")
    args = ap.parse_args()

    # ---------------------------------------------------------------- ingest
    gen = StudyGenerator(seed=2024)
    lake = StudyStore("starr-lake", key=b"lake-at-rest-key")
    mrns = {}
    print(f"ingesting {args.studies} studies into the lake ...")
    for i in range(args.studies):
        problem = "pdf" if i % 11 == 0 else ("secondary_capture" if i % 13 == 0 else None)
        s = gen.gen_study(f"ACC{i:05d}", n_images=args.images_per_study, problem=problem)
        lake.put_study(s.accession, s)
        mrns[s.accession] = s.mrn
    total = lake.store.total_bytes()
    print(f"lake holds {human_bytes(total)} across {args.studies} studies")

    # ---------------------------------------------------------------- submit
    clock = SimClock()
    tracer = Tracer(clock) if args.trace else NULL_TRACER
    # fresh deployment: a journal left by a previous example run would replay
    # its completions and mark this run's submissions DONE at admission
    Path(args.journal).unlink(missing_ok=True)
    ledger = None
    if args.audit:
        ledger_path = Path(f"{args.journal}.audit")
        ledger_path.unlink(missing_ok=True)
        ledger = AuditLedger(ledger_path, clock=clock)
    broker = Broker(clock, visibility_timeout=120, tracer=tracer, ledger=ledger)
    journal = Journal(args.journal)
    result_lake = ResultLake(max_bytes=1 << 30, ledger=ledger)  # de-id cache (§6)
    policy = DetectorPolicy()  # registry-first burned-in-text fallback (§9)
    pipeline = DeidPipeline(
        blank_fn=scrub_ops.blank_fn, lake=result_lake, detector_policy=policy,
        tracer=tracer, ledger=ledger,
    )
    service = DeidService(
        broker, lake, journal, result_lake=result_lake, pipeline=pipeline,
        tracer=tracer, ledger=ledger,
    )
    service.register_study("IRB-70007", TrustMode.POST_IRB)
    service.mark_ineligible("ACC00003")  # research opt-out
    records = service.submit("IRB-70007", list(mrns), mrns)
    queued = sum(1 for r in records if r.state is RequestState.QUEUED)
    print(f"validated: {queued} queued, "
          f"{sum(1 for r in records if r.state is RequestState.REJECTED)} rejected")

    # ------------------------------------------------- distributed scrub farm
    farm = ScrubFarm()
    dest = StudyStore("researcher-bucket")

    injector = FailureInjector(crash_rate=0.08, straggler_rate=0.05, slow_factor=30.0)

    def make_worker(wid: str) -> DeidWorker:
        return DeidWorker(wid, pipeline, lake, dest, journal, tracer=tracer,
                          ledger=ledger)

    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(delivery_window=1800), clock),
        make_worker,
        injector,
        straggler_age=120.0,
    )

    # ------------------------------------------------- drain (with a restart)
    print("draining (chaos on: crashes + stragglers) ...")
    pool.max_ticks = 10  # simulate an operator killing the pool mid-drain
    report1 = pool.drain()
    done_mid = len(journal.completed_keys())
    print(f"  pool killed after {pool.max_ticks} ticks: {done_mid}/{queued} done; restarting ...")

    pool2 = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(delivery_window=1800), clock),
        make_worker,
        injector,
        straggler_age=120.0,
    )
    report2 = pool2.drain()

    # ----------------------------------------------------------------- report
    manifest = journal.merged_manifest("IRB-70007")
    counts = manifest.counts()
    done = service.request_states("IRB-70007")
    wall = clock.now()
    print("\n=== Table-1-style report ===")
    print(f"studies:      {queued} requested, {sum(1 for s in done.values() if s is RequestState.DONE)} delivered")
    print(f"instances:    {counts['anonymized']} anonymized, {counts['scrubbed']} scrubbed, "
          f"{counts['filtered']} filtered, {counts['failed']} failed")
    print(f"bytes:        {human_bytes(total)}")
    print(f"duration:     {wall/60:.1f} min (simulated)")
    print(f"throughput:   {human_bytes(total / max(wall, 1e-9))}/s aggregate")
    print(f"cost:         ${report1.cost_usd + report2.cost_usd:.2f}")
    print(f"reliability:  {report1.crashes + report2.crashes} crashes, "
          f"{report1.redeliveries + report2.redeliveries} redeliveries, "
          f"{report1.speculative + report2.speculative} speculative re-dispatches, "
          f"{report1.deduped + report2.deduped} deduped")
    print(f"farm:         {farm.n} device(s) in the shard_map scrub mesh")
    assert counts["failed"] == 0
    assert len(journal.completed_keys()) == queued

    # ----------------------------------- repeat cohort (the on-demand story)
    # an overlapping cohort replayed against the de-id result lake: warm
    # accessions are served without publishing or dispatching anything (§6)
    cohort = list(mrns)[: max(args.studies // 2, 1)]
    pub0 = broker.total_published
    disp0 = pipeline.executor.stats.dispatches if pipeline.executor else 0
    ticket = service.submit_cohort("IRB-70007", cohort, mrns)
    disp1 = pipeline.executor.stats.dispatches if pipeline.executor else 0
    print(f"\ncohort replay: {len(ticket.hits)} warm / {len(ticket.cold)} cold "
          f"/ {len(ticket.rejected)} rejected of {len(cohort)}; "
          f"+{broker.total_published - pub0} publishes, +{disp1 - disp0} dispatches")
    print(f"result lake:  {result_lake.stats.hits} hits, "
          f"{human_bytes(result_lake.stored_bytes())} stored, "
          f"{result_lake.stats.evictions} evictions")
    assert not ticket.cold and broker.total_published == pub0

    # ---------------------------- query-then-de-identify (the paper's §8 flow)
    # researchers don't hand-build accession lists: they query the metadata
    # catalog and the matching slice is admitted through the planner
    from repro.catalog import And, Eq, Range, StudyCatalog

    catalog = StudyCatalog()
    lake.attach_catalog(catalog)  # backfills every stored study
    service.catalog = catalog
    query = And(Eq("modality", "CT"), Range("study_date", 20150101, 20191231))
    pub0 = broker.total_published
    selection, qticket = service.submit_query("IRB-70007", query, mrns)
    print(f"\nquery:        {selection.query}")
    print(f"selection:    {len(selection.accessions)} studies / "
          f"{selection.total_instances} instances / "
          f"{human_bytes(selection.total_bytes)} "
          f"(pruned {selection.blocks_pruned}/{selection.blocks_pruned + selection.blocks_scanned} blocks)")
    print(f"admission:    {len(qticket.hits)} warm / {len(qticket.cold)} cold / "
          f"{len(qticket.rejected)} rejected; "
          f"+{broker.total_published - pub0} publishes; "
          f"selection digest {qticket.selection_digest[:16]}")
    # everything CT was de-identified above -> the query serves fully warm
    assert not qticket.cold and broker.total_published == pub0

    # ------------------- unknown-device cohort (the §9 detector-fallback flow)
    # novel (manufacturer, model) variants have no scrub rule: the registry
    # miss is counted, the text-band detector proposes bands, and the blanked
    # cohort is served — then a policy edit structurally invalidates it all
    n_unknown = max(args.studies // 8, 2)
    unknown_cohort = []
    for i in range(n_unknown):
        acc = f"ACCU{i:04d}"
        s = gen.gen_study(acc, n_images=args.images_per_study,
                          device=gen.unknown_device(acc, "CT"))
        lake.put_study(acc, s)
        mrns[acc] = s.mrn
        unknown_cohort.append(acc)
    uticket = service.submit_cohort("IRB-70007", unknown_cohort, mrns)
    pool4 = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(delivery_window=1800), clock),
        make_worker,
    )
    pool4.drain()
    service.planner.resolve()
    st = pipeline.scrub.detect_stats
    print(f"\nunknown devices: {len(uticket.cold)} cold studies from novel "
          f"(make, model) variants; {st.unknown_lookups} registry misses "
          f"counted, {st.detector_runs} detector scans, "
          f"{st.detected} with text bands blanked")
    assert uticket.done() and not uticket.failed and st.detected > 0
    replay = service.submit_cohort("IRB-70007", unknown_cohort, mrns)
    assert not replay.cold, "same policy must serve the cohort warm"

    # a policy edit (stricter row threshold) changes the ruleset fingerprint:
    # every cached result minted under the old detector is structurally
    # invalid. The journal is deliberately ruleset-agnostic (it records
    # exactly-once *delivery*), so the edit rolls out as a redeploy — fresh
    # journal and broker against the same source lake and result lake — and
    # the very same cohort that just served warm now serves cold.
    edited = DeidPipeline(
        blank_fn=scrub_ops.blank_fn, lake=result_lake,
        detector_policy=DetectorPolicy(row_frac=0.05), ledger=ledger,
    )
    if ledger is not None:
        ledger.append("policy_edit", action="redeploy",
                      ruleset=edited.ruleset_fingerprint().digest,
                      detector_sha=edited.scrub.policy.fingerprint_identity)
    broker2 = Broker(clock, visibility_timeout=120, ledger=ledger)
    journal2_path = args.journal + ".edited"
    Path(journal2_path).unlink(missing_ok=True)
    journal2 = Journal(journal2_path)
    service2 = DeidService(
        broker2, lake, journal2, result_lake=result_lake, pipeline=edited,
        ledger=ledger,
    )
    service2.register_study("IRB-70007", TrustMode.POST_IRB)
    recold = service2.submit_cohort("IRB-70007", unknown_cohort, mrns)
    print(f"policy edit:  fingerprint {pipeline.ruleset_fingerprint().digest[:12]} "
          f"-> {edited.ruleset_fingerprint().digest[:12]}; "
          f"{len(replay.hits)} warm before, {len(recold.cold)} cold after redeploy")
    assert len(recold.cold) == len(unknown_cohort) and not recold.hits
    pool5 = WorkerPool(
        broker2,
        Autoscaler(broker2, AutoscalerConfig(delivery_window=1800), clock),
        lambda wid: DeidWorker(wid, edited, lake, dest, journal2,
                               ledger=ledger),
    )
    pool5.drain()
    service2.planner.resolve()
    assert recold.done() and not recold.failed

    # ------------- source mutation mid-cohort (the §10 incremental re-deid)
    # the PACS re-acquires one already-delivered study: the planner's etag
    # check marks exactly that accession stale, its cached result is evicted,
    # and ONE incremental re-deid runs — every other study still serves warm
    victim = unknown_cohort[0]
    reacquired = gen.gen_study(victim, n_images=args.images_per_study,
                               device=gen.unknown_device(victim, "CT"))
    reacquired.mrn = mrns[victim]  # same patient, new bytes
    lake.put_study(victim, reacquired)
    super0 = journal2.supersessions
    mut_ticket = service2.submit_cohort("IRB-70007", unknown_cohort, mrns)
    assert service2.planner.stats.stale_refreshes >= 1
    assert victim in mut_ticket.cold or victim in mut_ticket.pending
    assert len(mut_ticket.hits) == len(unknown_cohort) - 1  # rest stay warm
    mworkers = []

    def make_edited_worker(wid: str) -> DeidWorker:
        w = DeidWorker(wid, edited, lake, dest, journal2, ledger=ledger)
        mworkers.append(w)
        return w

    pool6 = WorkerPool(
        broker2,
        Autoscaler(broker2, AutoscalerConfig(delivery_window=1800), clock),
        make_edited_worker,
    )
    pool6.drain()
    service2.planner.resolve()
    evicted = sum(w.evicted_stale for w in mworkers)
    re_deids = sum(w.processed for w in mworkers)
    print(f"\nsource mutated: {victim} re-acquired mid-cohort; "
          f"{len(mut_ticket.hits)} warm / {len(mut_ticket.cold)} cold; "
          f"{evicted} stale cache entry evicted, "
          f"{journal2.supersessions - super0} supersession, "
          f"{re_deids} incremental re-deid (amplification "
          f"{re_deids}/{1} = {re_deids:.1f})")
    assert mut_ticket.done() and not mut_ticket.failed
    assert re_deids == 1, "exactly one re-deid: incrementality, not a rebuild"
    assert evicted == 1 and journal2.supersessions - super0 == 1
    assert journal2.etag_for(f"IRB-70007/{victim}") == lake.study_etag(victim)

    # -------------------------------------------- trace epilogue (§11)
    # Only the first deployment is traced: trace ids are (key, attempt)
    # derived, so tracing the post-edit redeploy of the same cohort through
    # the same tracer would alias its trace ids onto the first drain's.
    if args.trace:
        spans = tracer.spans()
        Path(args.trace).write_text(export_spans_jsonl(spans, Redactor()))
        # Reconstruct each delivered item's critical path from the broker
        # event chain. Under SimClock a span's wall time inside one pool tick
        # is zero — latency lives *between* events (queue wait, redelivery
        # backoff) and in the worker's simulated busy_s, not inside spans.
        publishes = {s.trace_id: s for s in spans if s.name == "broker.publish"}
        entries = {}  # final attempt's queue-entry event (publish/redeliver)
        for s in spans:
            if s.name in ("broker.publish", "broker.redeliver"):
                entries.setdefault(s.trace_id, s)
        leases = {s.trace_id: s for s in spans if s.name == "broker.lease"}
        procs = {s.trace_id: s for s in spans if s.name == "worker.process"}
        chains = []
        for ack in (s for s in spans if s.name == "broker.ack"):
            key, attempts = ack.attrs["key"], ack.attrs["deliveries"]
            first = publishes.get(trace_id_for(key, 1))
            lease, proc = leases.get(ack.trace_id), procs.get(ack.trace_id)
            if first is None or lease is None or proc is None:
                continue  # speculative clone or fenced duplicate
            entry = entries.get(ack.trace_id, first)
            chains.append({
                "key": key,
                "attempts": attempts,
                "retry_s": entry.t0 - first.t0,
                "queue_s": lease.t0 - entry.t0,
                "busy_s": proc.attrs.get("busy_s", 0.0),
                "e2e_s": ack.t1 - first.t0,
            })
        chains.sort(key=lambda c: -c["e2e_s"])
        print(f"\n=== critical path: slowest of {len(chains)} delivered items "
              f"(simulated seconds) ===")
        print(f"{'key':<24}{'attempts':>9}{'retry':>9}{'queued':>9}"
              f"{'busy':>9}{'e2e':>9}")
        for c in chains[:5]:
            print(f"{c['key']:<24}{c['attempts']:>9}{c['retry_s']:>9.1f}"
                  f"{c['queue_s']:>9.1f}{c['busy_s']:>9.1f}{c['e2e_s']:>9.1f}")
        by_name: dict = {}
        for s in spans:
            by_name[s.name] = by_name.get(s.name, 0) + 1
        names = ", ".join(f"{n}×{by_name[n]}"
                          for n in sorted(by_name, key=by_name.get, reverse=True))
        print(f"\nspans:        {len(spans)} across {len(tracer.traces())} traces ({names})")
        print(f"trace:        {args.trace} (redacted JSONL), "
              f"digest {tracer.digest()[:16]}")

    # ------------------------------------------ SLO + burn-rate epilogue (§13)
    # A self-contained fleet-sim scenario: every worker straggles 20x from
    # t=0, so the cold-serve latency SLO burns while the generous delivery
    # window keeps the backlog-derived autoscaler target small. With the
    # burn signal wired into the autoscaler the pool scales past what the
    # backlog justifies and the alert resolves sooner; the same seed with
    # the signal off is the negative control.
    if args.slo:
        import tempfile

        from repro.sim import ChaosEvent, ChaosSchedule, CohortArrival, FleetConfig, FleetSim

        def storm(slo_autoscale: bool, tag: str):
            n = 10
            corpus = [f"SIM{i:04d}" for i in range(n)]
            cfg = FleetConfig(
                seed=3, n_studies=n, images_per_study=2,
                delivery_window=3600.0, worker_throughput=2e6,
                max_instances=8, slo_cold_threshold=20.0,
                slo_autoscale=slo_autoscale,
            )
            traffic = [CohortArrival(t=0.0, study_id="IRB-B",
                                     accessions=tuple(corpus))]
            chaos = ChaosSchedule([ChaosEvent(
                t=0.0, kind="set_straggler",
                payload={"rate": 1.0, "slow_factor": 20.0})])
            with tempfile.TemporaryDirectory() as td:
                sim = FleetSim(cfg, traffic, Path(td) / f"{tag}.jsonl", chaos)
                rep = sim.run()
            return sim, rep

        print("\n=== burn-rate -> autoscaler closed loop (DESIGN.md §13) ===")
        results = {}
        for tag in ("on", "off"):
            sim, rep = storm(slo_autoscale=(tag == "on"), tag=tag)
            results[tag] = rep
            scale_ups = [e for e in sim.pool.autoscaler.events
                         if e.reason == "burn-scale-up"]
            alerts = [f"{a.action}@{a.t:.0f}s {a.slo}({a.severity})"
                      for a in sim.slo_engine.alerts]
            print(f"signal {tag:>3}: drained in {rep.metrics['sim_minutes']:.2f} "
                  f"sim-min, worst latency {rep.metrics['max_latency_s']:.1f}s; "
                  f"alerts [{', '.join(alerts) or 'none'}]; "
                  f"{len(scale_ups)} burn-scale-up event(s)")
            print(f"           health: {sim.service.health_report().summary()}")
        assert (results["on"].metrics["sim_minutes"]
                < results["off"].metrics["sim_minutes"])
        print("burn signal bought "
              f"{results['off'].metrics['sim_minutes'] - results['on'].metrics['sim_minutes']:.2f} "
              "sim-min of recovery time on the same seed")

    # --------------------- audit: verify chain + disclosures (§14)
    # Everything above rode the hash-chained ledger: every fetch, deid run,
    # lake byte in/out, delivery, and the policy redeploy. Verify the chain,
    # fold it into the accounting-of-disclosures report, then show the
    # tamper control: one flipped byte and verify() names the damaged line.
    if args.audit:
        ledger.flush()
        problems = ledger.verify()
        assert problems == [], problems
        kinds = ", ".join(f"{k}×{v}" for k, v in sorted(ledger.kind_counts().items()))
        print(f"\n=== tamper-evident audit ledger (DESIGN.md §14) ===")
        print(f"chain:        {len(ledger)} records verify clean ({kinds})")
        print(f"              head {ledger.head()[:16]}, digest {ledger.digest()[:16]}")
        print(DisclosureReport.from_ledger(ledger).summary())
        # the tamper control, on a scratch copy of the ledger file
        import shutil
        tampered_path = Path(f"{args.journal}.audit.tampered")
        shutil.copy(ledger.path, tampered_path)
        raw = bytearray(tampered_path.read_bytes())
        flip_at = len(raw) // 2
        raw[flip_at] = raw[flip_at] ^ 0x01
        tampered_path.write_bytes(bytes(raw))
        tampered = AuditLedger(tampered_path)
        tamper_problems = tampered.verify()
        tampered.close()
        tampered_path.unlink()
        assert tamper_problems, "one flipped byte must fail verification"
        print(f"tamper check: flipped 1 byte mid-file -> verify() fails: "
              f"{tamper_problems[0]}")
        ledger.close()


if __name__ == "__main__":
    main()
