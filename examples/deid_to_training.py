"""Platform integration: de-identified imaging -> VLM training batches.

    PYTHONPATH=src python examples/deid_to_training.py

This is the STARR story end to end (paper Background + Future Work): the
pipeline de-identifies studies into the researcher bucket, and a downstream
imaging-AI job consumes the *scrubbed* pixels — via the frozen-vision-tower
stub — to train the llava-family backbone. The PHI boundary is explicit:
the training side only ever touches post-scrub datasets.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeidPipeline, PseudonymService, TrustMode, build_request
from repro.dicom.generator import StudyGenerator
from repro.config.registry import get_arch
from repro.kernels.phi_detect.ops import audit_dataset
from repro.models import build_model
from repro.training import cosine_schedule, make_train_step, train_state_init
from repro.training.data import DeidImagePipeline


def main() -> None:
    # --- de-identify a small US+CT corpus (US = heaviest burn-in, paper Table 2)
    gen = StudyGenerator(11)
    pseudo = PseudonymService("IRB-IMG", TrustMode.POST_IRB, key=b"i" * 32)
    pipe = DeidPipeline(recompress=False)
    delivered = []
    for i in range(6):
        s = gen.gen_study(f"IMG{i:03d}", modality="US" if i % 2 else "CT", n_images=2)
        outs, manifest = pipe.process_study(s, build_request(pseudo, s.accession, s.mrn))
        delivered.extend(outs)
    print(f"de-identified corpus: {len(delivered)} instances")

    # --- PHI audit gate (Future Work: ML detection) before training sees pixels
    # audit_dataset thresholds at the stored bit depth (12-bit CT in u16 words)
    flagged = [d for d in delivered if audit_dataset(d)]
    assert not flagged, "post-scrub corpus must pass the burned-in-text audit"
    print("phi_detect audit: clean")

    # --- build VLM batches from scrubbed pixels
    cfg = get_arch("llava-next-34b").reduced()
    model = build_model(cfg)
    data = DeidImagePipeline(cfg, seed=3)
    batch_np = data.batch_from_datasets(delivered, batch=4, seq=128, rng=np.random.default_rng(0))
    batch = jax.tree.map(jnp.asarray, batch_np)

    # --- a few train steps on the backbone
    state = train_state_init(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, cosine_schedule(1e-3, 5, 100)))
    first = None
    for step in range(20):
        state, metrics = step_fn(state, batch)
        if first is None:
            first = float(metrics["loss"])
    print(f"VLM backbone loss: {first:.3f} -> {float(metrics['loss']):.3f} over 20 steps")
    assert float(metrics["loss"]) < first
    print("de-id -> training integration OK")


if __name__ == "__main__":
    main()
