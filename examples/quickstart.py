"""Quickstart: de-identify one imaging study end to end.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full request lifecycle on a tiny synthetic study:
register an IRB study -> validate + pseudonymize -> queue -> drain with one
worker -> inspect the de-identified output and the manifest.
"""
import json

from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


def main() -> None:
    # --- the data lake holds identified studies (paper: encrypted object store)
    gen = StudyGenerator(seed=42)
    lake = StudyStore("starr-lake", key=b"lake-at-rest-key")
    study = gen.gen_study("ACC-2024-001", modality="CT", n_images=3, problem="pdf")
    lake.put_study(study.accession, study)
    print(f"lake: {study.accession} ({len(study.datasets)} instances, "
          f"{study.nbytes()/1e6:.1f} MB, patient {study.patient_name})")

    # --- central server: register the research study, submit the request
    clock = SimClock()
    broker = Broker(clock)
    journal = Journal("/tmp/quickstart-journal.jsonl")
    service = DeidService(broker, lake, journal)
    service.register_study("IRB-60001", TrustMode.POST_IRB)
    records = service.submit("IRB-60001", [study.accession], {study.accession: study.mrn})
    print(f"submitted: {records[0].accession} -> {records[0].anon_accession} ({records[0].state.value})")

    # --- autoscaled worker pool drains the queue
    dest = StudyStore("researcher-bucket")
    pipeline = DeidPipeline()
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(wid, pipeline, lake, dest, journal),
    )
    report = pool.drain()
    print(f"drained: {report.processed} studies, cost ${report.cost_usd:.4f}")

    # --- researcher sees de-identified instances + manifest, never PHI
    request_id = f"IRB-60001/{records[0].anon_accession}"
    outputs = list(dest.outputs(request_id))
    manifest = journal.merged_manifest("IRB-60001")
    print(f"delivered {len(outputs)} instances; manifest counts: {manifest.counts()}")
    ds = outputs[0]
    print(f"  PatientID={ds['PatientID']} AccessionNumber={ds['AccessionNumber']} "
          f"StudyDate={ds['StudyDate']} (original {study.study_date})")
    assert all(study.mrn not in json.dumps(e.to_dict()) for e in manifest.entries)
    print("PHI-free manifest verified. Done.")


if __name__ == "__main__":
    main()
