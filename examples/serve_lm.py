"""Serve a small LM with batched requests.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-0.5b]
"""
import argparse

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    result = serve.main(["--arch", args.arch, "--requests", str(args.requests), "--max-new", "12"])
    print(f"served {result['requests']} requests / {result['tokens']} tokens in {result['seconds']:.2f}s")
    assert result["requests"] == args.requests


if __name__ == "__main__":
    main()
