"""Train a ~20M-param reduced LM for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-0.5b] [--steps 200]

Uses the real production train loop (repro.launch.train): AdamW + cosine
schedule, checkpoint every 50 steps, resumable with --resume.
"""
import argparse

from repro.launch import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--lr", "1e-3",
        "--ckpt-every", "50",
    ]
    if args.resume:
        argv.append("--resume")
    result = train.main(argv)
    print(f"final loss: {result['final_loss']:.4f} after {result['steps']} steps")
    # uniform baseline is ln(512) ~= 6.24; the default 200 steps lands well below
    threshold = 6.2 if args.steps < 150 else 6.0
    assert result["final_loss"] < threshold, "training should beat the uniform baseline"


if __name__ == "__main__":
    main()
