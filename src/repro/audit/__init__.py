"""Tamper-evident audit plane: hash-chained PHI-access ledger, per-delivery
provenance, and the accounting-of-disclosures report (DESIGN.md §14)."""
from repro.audit.ledger import GENESIS_SHA, AuditLedger, NULL_LEDGER, NullLedger
from repro.audit.records import (
    DEAD_LETTER,
    DEID_EXECUTE,
    DELIVERY,
    DETECTOR_DECISION,
    DURABLE_KINDS,
    INGEST_APPLY,
    LAKE_EVICT,
    LAKE_HIT,
    LAKE_WRITE,
    POLICY_EDIT,
    PROVENANCE,
    RECORD_KINDS,
    SOURCE_FETCH,
    TELEMETRY_EXPORT,
    canonical_json,
    record_sha,
)
from repro.audit.report import DisclosureReport, export_ledger_jsonl

__all__ = [
    "AuditLedger",
    "NullLedger",
    "NULL_LEDGER",
    "GENESIS_SHA",
    "DisclosureReport",
    "export_ledger_jsonl",
    "record_sha",
    "canonical_json",
    "RECORD_KINDS",
    "DURABLE_KINDS",
    "SOURCE_FETCH",
    "DEID_EXECUTE",
    "DETECTOR_DECISION",
    "LAKE_WRITE",
    "LAKE_HIT",
    "LAKE_EVICT",
    "DELIVERY",
    "PROVENANCE",
    "DEAD_LETTER",
    "INGEST_APPLY",
    "POLICY_EDIT",
    "TELEMETRY_EXPORT",
]
