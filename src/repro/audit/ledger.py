"""Append-only, hash-chained audit ledger (DESIGN.md §14).

Every record carries the ``sha`` of its predecessor (``prev_sha``), so the
file is a hash chain rooted at :data:`GENESIS_SHA`. :meth:`AuditLedger.verify`
re-reads the *raw disk bytes* and recomputes the chain; any mutation flips a
record sha, and any insertion, deletion-in-the-middle, or reordering breaks a
``prev_sha`` link. The one attack verify() alone cannot see is **truncation**
— a chopped file is a valid shorter chain — which is why the
``AuditCompleteness`` sim checker cross-checks record counts against the
processing journal and event log (every acked delivery must still have its
provenance record).

Durability is tiered (see :data:`~repro.audit.records.DURABLE_KINDS`):
disclosure-accounting facts (delivery, provenance, policy edits, ingest
applies) are fsynced at append; high-rate per-instance records (lake hits,
detector decisions) ride the OS buffer and become durable at the next
durable append / :meth:`AuditLedger.flush` / :meth:`AuditLedger.close`.
A crash therefore loses at most a tail of non-durable records; replay repairs
a torn tail exactly like the journal (shared ``repro.utils.wal`` helper).

:data:`NULL_LEDGER` is the zero-overhead null object (the ``NULL_TRACER``
pattern): every emit site calls it unconditionally, and the fleet sim proves
a NULL_LEDGER run is bit-identical (event-log digest, metrics, trace digest)
to no ledger at all.
"""
from __future__ import annotations

import hashlib
import os
from contextlib import contextmanager
from pathlib import Path
from typing import Dict, Iterator, List, Optional

from repro.utils.wal import replay_jsonl

from repro.audit.records import (
    DURABLE_KINDS,
    RECORD_KINDS,
    STRUCTURAL_KEYS,
    canonical_json,
    record_sha,
)

GENESIS_SHA = hashlib.sha256(b"audit|genesis").hexdigest()


class AuditLedger:
    """Hash-chained append-only JSONL ledger of PHI-touching actions."""

    enabled = True

    def __init__(self, path: str | os.PathLike, clock=None) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.clock = clock
        self.torn_tail = 0
        self.corrupt_lines = 0
        self._records: List[dict] = []
        self._head = GENESIS_SHA
        self._dirty = False
        self._batch_depth = 0
        self._pending_sync = False
        self.syncs = 0  # fsync count — the unit auditbench prices
        if self.path.exists():
            replay = replay_jsonl(self.path)
            self.torn_tail += replay.torn_tail
            self.corrupt_lines += replay.corrupt_lines
            # Trust-on-load: replay adopts the recovered chain as-is; verify()
            # is the integrity check, replay is the availability path.
            for rec in replay.records:
                self._records.append(rec)
                self._head = rec.get("sha", self._head)
        self._fh = open(self.path, "a", encoding="utf-8")

    # ----------------------------------------------------------------- write
    def append(self, kind: str, **fields) -> dict:
        """Append one typed record, chained to the current head."""
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown audit record kind: {kind!r}")
        clash = STRUCTURAL_KEYS.intersection(fields)
        if clash:
            raise ValueError(f"payload collides with structural keys: {sorted(clash)}")
        rec = {
            "kind": kind,
            "seq": len(self._records) + 1,
            "t": float(self.clock.now()) if self.clock is not None else 0.0,
            "prev_sha": self._head,
            **fields,
        }
        rec["sha"] = record_sha(rec)
        self._records.append(rec)
        self._head = rec["sha"]
        # Write the canonical form so a disk re-parse recomputes identically.
        self._fh.write(canonical_json(rec) + "\n")
        if kind in DURABLE_KINDS:
            if self._batch_depth:
                # group commit: the enclosing batch() fsyncs once at exit
                self._dirty = self._pending_sync = True
            else:
                self._sync()
        else:
            self._dirty = True
        return rec

    def _sync(self) -> None:
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._dirty = self._pending_sync = False
        self.syncs += 1

    @contextmanager
    def batch(self) -> Iterator["AuditLedger"]:
        """Group-commit scope: durable appends inside the ``with`` defer
        their fsync to ONE sync at exit. Emit sites that write several
        adjacent durable records (the worker's delivery+provenance pair, a
        cohort admission's warm hits) pay one fsync for the group; a crash
        inside the batch loses a suffix of the batch, never an interior
        record — the chain stays a valid prefix either way."""
        self._batch_depth += 1
        try:
            yield self
        finally:
            self._batch_depth -= 1
            if self._batch_depth == 0 and self._pending_sync:
                self._sync()

    def flush(self) -> None:
        if self._dirty and not self._fh.closed:
            self._sync()

    def close(self) -> None:
        self.flush()
        self._fh.close()

    # ------------------------------------------------------------------ read
    def records(self, kind: Optional[str] = None) -> List[dict]:
        if kind is None:
            return list(self._records)
        return [r for r in self._records if r.get("kind") == kind]

    def kind_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self._records:
            k = r.get("kind", "?")
            out[k] = out.get(k, 0) + 1
        return out

    def head(self) -> str:
        return self._head

    def __len__(self) -> int:
        return len(self._records)

    def digest(self) -> str:
        """Commits to both chain head and length — two same-seed sim runs
        must produce bit-identical digests (the determinism contract)."""
        return hashlib.sha256(f"audit|{len(self._records)}|{self._head}".encode()).hexdigest()

    # ---------------------------------------------------------------- verify
    def verify(self) -> List[str]:
        """Recompute the hash chain from the raw disk bytes.

        Returns a list of human-readable problems; ``[]`` means the on-disk
        ledger is an intact chain that matches the in-memory head. Detects
        any mutation (sha mismatch), insertion/deletion/reordering (prev_sha
        or seq break). Truncation alone yields a valid shorter chain — the
        head comparison catches it while this process is alive, and the
        journal cross-checks in ``AuditCompleteness`` bound it after a crash.
        """
        import json

        self.flush()
        problems: List[str] = []
        try:
            with open(self.path, "rb") as fh:
                raw = fh.read()
        except FileNotFoundError:
            return [f"ledger file missing: {self.path}"]
        prev = GENESIS_SHA
        n = 0
        for i, line in enumerate(raw.split(b"\n"), start=1):
            stripped = line.strip()
            if not stripped:
                continue
            try:
                rec = json.loads(stripped)
                if not isinstance(rec, dict):
                    raise ValueError("not a record")
            except ValueError:
                problems.append(f"line {i}: unparseable record")
                prev = None  # chain is broken from here on
                continue
            n += 1
            if rec.get("kind") not in RECORD_KINDS:
                problems.append(f"line {i}: unknown kind {rec.get('kind')!r}")
            if rec.get("seq") != n:
                problems.append(f"line {i}: seq {rec.get('seq')} != expected {n}")
            if prev is not None and rec.get("prev_sha") != prev:
                problems.append(f"line {i}: prev_sha break (chain reordered or edited)")
            want = record_sha(rec)
            if rec.get("sha") != want:
                problems.append(f"line {i}: sha mismatch (record mutated)")
                prev = rec.get("sha")  # follow the claimed chain to localize damage
            else:
                prev = rec["sha"]
        if prev is not None and prev != self._head:
            problems.append(
                f"disk head {str(prev)[:12]} != live head {self._head[:12]} "
                "(file truncated or diverged from this process)"
            )
        return problems


class NullLedger:
    """No-op ledger: no clock reads, no I/O, no allocation on append."""

    enabled = False
    path = None
    clock = None
    torn_tail = 0
    corrupt_lines = 0

    syncs = 0

    def append(self, kind: str, **fields) -> None:
        return None

    @contextmanager
    def batch(self) -> Iterator["NullLedger"]:
        yield self

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def records(self, kind: Optional[str] = None) -> List[dict]:
        return []

    def kind_counts(self) -> Dict[str, int]:
        return {}

    def head(self) -> str:
        return GENESIS_SHA

    def __len__(self) -> int:
        return 0

    def digest(self) -> str:
        # same value an empty AuditLedger reports
        return hashlib.sha256(f"audit|0|{GENESIS_SHA}".encode()).hexdigest()

    def verify(self) -> List[str]:
        return []


NULL_LEDGER = NullLedger()
