"""Audit record taxonomy + canonical hashing (DESIGN.md §14).

Every PHI-touching action in the de-id plane emits one typed record into the
:class:`~repro.audit.ledger.AuditLedger`. The record *kinds* below are the
closed vocabulary; the ledger rejects anything else so a typo can never
silently open an unaccounted category.

Hashing convention: a record's ``sha`` is the SHA-256 of its **canonical
JSON** (floats rounded to 9 places, sorted keys, compact separators — the
same convention the tracer and sim event log use for their digests) computed
over every field *except* ``sha`` itself. The ledger writes the canonical
form to disk, so re-parsing a line and recomputing its sha is bit-stable.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict

# ----------------------------------------------------------------- taxonomy
SOURCE_FETCH = "source_fetch"            # worker read PHI bytes from the source
DEID_EXECUTE = "deid_execute"            # pipeline ran the de-id kernels on a study
DETECTOR_DECISION = "detector_decision"  # burned-in-PHI detector ran on an instance
LAKE_WRITE = "lake_write"                # de-identified bytes written into the lake
LAKE_HIT = "lake_hit"                    # de-identified bytes served out of the lake
LAKE_EVICT = "lake_evict"                # lake entry dropped (lru / invalidate / lost)
DELIVERY = "delivery"                    # a ticket was delivered to its destination
PROVENANCE = "provenance"                # lineage record for one delivery (see ledger doc)
DEAD_LETTER = "dead_letter"              # a ticket exhausted redelivery and was parked
INGEST_APPLY = "ingest_apply"            # a source mutation reached a terminal outcome
POLICY_EDIT = "policy_edit"              # ruleset / detector-policy deploy or edit
TELEMETRY_EXPORT = "telemetry_export"    # spans/metrics left the system boundary

RECORD_KINDS = frozenset(
    {
        SOURCE_FETCH,
        DEID_EXECUTE,
        DETECTOR_DECISION,
        LAKE_WRITE,
        LAKE_HIT,
        LAKE_EVICT,
        DELIVERY,
        PROVENANCE,
        DEAD_LETTER,
        INGEST_APPLY,
        POLICY_EDIT,
        TELEMETRY_EXPORT,
    }
)

# Kinds fsynced at append time. Everything else is python-buffered and made
# durable at the next durable append / explicit flush / close — a crash can
# lose a *tail* of non-durable records (bounded by the journal cross-check in
# the AuditCompleteness checker) but never a delivery/provenance/policy fact.
DURABLE_KINDS = frozenset({DELIVERY, PROVENANCE, POLICY_EDIT, INGEST_APPLY})

# Field names owned by the chain itself; payloads may not collide with them.
STRUCTURAL_KEYS = frozenset({"kind", "seq", "t", "prev_sha", "sha"})


def canonical(obj):
    """Round floats (9 places) so shas survive re-serialization."""
    if isinstance(obj, float):
        return round(obj, 9)
    if isinstance(obj, dict):
        return {k: canonical(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [canonical(v) for v in obj]
    return obj


def canonical_json(obj: Dict[str, object]) -> str:
    return json.dumps(canonical(obj), sort_keys=True, separators=(",", ":"))


def record_sha(rec: Dict[str, object]) -> str:
    """SHA-256 over the canonical JSON of ``rec`` minus its ``sha`` field."""
    body = {k: v for k, v in rec.items() if k != "sha"}
    return hashlib.sha256(canonical_json(body).encode()).hexdigest()
