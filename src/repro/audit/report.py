"""Accounting of disclosures: aggregate the ledger into a PHI-safe report.

HIPAA's "accounting of disclosures" shape: *who received which bytes, derived
from which source version, under which ruleset/detector*. The
:class:`DisclosureReport` folds the ledger's ``provenance`` records into
per-project accounting plus lake/dead-letter totals; every exported line
crosses the existing telemetry :class:`~repro.obs.export.Redactor`, so the
report inherits the same allowlist PHI boundary as spans and metrics.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List

from repro.audit.records import (
    DEAD_LETTER,
    DEID_EXECUTE,
    LAKE_EVICT,
    LAKE_HIT,
    LAKE_WRITE,
    PROVENANCE,
    canonical,
)
from repro.obs.export import Redactor


@dataclass
class ProjectAccounting:
    """Disclosure rollup for one research project."""

    project: str
    deliveries: int = 0
    instances: int = 0
    nbytes: int = 0
    cold: int = 0          # deliveries that ran the kernels
    warm: int = 0          # deliveries served from the result lake
    journal: int = 0       # deliveries answered from the completion journal
    accessions: set = field(default_factory=set)
    rulesets: set = field(default_factory=set)
    first_t: float = 0.0
    last_t: float = 0.0

    def to_dict(self) -> dict:
        return {
            "project": self.project,
            "deliveries": self.deliveries,
            "instances": self.instances,
            "nbytes": self.nbytes,
            "cold": self.cold,
            "warm": self.warm,
            "journal": self.journal,
            "accessions": sorted(self.accessions),
            "rulesets": sorted(self.rulesets),
            "first_t": self.first_t,
            "last_t": self.last_t,
        }


@dataclass
class DisclosureReport:
    projects: Dict[str, ProjectAccounting] = field(default_factory=dict)
    deid_executions: int = 0
    lake_writes: int = 0
    lake_hits: int = 0
    lake_evictions: int = 0
    lake_bytes_in: int = 0
    lake_bytes_out: int = 0
    dead_lettered: int = 0
    ledger_records: int = 0
    ledger_digest: str = ""

    @classmethod
    def from_ledger(cls, ledger) -> "DisclosureReport":
        rep = cls(ledger_records=len(ledger), ledger_digest=ledger.digest())
        for rec in ledger.records():
            kind = rec.get("kind")
            if kind == PROVENANCE:
                proj = str(rec.get("project", ""))
                acct = rep.projects.get(proj)
                if acct is None:
                    acct = rep.projects[proj] = ProjectAccounting(
                        project=proj, first_t=rec.get("t", 0.0)
                    )
                acct.deliveries += 1
                acct.instances += int(rec.get("instances", 0))
                acct.nbytes += int(rec.get("nbytes", 0))
                temp = rec.get("temp", "cold")
                if temp == "warm":
                    acct.warm += 1
                elif temp == "journal":
                    acct.journal += 1
                else:
                    acct.cold += 1
                acct.accessions.add(str(rec.get("accession", "")))
                if rec.get("ruleset"):
                    acct.rulesets.add(str(rec["ruleset"]))
                acct.last_t = rec.get("t", acct.last_t)
            elif kind == DEID_EXECUTE:
                rep.deid_executions += 1
            elif kind == LAKE_WRITE:
                rep.lake_writes += 1
                rep.lake_bytes_in += int(rec.get("nbytes", 0))
            elif kind == LAKE_HIT:
                rep.lake_hits += 1
                rep.lake_bytes_out += int(rec.get("nbytes", 0))
            elif kind == LAKE_EVICT:
                rep.lake_evictions += 1
            elif kind == DEAD_LETTER:
                rep.dead_lettered += 1
        return rep

    def to_dict(self) -> dict:
        return {
            "projects": {p: a.to_dict() for p, a in sorted(self.projects.items())},
            "deid_executions": self.deid_executions,
            "lake_writes": self.lake_writes,
            "lake_hits": self.lake_hits,
            "lake_evictions": self.lake_evictions,
            "lake_bytes_in": self.lake_bytes_in,
            "lake_bytes_out": self.lake_bytes_out,
            "dead_lettered": self.dead_lettered,
            "ledger_records": self.ledger_records,
            "ledger_digest": self.ledger_digest,
        }

    def to_jsonl(self, redactor: Redactor) -> str:
        """One redacted JSON line per project, then one totals line. Every
        per-project attribute dict crosses the redactor allowlist, same as a
        span's attrs — free text planted in the ledger cannot survive."""
        lines: List[str] = []
        for _, acct in sorted(self.projects.items()):
            lines.append(json.dumps(
                canonical(redactor.attrs(acct.to_dict())),
                sort_keys=True, separators=(",", ":")))
        totals = self.to_dict()
        totals.pop("projects")
        lines.append(json.dumps(
            canonical({"totals": redactor.attrs(totals)}),
            sort_keys=True, separators=(",", ":")))
        return "\n".join(lines) + "\n"

    def summary(self) -> str:
        """Human-readable accounting, for the example epilogue / operators."""
        out = [
            f"disclosure report — {self.ledger_records} ledger records, "
            f"digest {self.ledger_digest[:12]}…",
            f"  lake: {self.lake_writes} writes ({self.lake_bytes_in} B in), "
            f"{self.lake_hits} hits ({self.lake_bytes_out} B out), "
            f"{self.lake_evictions} evictions",
            f"  deid executions: {self.deid_executions}; "
            f"dead-lettered: {self.dead_lettered}",
        ]
        for _, acct in sorted(self.projects.items()):
            out.append(
                f"  project {acct.project or '<none>'}: {acct.deliveries} deliveries "
                f"({acct.cold} cold / {acct.warm} warm / {acct.journal} journal), "
                f"{acct.instances} instances, {acct.nbytes} B, "
                f"{len(acct.accessions)} accessions, "
                f"{len(acct.rulesets)} ruleset(s)"
            )
        return "\n".join(out)


def export_ledger_jsonl(ledger, redactor: Redactor) -> str:
    """Redacted JSONL dump of the full ledger. Structural chain fields
    (kind/seq/t/prev_sha/sha) are code-controlled and pass as-is — like span
    ids — while every payload attribute crosses the redactor allowlist."""
    lines: List[str] = []
    for rec in ledger.records():
        structural = {k: rec[k] for k in ("kind", "seq", "t", "prev_sha", "sha") if k in rec}
        payload = {k: v for k, v in rec.items() if k not in structural}
        out = {**structural, **redactor.attrs(payload)}
        lines.append(json.dumps(canonical(out), sort_keys=True, separators=(",", ":")))
    return "\n".join(lines) + ("\n" if lines else "")
