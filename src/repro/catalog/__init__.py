"""Columnar DICOM metadata catalog + vectorized cohort query engine
(DESIGN.md §8): dictionary-encoded column blocks with zone maps, a typed
predicate AST compiled to a jnp/Pallas bitmap evaluation, and the
``StudyCatalog`` facade turning queries into :class:`CohortSelection`\\ s the
cohort planner can admit.
"""
from repro.catalog.catalog import CatalogStats, CohortSelection, StudyCatalog
from repro.catalog.columns import (
    COLUMN_KINDS,
    COLUMNS,
    Dictionary,
    ZoneMap,
    row_from_dataset,
    rows_from_study,
)
from repro.catalog.query import (
    And,
    Contains,
    Eq,
    In,
    Not,
    Or,
    Predicate,
    Range,
    compile_query,
    describe,
    matches_row,
)

__all__ = [
    "And",
    "CatalogStats",
    "CohortSelection",
    "COLUMN_KINDS",
    "COLUMNS",
    "Contains",
    "Dictionary",
    "Eq",
    "In",
    "Not",
    "Or",
    "Predicate",
    "Range",
    "StudyCatalog",
    "ZoneMap",
    "compile_query",
    "describe",
    "matches_row",
    "row_from_dataset",
    "rows_from_study",
]
