"""StudyCatalog: the queryable metadata index over the imaging lake.

The paper's workflow is query-then-de-identify: researchers select cohorts
by metadata criteria and only the matching slice is de-identified on demand.
This facade owns the columnar blocks (``columns.py``), compiles and runs
predicates (``query.py``), and turns a match mask into a
:class:`CohortSelection` — accessions, instance counts, byte totals, and a
snapshot digest that pins exactly which catalog state answered the query
(replay determinism: same digest, same cohort, same warm-replay identity).

Ingest is incremental: ``StudyStore.attach_catalog`` routes every
``put_study`` here, and re-ingesting an accession (new source bytes, new
etag) tombstones its old rows and appends the new ones — queries never see
two versions of a study at once.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.catalog.columns import (
    COLUMNS,
    DICT_COLUMNS,
    Block,
    Dictionary,
    rows_from_study,
    seal_block,
)
from repro.catalog.query import (
    Predicate,
    compile_query,
    describe,
    eval_oracle,
    eval_vectorized,
    zone_may_match,
)
from repro.utils.logging import get_logger

log = get_logger("catalog")


@dataclass
class CatalogStats:
    rows: int = 0
    tombstoned: int = 0
    deletes: int = 0      # accessions removed via remove_study (feed deletes)
    queries: int = 0
    blocks_scanned: int = 0
    blocks_pruned: int = 0
    rows_scanned: int = 0


@dataclass(frozen=True)
class CohortSelection:
    """One query's answer, frozen at serve time.

    ``accessions`` are sorted lexicographically (deterministic, and
    first-occurrence row order would shift under re-ingest tombstoning).
    ``digest`` is sha256(catalog snapshot digest | canonical query) — two
    selections with the same digest are guaranteed to be the same cohort, so
    the digest rides the cohort ticket into the warm-replay identity.
    """

    query: str
    accessions: Tuple[str, ...]
    instance_counts: Dict[str, int]
    total_instances: int
    total_bytes: int
    digest: str
    blocks_scanned: int = 0
    blocks_pruned: int = 0


class StudyCatalog:
    def __init__(self, block_rows: int = 512, tracer=None) -> None:
        from repro.obs.trace import NULL_TRACER

        self.block_rows = block_rows
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.dicts: Dict[str, Dictionary] = {c: Dictionary() for c in DICT_COLUMNS}
        self._blocks: List[Block] = []
        # open (unsealed) block buffers
        self._open: Dict[str, List[int]] = {c: [] for c in COLUMNS}
        self._open_acc: List[int] = []
        self._open_valid: List[bool] = []
        # accession interning is exact-string (not CS-normalized): accession
        # ids must round-trip byte-identically into broker keys
        self._acc_values: List[str] = []
        self._acc_codes: Dict[str, int] = {}
        self._etags: Dict[str, Optional[str]] = {}  # insertion-ordered
        self._digest = hashlib.sha256()
        self._generation = 0
        # (generation, acc concat, nbytes concat): selection grouping needs
        # these for every row, but they only change on ingest — without the
        # cache every query would pay O(total rows) even when pruning
        # skipped every block
        self._concat_cache: Optional[Tuple[int, np.ndarray, np.ndarray]] = None
        self.stats = CatalogStats()

    # --------------------------------------------------------------- ingest
    def ingest_study(self, accession: str, study, etag: Optional[str] = None) -> int:
        """Index one study's instances; replaces any prior rows for the
        accession (re-acquisition safety). Returns rows ingested."""
        return self.ingest_rows(accession, rows_from_study(study), etag=etag)

    def ingest_rows(
        self, accession: str, rows: Sequence[dict], etag: Optional[str] = None
    ) -> int:
        if accession in self._acc_codes:
            self._tombstone(accession)
        code = self._acc_codes.get(accession)
        if code is None:
            code = len(self._acc_values)
            self._acc_codes[accession] = code
            self._acc_values.append(accession)
        for row in rows:
            # missing columns default to ""/0 (schema growth: row dicts built
            # before a column existed stay ingestable; matches_row mirrors
            # the same defaults, so oracle and vectorized paths agree)
            for col in COLUMNS:
                if col in DICT_COLUMNS:
                    self._open[col].append(self.dicts[col].encode(row.get(col, "")))
                else:
                    self._open[col].append(int(row.get(col, 0)))
            self._open_acc.append(code)
            self._open_valid.append(True)
            if len(self._open_acc) >= self.block_rows:
                self._seal_open()
        self._etags[accession] = etag
        self.stats.rows += len(rows)
        self._generation += 1
        self._digest.update(
            f"{self._generation}|{accession}|{etag or ''}|{len(rows)}".encode()
        )
        return len(rows)

    def remove_study(self, accession: str) -> int:
        """Delta delete: tombstone an accession's live rows and drop it from
        the etag inventory — no rebuild, work ∝ the accession's rows. Returns
        the number of rows tombstoned (0 for unknown accessions)."""
        if accession not in self._acc_codes:
            return 0
        before = self.stats.tombstoned
        self._tombstone(accession)
        self._etags.pop(accession, None)
        self.stats.deletes += 1
        self._generation += 1
        self._digest.update(f"{self._generation}|{accession}|<deleted>|0".encode())
        return self.stats.tombstoned - before

    def _seal_open(self) -> None:
        self._blocks.append(seal_block(self._open, self._open_acc, self._open_valid))
        self._open = {c: [] for c in COLUMNS}
        self._open_acc = []
        self._open_valid = []

    def _tombstone(self, accession: str) -> None:
        code = self._acc_codes[accession]
        killed = 0
        for block in self._blocks:
            hit = block.acc == code
            killed += int((hit & block.valid).sum())
            block.valid[hit] = False
        for i, c in enumerate(self._open_acc):
            if c == code and self._open_valid[i]:
                self._open_valid[i] = False
                killed += 1
        self.stats.tombstoned += killed

    # ------------------------------------------------------------ inventory
    def accessions(self) -> List[str]:
        return list(self._etags)

    def accession_etags(self) -> Dict[str, Optional[str]]:
        """accession -> source etag at last ingest, insertion-ordered. The
        fleet sim snapshots this at query-serve time so the consistency
        checker replays against exactly the indexed versions."""
        return dict(self._etags)

    def snapshot_digest(self) -> str:
        """Digest of the full ingest history (accession, etag, row count per
        generation) — the catalog-state half of every selection digest."""
        return self._digest.copy().hexdigest()

    def n_rows(self) -> int:
        return sum(b.n for b in self._blocks) + len(self._open_acc)

    def _all_blocks(self) -> List[Block]:
        blocks = list(self._blocks)
        if self._open_acc:
            blocks.append(
                Block(
                    cols={c: np.asarray(v, np.int32) for c, v in self._open.items()},
                    acc=np.asarray(self._open_acc, np.int32),
                    valid=np.asarray(self._open_valid, bool),
                    zmaps=None,  # unsealed: no zone maps, always scanned
                )
            )
        return blocks

    # --------------------------------------------------------------- queries
    def match_mask(
        self, pred: Predicate, mode: str = "auto", prune: bool = True
    ) -> Tuple[np.ndarray, int, int]:
        """Evaluate a predicate over every row. Returns (mask over all rows
        in ingest order, blocks_scanned, blocks_pruned); tombstoned rows are
        always False. ``mode``: "auto" = vectorized jnp+Pallas path,
        "oracle" = numpy reference scan."""
        compiled = compile_query(pred, self.dicts)
        blocks = self._all_blocks()
        total = sum(b.n for b in blocks)
        mask = np.zeros(total, bool)
        scanned: List[Tuple[int, Block]] = []
        pruned = 0
        offset = 0
        for b in blocks:
            skip = b.zmaps is not None and (
                not b.valid.any()
                or not zone_may_match(compiled.tree, compiled.leaves, b.zmaps)
            )
            if prune and skip:
                pruned += 1
            else:
                scanned.append((offset, b))
            offset += b.n
        if scanned:
            arrays = {
                c: np.concatenate([b.cols[c] for _, b in scanned]) for c in compiled.cols
            }
            valid = np.concatenate([b.valid for _, b in scanned])
            evaluate = eval_oracle if mode == "oracle" else eval_vectorized
            seg = evaluate(compiled, arrays, valid)
            pos = 0
            for off, b in scanned:
                mask[off : off + b.n] = seg[pos : pos + b.n]
                pos += b.n
        self.stats.queries += 1
        self.stats.blocks_scanned += len(scanned)
        self.stats.blocks_pruned += pruned
        self.stats.rows_scanned += sum(b.n for _, b in scanned)
        return mask, len(scanned), pruned

    def _row_identity(self) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenated (acc codes, nbytes) over all rows, cached per ingest
        generation (tombstoning bumps the generation too, but identity
        columns never change value — only ``valid`` does)."""
        if self._concat_cache is None or self._concat_cache[0] != self._generation:
            blocks = self._all_blocks()
            if blocks:
                acc = np.concatenate([b.acc for b in blocks])
                nbytes = np.concatenate([b.cols["nbytes"] for b in blocks])
            else:
                acc = np.zeros(0, np.int32)
                nbytes = np.zeros(0, np.int32)
            self._concat_cache = (self._generation, acc, nbytes)
        return self._concat_cache[1], self._concat_cache[2]

    def select(
        self, pred: Predicate, mode: str = "auto", prune: bool = True
    ) -> CohortSelection:
        """Resolve a predicate to the matching cohort."""
        with self.tracer.span("catalog.select", mode=mode) as _scan_span:
            mask, n_scanned, n_pruned = self.match_mask(pred, mode=mode, prune=prune)
            _scan_span.set(
                blocks_scanned=n_scanned,
                blocks_pruned=n_pruned,
                matched=int(mask.sum()),
            )
        acc, nbytes = self._row_identity()
        hit_acc = acc[mask]
        counts: Dict[str, int] = {}
        for code, n in zip(*np.unique(hit_acc, return_counts=True)):
            counts[self._acc_values[int(code)]] = int(n)
        ordered = tuple(sorted(counts))
        qs = describe(pred)
        digest = hashlib.sha256(
            f"{self.snapshot_digest()}|{qs}".encode()
        ).hexdigest()
        return CohortSelection(
            query=qs,
            accessions=ordered,
            instance_counts={a: counts[a] for a in ordered},
            total_instances=int(mask.sum()),
            total_bytes=int(nbytes[mask].sum()),
            digest=digest,
            blocks_scanned=n_scanned,
            blocks_pruned=n_pruned,
        )
