"""Ingest-time columnar encoding of DICOM metadata (DESIGN.md §8).

A catalog row is one SOP instance. String-ish tags (CS/LO) are
dictionary-encoded to int32 codes through the same ``normalize_cs``
normalization the filter stage uses — the catalog and the filter can never
disagree about string equality. Numeric tags are stored as int32 directly
(StudyDate as the yyyymmdd integer, so date ranges are integer ranges).

Rows are grouped into fixed-size blocks. Each sealed block carries a zone
map per column: [min, max] for numeric columns, a 64-bit bloom-lite code
mask for dictionary columns. Zone maps are computed at seal time over every
row the block ever held, so tombstoning rows (re-ingest) keeps them
conservative — pruning may scan a dead block, never skip a live row.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dicom.dataset import DicomDataset, normalize_cs

# column name -> kind. "dict": dictionary-encoded normalized string;
# "int": raw int32 value. The query AST validates against this schema.
COLUMN_KINDS: Dict[str, str] = {
    "modality": "dict",
    "body_part": "dict",
    "manufacturer": "dict",
    "model": "dict",
    "study_date": "int",
    "bits_stored": "int",
    "rows": "int",
    "cols": "int",
    "nbytes": "int",
    "burned_in": "int",
    # detector-oracle verdict over the *source* pixels at ingest: 1 when the
    # text-band detector (default policy knobs) proposes at least one band.
    # Complements the self-declared BurnedInAnnotation tag — devices lie
    # about burn-in far more often than pixels do (DESIGN.md §9).
    "burned_in_detected": "int",
}
COLUMNS: Tuple[str, ...] = tuple(COLUMN_KINDS)
DICT_COLUMNS: Tuple[str, ...] = tuple(c for c, k in COLUMN_KINDS.items() if k == "dict")


def date_int(value: Any) -> int:
    """DICOM DA string -> yyyymmdd integer (0 when absent/malformed)."""
    digits = "".join(ch for ch in str(value) if ch.isdigit())
    return int(digits[:8]) if digits else 0


def burned_in_detected(ds: DicomDataset) -> int:
    """Detector-oracle verdict for one instance's pixels (0 for pixel-less
    or multi-plane objects). Pure numpy at scan time; imports are lazy so the
    catalog module itself stays jax-free."""
    pix = ds.pixels
    if pix is None or getattr(pix, "ndim", 0) != 2:
        return 0
    from repro.detect import DetectorPolicy, detect_bands_for

    bands, _ = detect_bands_for(ds, DetectorPolicy())
    return int(bool(bands))


def row_from_dataset(ds: DicomDataset) -> Dict[str, Any]:
    """Extract one catalog row from a dataset. Raw (unnormalized) strings —
    normalization happens at dictionary-encode time, and the brute-force
    oracle (`query.matches_row`) normalizes on its side, so both paths see
    the same values the same way."""
    res = ds.resolution() or (0, 0)
    return {
        "modality": str(ds.get("Modality", "")),
        "body_part": str(ds.get("BodyPartExamined", "")),
        "manufacturer": str(ds.get("Manufacturer", "")),
        "model": str(ds.get("ManufacturerModelName", "")),
        "study_date": date_int(ds.get("StudyDate", "")),
        "bits_stored": int(ds.get("BitsStored", 0) or 0),
        "rows": int(res[0]),
        "cols": int(res[1]),
        "nbytes": int(ds.nbytes()),
        "burned_in": int(normalize_cs(ds.get("BurnedInAnnotation", "")) == "YES"),
        "burned_in_detected": burned_in_detected(ds),
    }


def rows_from_study(study) -> List[Dict[str, Any]]:
    """Catalog rows for every instance of a :class:`SyntheticStudy`."""
    return [row_from_dataset(ds) for ds in study.datasets]


class Dictionary:
    """Incremental string dictionary: normalized value <-> int32 code."""

    __slots__ = ("values", "codes")

    def __init__(self) -> None:
        self.values: List[str] = []
        self.codes: Dict[str, int] = {}

    def encode(self, raw: Any) -> int:
        v = normalize_cs(raw)
        code = self.codes.get(v)
        if code is None:
            code = len(self.values)
            self.codes[v] = code
            self.values.append(v)
        return code

    def code_of(self, raw: Any) -> Optional[int]:
        """Code for a query literal; None when the value was never ingested
        (the query can then match nothing — a pruning fact, not an error)."""
        return self.codes.get(normalize_cs(raw))

    def decode(self, code: int) -> str:
        return self.values[code]

    def codes_containing(self, needle: Any) -> Tuple[int, ...]:
        """All codes whose decoded value contains the normalized needle —
        free-text Contains compiles down to an In over these codes."""
        nv = normalize_cs(needle)
        return tuple(c for c, v in enumerate(self.values) if nv in v)

    def __len__(self) -> int:
        return len(self.values)


def bloom_bit(code: int) -> int:
    """64-bit bloom-lite position for a dictionary code (Knuth multiplicative
    mix — codes are small sequential ints, so unmixed modulo would alias
    neighbouring values into runs)."""
    return (code * 2654435761) % 64


@dataclass(frozen=True)
class ZoneMap:
    lo: int
    hi: int
    bloom: int  # 64-bit code mask, dictionary columns only (0 for int cols)


@dataclass
class Block:
    """One sealed (or open-view) block: column arrays + validity + zone maps.

    ``zmaps`` is None for the open-block view — an unsealed block has no zone
    maps yet and is always scanned.
    """

    cols: Dict[str, np.ndarray]      # column -> (n,) int32
    acc: np.ndarray                  # (n,) int32 accession codes
    valid: np.ndarray                # (n,) bool, False = tombstoned
    zmaps: Optional[Dict[str, ZoneMap]]

    @property
    def n(self) -> int:
        return int(self.acc.shape[0])

    def n_valid(self) -> int:
        return int(self.valid.sum())


def build_zone_maps(cols: Dict[str, np.ndarray]) -> Dict[str, ZoneMap]:
    zmaps: Dict[str, ZoneMap] = {}
    for name, arr in cols.items():
        lo = int(arr.min()) if arr.size else 0
        hi = int(arr.max()) if arr.size else -1
        bloom = 0
        if COLUMN_KINDS[name] == "dict":
            for code in np.unique(arr):
                bloom |= 1 << bloom_bit(int(code))
        zmaps[name] = ZoneMap(lo, hi, bloom)
    return zmaps


def seal_block(
    cols: Dict[str, Sequence[int]], acc: Sequence[int], valid: Sequence[bool]
) -> Block:
    arrays = {name: np.asarray(vals, np.int32) for name, vals in cols.items()}
    return Block(
        cols=arrays,
        acc=np.asarray(acc, np.int32),
        valid=np.asarray(valid, bool),
        zmaps=build_zone_maps(arrays),
    )
