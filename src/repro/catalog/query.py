"""Typed predicate AST + compilation to vectorized columnar evaluation.

Three evaluation paths, all required to agree bit-for-bit:

* :func:`matches_row` — python truth, one row at a time. The brute-force
  oracle the fleet simulator's query-consistency invariant replays; it never
  touches dictionaries, bitmaps, or pruning.
* :func:`eval_oracle` — numpy over resolved int32 columns, no bitmaps. The
  reference scan the vectorized path is parity-tested against (and the
  catalogbench baseline).
* :func:`eval_vectorized` — jnp leaf compares packed into uint32 bitmaps,
  combined by the Pallas popcount kernel (interpret mode on CPU). The
  production path.

Compilation resolves string literals to dictionary codes once (``Eq`` on a
never-ingested value becomes a statically-false leaf; ``Contains`` becomes an
``In`` over the matching codes) and flattens the tree into a static stack
program terminated by a validity-AND, so NOT can never resurrect tombstoned
or padding rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.catalog.columns import COLUMN_KINDS, Dictionary, ZoneMap, bloom_bit
from repro.dicom.dataset import normalize_cs
from repro.kernels.bitmap.ops import combine_bitmaps, pack_mask, unpack_mask
from repro.kernels.bitmap.ref import Program


# ------------------------------------------------------------------------ AST
class Predicate:
    """Marker base. Predicates are frozen (hashable) — traffic models treat
    them as data, exactly like accession tuples."""


@dataclass(frozen=True)
class Eq(Predicate):
    col: str
    value: Any


@dataclass(frozen=True)
class In(Predicate):
    col: str
    values: Tuple[Any, ...]


@dataclass(frozen=True)
class Range(Predicate):
    """Inclusive [lo, hi] over an int column (StudyDate is yyyymmdd)."""

    col: str
    lo: int
    hi: int


@dataclass(frozen=True)
class Contains(Predicate):
    """Free-text substring over a dictionary column's decoded values."""

    col: str
    needle: str


@dataclass(frozen=True, init=False)
class And(Predicate):
    preds: Tuple[Predicate, ...]

    def __init__(self, *preds: Predicate) -> None:
        object.__setattr__(self, "preds", tuple(preds))


@dataclass(frozen=True, init=False)
class Or(Predicate):
    preds: Tuple[Predicate, ...]

    def __init__(self, *preds: Predicate) -> None:
        object.__setattr__(self, "preds", tuple(preds))


@dataclass(frozen=True)
class Not(Predicate):
    pred: Predicate


def describe(pred: Predicate) -> str:
    """Canonical string form — feeds selection digests and the sim event
    log, so it must be deterministic (values normalized, order preserved)."""
    if isinstance(pred, Eq):
        v = normalize_cs(pred.value) if COLUMN_KINDS.get(pred.col) == "dict" else int(pred.value)
        return f"Eq({pred.col},{v})"
    if isinstance(pred, In):
        if COLUMN_KINDS.get(pred.col) == "dict":
            vals = ",".join(normalize_cs(v) for v in pred.values)
        else:
            vals = ",".join(str(int(v)) for v in pred.values)
        return f"In({pred.col},[{vals}])"
    if isinstance(pred, Range):
        return f"Range({pred.col},{int(pred.lo)},{int(pred.hi)})"
    if isinstance(pred, Contains):
        return f"Contains({pred.col},{normalize_cs(pred.needle)})"
    if isinstance(pred, And):
        return f"And({','.join(describe(p) for p in pred.preds)})"
    if isinstance(pred, Or):
        return f"Or({','.join(describe(p) for p in pred.preds)})"
    if isinstance(pred, Not):
        return f"Not({describe(pred.pred)})"
    raise TypeError(f"not a predicate: {pred!r}")


# ----------------------------------------------------------- row-level oracle
def matches_row(pred: Predicate, row: Dict[str, Any]) -> bool:
    """Ground truth for one raw row dict (`columns.row_from_dataset` output).
    Pure python semantics — no dictionaries, no vectorization. Missing
    columns read as ""/0, the same defaults ``ingest_rows`` encodes."""
    if isinstance(pred, Eq):
        if COLUMN_KINDS[pred.col] == "dict":
            return normalize_cs(row.get(pred.col, "")) == normalize_cs(pred.value)
        return int(row.get(pred.col, 0)) == int(pred.value)
    if isinstance(pred, In):
        return any(matches_row(Eq(pred.col, v), row) for v in pred.values)
    if isinstance(pred, Range):
        _require_int(pred.col, "Range")
        return int(pred.lo) <= int(row.get(pred.col, 0)) <= int(pred.hi)
    if isinstance(pred, Contains):
        _require_dict(pred.col, "Contains")
        return normalize_cs(pred.needle) in normalize_cs(row.get(pred.col, ""))
    if isinstance(pred, And):
        return all(matches_row(p, row) for p in pred.preds)
    if isinstance(pred, Or):
        return any(matches_row(p, row) for p in pred.preds)
    if isinstance(pred, Not):
        return not matches_row(pred.pred, row)
    raise TypeError(f"not a predicate: {pred!r}")


def _require_int(col: str, what: str) -> None:
    if COLUMN_KINDS.get(col) != "int":
        raise ValueError(f"{what} requires an int column, got {col!r}")


def _require_dict(col: str, what: str) -> None:
    if COLUMN_KINDS.get(col) != "dict":
        raise ValueError(f"{what} requires a dictionary column, got {col!r}")


def _check_col(col: str) -> None:
    if col not in COLUMN_KINDS:
        raise KeyError(f"unknown catalog column {col!r}; schema: {sorted(COLUMN_KINDS)}")


# ---------------------------------------------------------------- compilation
@dataclass(frozen=True)
class ResolvedLeaf:
    """A leaf after literal resolution: string literals became dictionary
    codes. ``test`` is ("in", codes_or_values_tuple) or ("range", lo, hi);
    Eq resolves to a one-element "in", unknown dict literals to an empty one
    (statically false)."""

    col: str
    test: Tuple


@dataclass(frozen=True)
class ResolvedNode:
    """Tree mirror of the predicate with leaves resolved — the oracle and the
    zone-map pruner walk this; the vectorized path uses the flat program."""

    op: str  # "leaf" | "and" | "or" | "not"
    leaf: Optional[int] = None               # leaf index for op == "leaf"
    children: Tuple["ResolvedNode", ...] = ()


@dataclass
class CompiledQuery:
    leaves: List[ResolvedLeaf]
    tree: ResolvedNode
    program: Program       # stack program over leaves + terminal validity AND
    cols: Tuple[str, ...]  # columns the leaves touch


def _resolve_leaf(pred: Predicate, dicts: Dict[str, Dictionary]) -> ResolvedLeaf:
    if isinstance(pred, Eq):
        _check_col(pred.col)
        if COLUMN_KINDS[pred.col] == "dict":
            code = dicts[pred.col].code_of(pred.value)
            return ResolvedLeaf(pred.col, ("in", () if code is None else (code,)))
        return ResolvedLeaf(pred.col, ("in", (int(pred.value),)))
    if isinstance(pred, In):
        _check_col(pred.col)
        if COLUMN_KINDS[pred.col] == "dict":
            codes = tuple(
                c for c in (dicts[pred.col].code_of(v) for v in pred.values) if c is not None
            )
            return ResolvedLeaf(pred.col, ("in", codes))
        return ResolvedLeaf(pred.col, ("in", tuple(int(v) for v in pred.values)))
    if isinstance(pred, Range):
        _check_col(pred.col)
        _require_int(pred.col, "Range")
        return ResolvedLeaf(pred.col, ("range", int(pred.lo), int(pred.hi)))
    if isinstance(pred, Contains):
        _check_col(pred.col)
        _require_dict(pred.col, "Contains")
        return ResolvedLeaf(pred.col, ("in", dicts[pred.col].codes_containing(pred.needle)))
    raise TypeError(f"not a leaf predicate: {pred!r}")


def compile_query(pred: Predicate, dicts: Dict[str, Dictionary]) -> CompiledQuery:
    leaves: List[ResolvedLeaf] = []
    ops: List[tuple] = []

    def emit(p: Predicate) -> ResolvedNode:
        if isinstance(p, (And, Or)):
            if not p.preds:
                raise ValueError(f"{type(p).__name__} needs at least one child")
            kind = "and" if isinstance(p, And) else "or"
            children = []
            for i, c in enumerate(p.preds):
                children.append(emit(c))
                if i:
                    ops.append((kind,))
            return ResolvedNode(kind, children=tuple(children))
        if isinstance(p, Not):
            node = emit(p.pred)
            ops.append(("not",))
            return ResolvedNode("not", children=(node,))
        leaf = _resolve_leaf(p, dicts)
        idx = len(leaves)
        leaves.append(leaf)
        ops.append(("leaf", idx))
        return ResolvedNode("leaf", leaf=idx)

    tree = emit(pred)
    # terminal validity AND: leaf index len(leaves) is reserved for the valid
    # bitmap the evaluator appends (tombstones + padding)
    program = tuple(ops) + (("leaf", len(leaves)), ("and",))
    cols = tuple(dict.fromkeys(leaf.col for leaf in leaves))
    return CompiledQuery(leaves=leaves, tree=tree, program=program, cols=cols)


# ------------------------------------------------------------------- pruning
def zone_may_match(
    node: ResolvedNode, leaves: List[ResolvedLeaf], zmaps: Dict[str, ZoneMap]
) -> bool:
    """Conservative block test: False only when the zone maps PROVE no row in
    the block can satisfy the predicate. NOT is always conservative-True
    (zone maps witness presence, not absence)."""
    if node.op == "leaf":
        leaf = leaves[node.leaf]
        zm = zmaps[leaf.col]
        if leaf.test[0] == "range":
            _, lo, hi = leaf.test
            return hi >= zm.lo and lo <= zm.hi
        values = leaf.test[1]
        if not values:
            return False  # statically-false leaf (unknown literal)
        if COLUMN_KINDS[leaf.col] == "dict":
            return any(zm.bloom >> bloom_bit(v) & 1 for v in values)
        return any(zm.lo <= v <= zm.hi for v in values)
    if node.op == "and":
        return all(zone_may_match(c, leaves, zmaps) for c in node.children)
    if node.op == "or":
        return any(zone_may_match(c, leaves, zmaps) for c in node.children)
    return True  # not


# ----------------------------------------------------------------- evaluation
def _leaf_mask_np(leaf: ResolvedLeaf, arrays: Dict[str, np.ndarray]) -> np.ndarray:
    arr = arrays[leaf.col]
    if leaf.test[0] == "range":
        _, lo, hi = leaf.test
        return (arr >= lo) & (arr <= hi)
    mask = np.zeros(arr.shape[0], bool)
    for v in leaf.test[1]:
        mask |= arr == v
    return mask


def _leaf_mask_jnp(leaf: ResolvedLeaf, arrays: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    arr = arrays[leaf.col]
    if leaf.test[0] == "range":
        _, lo, hi = leaf.test
        return (arr >= lo) & (arr <= hi)
    mask = jnp.zeros(arr.shape[0], bool)
    for v in leaf.test[1]:
        mask = mask | (arr == v)
    return mask


def _eval_tree_np(
    node: ResolvedNode, leaves: List[ResolvedLeaf], arrays: Dict[str, np.ndarray]
) -> np.ndarray:
    if node.op == "leaf":
        return _leaf_mask_np(leaves[node.leaf], arrays)
    masks = [_eval_tree_np(c, leaves, arrays) for c in node.children]
    if node.op == "and":
        out = masks[0]
        for m in masks[1:]:
            out = out & m
        return out
    if node.op == "or":
        out = masks[0]
        for m in masks[1:]:
            out = out | m
        return out
    return ~masks[0]  # not


def eval_oracle(
    compiled: CompiledQuery, arrays: Dict[str, np.ndarray], valid: np.ndarray
) -> np.ndarray:
    """Numpy reference scan: resolved tree over int32 columns, validity AND
    at the end. No bitmaps, no jax."""
    if valid.shape[0] == 0:
        return np.zeros(0, bool)
    return _eval_tree_np(compiled.tree, compiled.leaves, arrays) & valid


def eval_vectorized(
    compiled: CompiledQuery, arrays: Dict[str, np.ndarray], valid: np.ndarray
) -> np.ndarray:
    """Production path: jnp leaf compares -> packed uint32 bitmaps -> Pallas
    combine+popcount kernel. Bit-identical to :func:`eval_oracle`."""
    n = int(valid.shape[0])
    if n == 0:
        return np.zeros(0, bool)
    jarrays = {c: jnp.asarray(arrays[c], jnp.int32) for c in compiled.cols}
    packed = [pack_mask(_leaf_mask_jnp(leaf, jarrays)) for leaf in compiled.leaves]
    packed.append(pack_mask(jnp.asarray(valid)))  # the reserved validity leaf
    bitmap, _count = combine_bitmaps(jnp.stack(packed), compiled.program)
    return unpack_mask(bitmap, n)
