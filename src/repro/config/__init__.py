from repro.config.model import ModelConfig, ShapeConfig, SHAPES
from repro.config.registry import register_arch, get_arch, list_archs

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "register_arch", "get_arch", "list_archs"]
