"""Unified model/shape configuration for every assigned architecture family.

One frozen dataclass covers dense / MoE / SSM / hybrid / encoder / VLM; family
selects the block stack, the rest are dimension knobs. `reduced()` produces
the family-preserving smoke-test config (small dims, same structure) required
by deliverable (f).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encoder", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0      # 0 = full attention
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 0         # 1 = mamba1 (falcon-mamba), 2 = mamba2/SSD (zamba2)
    ssm_head_dim: int = 64       # mamba2 P
    ssm_dt_rank: int = 0         # mamba1; 0 -> ceil(d_model/16)
    ssm_chunk: int = 128         # chunked-scan length (TPU adaptation knob)
    # hybrid (zamba2)
    attn_every: int = 0          # shared attn block after every k-th ssm layer
    # structure
    causal: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # attention compute (TPU adaptation knobs, see DESIGN.md / §Perf)
    attn_chunk: int = 1024       # KV-chunked (flash-style) attention block
    loss_chunk: int = 512        # sequence chunking for the vocab head + CE
    remat: str = "full"          # "none" | "dots" | "full" per-layer remat policy
                                 # (full = save only scan carries; "dots" is a
                                 # §Perf knob for models with HBM headroom)
    scan_unroll: bool = False    # unroll every lax.scan (dry-run cost variants
                                 # only: XLA cost_analysis counts a scan body
                                 # once regardless of trip count)
    attn_p_bf16: bool = True     # store post-softmax probabilities in bf16 for
                                 # the PV matmul (halves prefill HBM traffic;
                                 # §Perf iteration 2); f32 when dtype=float32
    attn_grouped: bool = True    # grouped-GQA einsums (no KV repeat); False =
                                 # naive repeat_kv baseline (§Perf iteration 1 A/B)
    source: str = ""             # provenance tag from the assignment table

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (spec: SSM / hybrid / linear-attn only)."""
        return self.family in ("ssm", "hybrid")

    def n_shared_attn(self) -> int:
        """Hybrid: number of shared-attention applications."""
        if self.family != "hybrid" or not self.attn_every:
            return 0
        return self.n_layers // self.attn_every

    # ------------------------------------------------------------ counting
    def param_count(self) -> int:
        """Analytic parameter count (cross-checked against the real pytree)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = V * d  # embedding
        if not self.tie_embeddings and self.family != "encoder":
            n += V * d  # lm head
        if self.family == "encoder":
            n += V * d  # classifier head
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            a = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                a += H * hd + 2 * KV * hd
            return a

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gate, up, down

        if self.family in ("dense", "vlm"):
            per = attn_params() + mlp_params(f) + 2 * d
            n += L * per
        elif self.family == "encoder":
            per = attn_params() + mlp_params(f) + 2 * d
            n += L * per
        elif self.family == "moe":
            per = attn_params() + self.n_experts * mlp_params(f) + d * self.n_experts + 2 * d
            n += L * per
        elif self.family == "ssm":
            n += L * (self._mamba1_params() + d)
        elif self.family == "hybrid":
            n += L * (self._mamba2_params() + d)
            if self.n_shared_attn():
                # shared block params counted once (weights reused)
                n += 2 * d * self.n_heads * self.hd + 2 * 2 * d * self.n_kv_heads * self.hd \
                     + self.n_heads * self.hd * d + 2 * d + mlp_params(self.d_ff) if self.d_ff else 0
        n += d  # final norm
        return n

    def _mamba1_params(self) -> int:
        d, di, N, R = self.d_model, self.d_inner, self.ssm_state, self.dt_rank
        return (
            d * 2 * di            # in_proj
            + self.ssm_conv * di  # depthwise conv
            + di * (R + 2 * N)    # x_proj
            + R * di + di         # dt_proj
            + di * N + di         # A_log, D
            + di * d              # out_proj
        )

    def _mamba2_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H = self.ssm_nheads
        return (
            d * (2 * di + 2 * N + H)  # in_proj -> z, x, B, C, dt
            + self.ssm_conv * (di + 2 * N)
            + 3 * H                   # A_log, D, dt_bias
            + di                      # norm
            + di * d                  # out_proj
        )

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * f * self.n_layers
        return self.param_count() - inactive

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        changes: Dict = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 2 * max(self.attn_every, 1)),
            d_model=128,
            n_heads=min(self.n_heads, 4) or 0,
            n_kv_heads=min(self.n_kv_heads, 2) or 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32 if self.n_heads else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_chunk=64,
            loss_chunk=64,
            ssm_chunk=32,
            ssm_head_dim=32 if self.ssm_version == 2 else self.ssm_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            name=f"{self.name}-reduced",
            dtype="float32",
        )
        if self.family == "moe":
            changes["n_experts"] = min(self.n_experts, 8)
            changes["experts_per_token"] = min(self.experts_per_token, 2)
        if self.family == "hybrid":
            changes["attn_every"] = min(self.attn_every or 2, 2)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec'd skip rules (DESIGN.md §4): returns (runnable, reason_if_not)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only architecture has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (spec: run for SSM/hybrid only)"
    return True, ""
