"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.model import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}

# configs are one module per arch under repro.configs (deliverable f)
_ARCH_MODULES = [
    "qwen1_5_110b",
    "qwen2_0_5b",
    "glm4_9b",
    "h2o_danube_1_8b",
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "llava_next_34b",
    "zamba2_2_7b",
    "hubert_xlarge",
    "falcon_mamba_7b",
]


def register_arch(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(arch_id: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)
