# One module per assigned architecture (deliverable f). Selected via
# ``--arch <id>`` through repro.config.registry.
