"""falcon-mamba-7b — attention-free Mamba-1 SSM. [arXiv:2410.05355; unverified]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("falcon-mamba-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        ssm_version=1,  # mamba1 arch
        ssm_expand=2,
        ssm_conv=4,
        tie_embeddings=True,
        source="arXiv:2410.05355; unverified",
    )
