"""h2o-danube-1.8b — dense llama+mistral mix, GQA kv=8, sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("h2o-danube-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        head_dim=80,
        sliding_window=4096,  # mistral-style SWA
        rope_theta=1e4,
        source="arXiv:2401.16818; hf",
    )
