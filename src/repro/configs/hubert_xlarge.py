"""hubert-xlarge — encoder-only audio transformer (wav2vec2-style backbone);
conv frame frontend is a stub per the assignment (input_specs() provides
precomputed frame embeddings); masked-prediction head over 504 clusters.
[arXiv:2106.07447; unverified]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        head_dim=80,
        causal=False,
        source="arXiv:2106.07447; unverified",
    )
