"""llava-next-34b — VLM: dense GQA decoder backbone (Yi-34B-class) consuming
anyres patch embeddings; modality frontend is a stub per the assignment
(input_specs() provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("llava-next-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        head_dim=128,
        rope_theta=5e6,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    )
