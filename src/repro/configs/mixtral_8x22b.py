"""mixtral-8x22b — MoE 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        head_dim=128,
        sliding_window=4096,
        n_experts=8,
        experts_per_token=2,
        rope_theta=1e6,
        source="arXiv:2401.04088; hf",
    )
