"""olmoe-1b-7b — MoE 64 experts top-8, fine-grained (d_ff=1024/expert).
[arXiv:2409.02060; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MHA
        d_ff=1024,
        vocab_size=50304,
        head_dim=128,
        n_experts=64,
        experts_per_token=8,
        rope_theta=1e4,
        source="arXiv:2409.02060; hf",
    )
