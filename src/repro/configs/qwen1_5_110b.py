"""qwen1.5-110b — dense, GQA kv=8, QKV bias. [hf:Qwen/Qwen1.5-110B; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("qwen1.5-110b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-110B; hf",
    )
