"""qwen2-0.5b — dense, GQA kv=2, QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("qwen2-0.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151936,
        head_dim=64,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
        source="arXiv:2407.10671; hf",
    )
