"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block (weights
reused, applied every 6th layer, concat-skip from embeddings).
[arXiv:2411.15242; hf]"""
from repro.config.model import ModelConfig
from repro.config.registry import register_arch


@register_arch("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,  # MHA in the shared block
        d_ff=10240,     # shared block MLP
        vocab_size=32000,
        head_dim=80,
        ssm_state=64,
        ssm_version=2,  # Mamba2 / SSD
        ssm_expand=2,
        ssm_head_dim=64,
        attn_every=6,
        rope_theta=1e4,
        source="arXiv:2411.15242; hf",
    )
