# The paper's primary contribution: the on-demand de-identification engine.
# filter -> scrub -> anonymize stages, pseudonymization, manifests, rule DSL.
from repro.core.batch import BatchedDeidExecutor
from repro.core.pipeline import DeidPipeline, DeidRequest, StudyDeidResult, build_request
from repro.core.pseudonym import PseudonymService, TrustMode
from repro.core.manifest import Manifest, ManifestEntry, Outcome
from repro.core.filter import FilterStage
from repro.core.scrub import ScrubStage, ScrubError, numpy_blank
from repro.core.anonymize import AnonymizerStage

__all__ = [
    "BatchedDeidExecutor",
    "DeidPipeline",
    "DeidRequest",
    "StudyDeidResult",
    "build_request",
    "PseudonymService",
    "TrustMode",
    "Manifest",
    "ManifestEntry",
    "Outcome",
    "FilterStage",
    "ScrubStage",
    "ScrubError",
    "numpy_blank",
    "AnonymizerStage",
]
