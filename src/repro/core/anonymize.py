"""Anonymizer stage: remove/replace metadata known to contain PHI.

Third stage of the paper's engine. Executes the parsed anonymizer script
against a dataset: explicit per-tag rules first (first rule naming a tag
wins, CTP semantics), then the ``default`` policy sweeps every remaining tag.
Private groups and free-text VRs have dedicated sweep actions because they
are the highest-risk leak vectors.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.pseudonym import PseudonymService
from repro.core.rules import AnonRule, parse_anonymizer_script, render_template, script_sha
from repro.dicom.dataset import DicomDataset, new_uid
from repro.dicom.tags import FREETEXT_KEYWORDS, TAGS


@dataclass
class AnonResult:
    dataset: DicomDataset
    tag_actions: Dict[str, str] = field(default_factory=dict)


class AnonymizerStage:
    def __init__(self, script_text: str) -> None:
        self.script_text = script_text
        self.rules = parse_anonymizer_script(script_text)
        self.sha = script_sha(script_text)
        self._explicit: Dict[str, AnonRule] = {}
        self._default = "remove"
        self._sweep_private = False
        self._sweep_freetext = False
        for r in self.rules:
            if r.action == "default":
                self._default = r.template
            elif r.action == "removeprivate":
                self._sweep_private = True
            elif r.action == "removefreetext":
                self._sweep_freetext = True
            elif r.keyword is not None and r.keyword not in self._explicit:
                self._explicit[r.keyword] = r

    def __call__(
        self,
        ds: DicomDataset,
        params: Dict[str, str],
        pseudo: Optional[PseudonymService] = None,
    ) -> AnonResult:
        out = ds.copy()
        actions: Dict[str, str] = {}
        jitter = int(params.get("jitter", 0))

        for kw in list(out.keys()):
            rule = self._explicit.get(kw)
            if rule is None:
                continue
            if rule.action == "keep":
                actions[kw] = "keep"
            elif rule.action == "remove":
                out.pop(kw)
                actions[kw] = "remove"
            elif rule.action == "empty":
                out[kw] = ""
                actions[kw] = "empty"
            elif rule.action == "set":
                out[kw] = render_template(rule.template, params, ds)
                actions[kw] = "set"
            elif rule.action == "hashuid":
                # UID remapped through the study-scoped pseudonym key so
                # references stay consistent *within* a request but cannot be
                # joined across research studies.
                salt = params.get("uid_salt", "")
                out[kw] = new_uid(f"{salt}|{ds.get(kw, '')}")
                actions[kw] = "hashuid"
            elif rule.action == "jitterdate":
                out[kw] = PseudonymService.jitter_date(str(ds.get(kw, "")), jitter)
                actions[kw] = "jitterdate"

        # sweeps
        if self._sweep_private and out.private:
            for tag in list(out.private):
                del out.private[tag]
            actions["<private>"] = "removeprivate"
        if self._sweep_freetext:
            for kw in FREETEXT_KEYWORDS:
                if kw in out and actions.get(kw) != "keep":
                    out.pop(kw)
                    actions[kw] = "removefreetext"
        # default policy over remaining known tags
        for kw in list(out.keys()):
            if kw in actions or kw == "PixelData":
                continue
            if self._default == "remove":
                out.pop(kw)
                actions[kw] = "default-remove"
            else:
                actions[kw] = "default-keep"
        return AnonResult(out, actions)
