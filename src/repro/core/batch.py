"""Shape-bucketed batch executor for the de-id hot path (DESIGN.md §4).

The production pipeline used to push one SOP instance at a time through
``ScrubStage.__call__`` — a device round-trip per image. A study is hundreds
of same-shape slices, so the executor restores the batching the hardware
wants:

* **bucket** — group instances by (H, W, dtype, rect-count bucket). Studies
  mix 512x512 CT with 2500x2048 DX; dispatches must be shape-uniform.
* **pad once** — each chunk pads its batch dim to a power of two (capped at
  ``max_batch``) and its rect dim to the bucket's power-of-two, so the jit
  cache only ever sees a small, closed set of padded shapes.
* **dispatch** — one fused scrub+JLS kernel call per chunk
  (``kernels/fused``: blank + predictor residuals in a single HBM pass),
  or the batched scrub kernel alone when recompression is off.
* **host tail** — sequential Golomb-Rice entropy coding stays on the host
  (``codec.rice_encode``), exactly like the paper keeps it on CPU; pixel
  blanking for the delivered object is a host rect-region write (touches
  only banner pixels, not the frame).

The executor is config-free state: it owns dispatch statistics only, so one
instance can serve every stage/pipeline combination and is safe to share
across the (single-threaded) worker pool simulation.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.dicom import codec
from repro.dicom.devices import Rect
from repro.obs.trace import NULL_TRACER

_CODEC_DTYPES = ("uint8", "uint16")


def _pow2_at_least(n: int, cap: Optional[int] = None) -> int:
    p = 1
    while p < n:
        p *= 2
    return min(p, cap) if cap is not None else p


def blank_inplace(pixels: np.ndarray, rects: Sequence[Rect]) -> np.ndarray:
    """Zero the rectangles in place (same clamping as ``scrub.numpy_blank``,
    minus the full-frame copy — callers own the array)."""
    H, W = pixels.shape[:2]
    for x, y, w, h in rects:
        pixels[max(0, y) : max(0, min(H, y + h)), max(0, x) : max(0, min(W, x + w))] = 0
    return pixels


@dataclass
class BatchOutput:
    """Per-instance result: blanked pixels + the full RJLS stream (or None
    when recompression was off)."""

    pixels: np.ndarray
    payload: Optional[bytes] = None


@dataclass
class ExecutorStats:
    instances: int = 0        # instances that went through a batched dispatch
    dispatches: int = 0       # device calls issued
    buckets: int = 0          # bucket keys seen across all runs
    padded_shapes: Set[tuple] = field(default_factory=set)  # jit-cache keys
    detect_instances: int = 0  # instances scanned by the text-band detector
    detect_dispatches: int = 0  # detector device calls issued


class BatchedDeidExecutor:
    """Groups a study's instances into shape buckets and runs the fused
    scrub+JLS kernel once per bucket chunk.

    ``use_kernel=None`` auto-detects: the fused Pallas kernel on accelerator
    backends, the host two-pass (``blank_inplace`` + ``codec.residuals``) on
    CPU — interpret-mode Pallas is a correctness stand-in, not a fast path.
    Bucketing/chunking (and the dispatch statistics) are identical either
    way, so the batching architecture is exercised on every backend.
    """

    def __init__(
        self,
        max_batch: int = 32,
        bh: int = 64,
        interpret: Optional[bool] = None,
        use_kernel: Optional[bool] = None,
        tracer=None,
    ) -> None:
        self.max_batch = max_batch
        self.bh = bh
        self.interpret = interpret
        self.use_kernel = use_kernel
        self.stats = ExecutorStats()
        # per-dispatch profiling spans (kernel.dispatch / kernel.entropy_code
        # / kernel.detect_dispatch) — the roofline measurement substrate
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def _resolve_use_kernel(self) -> bool:
        if self.use_kernel is None:
            import jax

            self.use_kernel = jax.default_backend() != "cpu"
        return self.use_kernel

    # ------------------------------------------------------------- planning
    def supports(self, pixels: Optional[np.ndarray], recompress: bool) -> bool:
        """Batchable: single-plane 2D frames; recompression further requires a
        codec dtype. Everything else takes the per-instance fallback path."""
        if pixels is None or pixels.ndim != 2:
            return False
        if recompress:
            return pixels.dtype.name in _CODEC_DTYPES
        return pixels.dtype.kind in "uif"

    def bucket(
        self, items: Sequence[Tuple[np.ndarray, Sequence[Rect]]]
    ) -> Dict[tuple, List[int]]:
        """Group item indices by (H, W, dtype, rect-count bucket)."""
        buckets: Dict[tuple, List[int]] = defaultdict(list)
        for i, (pixels, rects) in enumerate(items):
            rb = _pow2_at_least(max(len(rects), 1))
            buckets[(pixels.shape[0], pixels.shape[1], pixels.dtype.name, rb)].append(i)
        return dict(buckets)

    # ------------------------------------------------------------- dispatch
    def run(
        self,
        items: Sequence[Tuple[np.ndarray, Sequence[Rect]]],
        *,
        sv: int = 1,
        recompress: bool = True,
    ) -> List[BatchOutput]:
        """Scrub (and recompress) a heterogeneous batch.

        items: per instance (pixels, rects). Pixels are blanked in place —
        callers pass freshly copied arrays (``ScrubStage`` copies the dataset
        first). Returns outputs aligned with ``items``.
        """
        use_kernel = self._resolve_use_kernel()
        out: List[Optional[BatchOutput]] = [None] * len(items)
        buckets = self.bucket(items)
        self.stats.buckets += len(buckets)
        for (H, W, dtype_name, rb), idxs in buckets.items():
            for c0 in range(0, len(idxs), self.max_batch):
                chunk = idxs[c0 : c0 + self.max_batch]
                self.stats.dispatches += 1
                self.stats.instances += len(chunk)
                bytes_in = sum(items[i][0].nbytes for i in chunk)
                with self.tracer.span(
                    "kernel.dispatch",
                    path="fused" if use_kernel else "host",
                    batch=len(chunk),
                    shape=f"{H}x{W}",
                    dtype=dtype_name,
                    bucket=rb,
                    bytes_in=bytes_in,
                ) as sp:
                    if use_kernel:
                        self._run_kernel_chunk(items, chunk, H, W, dtype_name, rb, sv, recompress, out)
                    else:
                        self._run_host_chunk(items, chunk, H, W, sv, recompress, out)
                    sp.set(bytes_out=sum(
                        len(out[i].payload) if out[i].payload is not None else out[i].pixels.nbytes
                        for i in chunk
                    ))
        return out  # every index was bucketed exactly once

    def _run_kernel_chunk(self, items, chunk, H, W, dtype_name, rb, sv, recompress, out) -> None:
        """One fused (or scrub-only) device dispatch over a padded chunk."""
        # import here so host-only core code never pulls jax at module import
        from repro.kernels.fused.ops import fused_scrub_residuals
        from repro.kernels.scrub.ops import pack_rects, scrub_images

        n = len(chunk)
        n_pad = _pow2_at_least(n, self.max_batch)
        stack = np.zeros((n_pad, H, W), np.dtype(dtype_name))
        for j, i in enumerate(chunk):
            stack[j] = items[i][0]
        rects = np.zeros((n_pad, rb, 4), np.int32)
        rects[:n] = pack_rects([list(items[i][1]) for i in chunk], R=rb)
        self.stats.padded_shapes.add((n_pad, H, W, dtype_name, rb))

        if recompress:
            bits = np.dtype(dtype_name).itemsize * 8
            res = np.asarray(
                fused_scrub_residuals(
                    stack, rects, sv=sv, bits=bits, bh=self.bh, interpret=self.interpret
                )
            )
            # host Golomb-Rice tail — the ROADMAP's entropy-coding bottleneck;
            # its own span so a trace shows device vs host time per chunk
            with self.tracer.span("kernel.entropy_code", batch=len(chunk)) as sp:
                total = 0
                for j, i in enumerate(chunk):
                    pixels, rl = items[i]
                    blank_inplace(pixels, rl)
                    payload, k = codec.rice_encode(res[j])
                    total += len(payload)
                    out[i] = BatchOutput(
                        pixels=pixels,
                        payload=codec.pack_header(H, W, bits, sv, k, len(payload)) + payload,
                    )
                sp.set(bytes_out=total)
        else:
            scrubbed = np.asarray(scrub_images(stack, rects))
            for j, i in enumerate(chunk):
                pixels = items[i][0]
                pixels[...] = scrubbed[j]
                out[i] = BatchOutput(pixels=pixels)

    # ------------------------------------------------------------- detection
    def detect_row_hits(
        self,
        entries: Sequence[Tuple[np.ndarray, float]],
        *,
        tile: Tuple[int, int] = (32, 128),
    ) -> List[np.ndarray]:
        """Batched text-band profile pass for the burned-in-PHI detector.

        entries: per instance (2D pixels, binarization threshold). Instances
        are bucketed by (H, W, dtype, threshold) — the detector rides the
        same shape-uniform dispatch discipline as the scrub kernel — and each
        chunk is one ``kernels/textdetect`` call (Pallas on accelerators, the
        bit-identical numpy oracle on CPU). Returns per-instance (H,) int32
        row glyph-hit profiles aligned with ``entries``.
        """
        use_kernel = self._resolve_use_kernel()
        out: List[Optional[np.ndarray]] = [None] * len(entries)
        buckets: Dict[tuple, List[int]] = defaultdict(list)
        for i, (pixels, thresh) in enumerate(entries):
            buckets[(pixels.shape[0], pixels.shape[1], pixels.dtype.name, float(thresh))].append(i)
        for (H, W, dtype_name, thresh), idxs in buckets.items():
            for c0 in range(0, len(idxs), self.max_batch):
                chunk = idxs[c0 : c0 + self.max_batch]
                self.stats.detect_dispatches += 1
                self.stats.detect_instances += len(chunk)
                with self.tracer.span(
                    "kernel.detect_dispatch",
                    path="textdetect" if use_kernel else "oracle",
                    batch=len(chunk),
                    shape=f"{H}x{W}",
                    dtype=dtype_name,
                    bytes_in=sum(entries[i][0].nbytes for i in chunk),
                ):
                    if use_kernel:
                        from repro.kernels.textdetect.ops import row_hit_profile

                        # pad the batch dim like the fused path: the jit cache
                        # only ever sees a small closed set of padded shapes
                        n_pad = _pow2_at_least(len(chunk), self.max_batch)
                        stack = np.zeros((n_pad, H, W), np.dtype(dtype_name))
                        for j, i in enumerate(chunk):
                            stack[j] = entries[i][0]
                        self.stats.padded_shapes.add((n_pad, H, W, dtype_name, "detect"))
                        hits = row_hit_profile(
                            stack, thresh=thresh, tile=tile, interpret=self.interpret
                        )
                    else:
                        stack = np.stack([entries[i][0] for i in chunk])
                        from repro.kernels.textdetect.ref import row_hits_np

                        hits = row_hits_np(stack, thresh, tile)
                    for j, i in enumerate(chunk):
                        out[i] = hits[j]
        return out  # every index was bucketed exactly once

    def _run_host_chunk(self, items, chunk, H, W, sv, recompress, out) -> None:
        """CPU fallback: same bucket walk, numpy blank + codec residuals."""
        for i in chunk:
            pixels, rl = items[i]
            blank_inplace(pixels, rl)
            if recompress:
                bits = pixels.dtype.itemsize * 8
                payload, k = codec.rice_encode(codec.residuals(pixels, sv))
                out[i] = BatchOutput(
                    pixels=pixels,
                    payload=codec.pack_header(H, W, bits, sv, k, len(payload)) + payload,
                )
            else:
                out[i] = BatchOutput(pixels=pixels)
