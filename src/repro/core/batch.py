"""Shape-bucketed, pipelined batch executor for the de-id hot path
(DESIGN.md §4, §12).

The production pipeline used to push one SOP instance at a time through
``ScrubStage.__call__`` — a device round-trip per image. A study is hundreds
of same-shape slices, so the executor restores the batching the hardware
wants:

* **bucket** — group instances by (H, W, dtype, rect-count bucket). Studies
  mix 512x512 CT with 2500x2048 DX; dispatches must be shape-uniform.
* **pad once** — each chunk pads its batch dim to a power of two (capped at
  ``max_batch``, itself normalized to a power of two) and its rect dim to
  the bucket's power-of-two, so the jit cache only ever sees a small,
  closed set of padded shapes.
* **dispatch** — one fused scrub+JLS kernel call per chunk
  (``kernels/fused``: blank + predictor residuals in a single HBM pass),
  or the batched scrub kernel alone when recompression is off.
* **pipeline** — ``run`` is split into submit/collect with up to
  ``pipeline_depth`` chunks in flight: the device dispatch of chunk N+1 is
  issued (jax dispatch is asynchronous) before the host entropy tail of
  chunk N is drained, so device and host work overlap instead of
  serializing. On the kernel path the device also runs the Golomb-Rice
  *plan* pre-pass (``kernels/jls/entropy``: zigzag + row sums, then
  per-symbol code lengths + remainders), leaving the host only the final
  unary splice (``codec.rice_pack``).
* **host tail** — per-instance pack/encode jobs are embarrassingly parallel
  and fan out across a small thread pool (numpy releases the GIL); jobs are
  pure functions of per-instance arrays and are drained in submission
  order, so payload bytes are identical for any pool size — including the
  inline ``host_workers=0`` mode.

The executor owns dispatch statistics and a lazily created pack pool; one
instance can serve every stage/pipeline combination and is safe to share
across the (single-threaded) worker pool simulation.
"""
from __future__ import annotations

import math
import os
from collections import defaultdict, deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.dicom import codec
from repro.dicom.devices import Rect
from repro.obs.metrics import Gauge, StatsShim
from repro.obs.trace import NULL_TRACER

_CODEC_DTYPES = ("uint8", "uint16")


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pow2_at_least(n: int, cap: Optional[int] = None) -> int:
    p = 1
    while p < n:
        p *= 2
    if cap is not None:
        # the cap itself must be a power of two or min() could hand back a
        # non-power-of-two batch dim, silently growing the jit-cache shape set
        p = min(p, _pow2_floor(cap))
    return p


def blank_inplace(pixels: np.ndarray, rects: Sequence[Rect]) -> np.ndarray:
    """Zero the rectangles in place (same clamping as ``scrub.numpy_blank``,
    minus the full-frame copy — callers own the array)."""
    H, W = pixels.shape[:2]
    for x, y, w, h in rects:
        pixels[max(0, y) : max(0, min(H, y + h)), max(0, x) : max(0, min(W, x + w))] = 0
    return pixels


@dataclass
class BatchOutput:
    """Per-instance result: blanked pixels + the full RJLS stream (or None
    when recompression was off)."""

    pixels: np.ndarray
    payload: Optional[bytes] = None


class _GaugeSet(set):
    """Set whose cardinality mirrors into a gauge on every mutation — keeps
    the historical ``stats.bucket_keys``/``padded_shapes`` set surface (adds,
    membership, iteration) while the count lives in the metrics plane."""

    def __init__(self, gauge: Gauge):
        super().__init__()
        self._gauge = gauge

    def _sync(self) -> None:
        self._gauge.set(len(self))

    def add(self, item) -> None:
        super().add(item)
        self._sync()

    def update(self, *others) -> None:
        super().update(*others)
        self._sync()

    def discard(self, item) -> None:
        super().discard(item)
        self._sync()

    def clear(self) -> None:
        super().clear()
        self._sync()


class ExecutorStats(StatsShim):
    """Dispatch accounting for :class:`BatchedDeidExecutor`, backed by the
    metrics registry (the last ad-hoc stats dataclass to migrate).

    Counter fields keep their exact historical meaning; ``bucket_keys`` and
    ``padded_shapes`` remain real sets (distinct-key semantics) whose sizes
    are exported as gauges. ``MetricsConservation`` cross-checks the
    registry's ``repro_executor_instances`` total against the worker pool's
    independently kept per-worker dispatch deltas.
    """

    _SUBSYSTEM = "executor"
    _FIELDS = (
        "instances",         # instances that went through a batched dispatch
        "dispatches",        # device calls issued
        "dispatch_groups",   # (run, bucket) groups — counts repeats per run
        "detect_instances",  # instances scanned by the text-band detector
        "detect_dispatches", # detector device calls issued
    )

    def __init__(self, registry=None) -> None:
        super().__init__(registry)
        # distinct keys ever / jit-cache keys
        self.bucket_keys: Set[tuple] = _GaugeSet(
            Gauge("repro_executor_bucket_keys", registry=self.registry))
        self.padded_shapes: Set[tuple] = _GaugeSet(
            Gauge("repro_executor_padded_shapes", registry=self.registry))

    @property
    def buckets(self) -> int:
        """Distinct bucket keys seen across all runs (repeat keys in later
        runs don't re-count — ``dispatch_groups`` has the per-run tally)."""
        return len(self.bucket_keys)


class _Chunk:
    """One in-flight dispatch: device handles + pending host pack jobs."""

    __slots__ = (
        "idxs", "H", "W", "dtype_name", "rb", "bits", "kind",
        "res", "u", "rs", "scrubbed", "jobs", "t_submit",
    )

    def __init__(self, idxs, H, W, dtype_name, rb):
        self.idxs = idxs
        self.H, self.W, self.dtype_name, self.rb = H, W, dtype_name, rb
        self.bits = np.dtype(dtype_name).itemsize * 8
        self.kind = "done"
        self.res = self.u = self.rs = self.scrubbed = None
        self.jobs: Optional[list] = None
        self.t_submit: Optional[float] = None


class BatchedDeidExecutor:
    """Groups a study's instances into shape buckets and runs the fused
    scrub+JLS kernel once per bucket chunk, pipelined against the host
    entropy tail.

    ``use_kernel=None`` auto-detects: the fused Pallas kernel on accelerator
    backends, the host two-pass (``blank_inplace`` + ``codec.residuals``) on
    CPU — interpret-mode Pallas is a correctness stand-in, not a fast path.
    Bucketing/chunking (and the dispatch statistics) are identical either
    way, so the batching architecture is exercised on every backend.

    ``pipeline_depth`` is the max number of chunks in flight (1 disables
    overlap — strict submit-then-collect). ``host_workers`` sizes the pack
    pool (None auto-sizes, 0 runs pack jobs inline on the collect thread).
    ``device_entropy`` gates the Pallas Rice plan pre-pass (None follows
    ``use_kernel``). None of these change a single output byte — only where
    and when the work runs.
    """

    def __init__(
        self,
        max_batch: int = 32,
        bh: int = 64,
        interpret: Optional[bool] = None,
        use_kernel: Optional[bool] = None,
        tracer=None,
        host_workers: Optional[int] = None,
        pipeline_depth: int = 2,
        device_entropy: Optional[bool] = None,
        registry=None,
    ) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        # normalize to a power of two so every padded batch dim stays inside
        # the closed jit-cache shape set (a cap of e.g. 24 would otherwise
        # leak non-power-of-two shapes through _pow2_at_least)
        self.max_batch = _pow2_floor(max_batch)
        self.bh = bh
        self.interpret = interpret
        self.use_kernel = use_kernel
        self.host_workers = host_workers
        self.pipeline_depth = pipeline_depth
        self.device_entropy = device_entropy
        self.stats = ExecutorStats(registry)
        # per-dispatch profiling spans (kernel.dispatch / kernel.entropy_code
        # / kernel.detect_dispatch) — the roofline measurement substrate
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._pool: Optional[ThreadPoolExecutor] = None

    def _resolve_use_kernel(self) -> bool:
        if self.use_kernel is None:
            import jax

            self.use_kernel = jax.default_backend() != "cpu"
        return self.use_kernel

    def _use_device_entropy(self, use_kernel: bool) -> bool:
        if self.device_entropy is not None:
            return bool(self.device_entropy) and use_kernel
        return use_kernel

    # ------------------------------------------------------------ pack pool
    def _resolve_workers(self) -> int:
        if self.host_workers is not None:
            return max(0, int(self.host_workers))
        return min(4, os.cpu_count() or 1)

    def _ensure_pool(self) -> Optional[ThreadPoolExecutor]:
        if self._resolve_workers() <= 0:
            return None
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._resolve_workers(), thread_name_prefix="rice-pack"
            )
        return self._pool

    def close(self) -> None:
        """Shut down the pack pool (idempotent; the executor stays usable —
        the pool is recreated lazily on the next run)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def _submit_jobs(self, fns) -> list:
        """Queue pure per-instance pack jobs; inline thunks when pool is off.
        Job order == chunk order either way, so drain order (and therefore
        every output byte) is independent of the pool size."""
        pool = self._ensure_pool()
        if pool is None:
            return list(fns)  # evaluated lazily, in order, on collect
        return [pool.submit(fn) for fn in fns]

    @staticmethod
    def _job_result(job):
        return job.result() if hasattr(job, "result") else job()

    # ------------------------------------------------------------- planning
    def supports(self, pixels: Optional[np.ndarray], recompress: bool) -> bool:
        """Batchable: single-plane 2D frames; recompression further requires a
        codec dtype. Everything else takes the per-instance fallback path."""
        if pixels is None or pixels.ndim != 2:
            return False
        if recompress:
            return pixels.dtype.name in _CODEC_DTYPES
        return pixels.dtype.kind in "uif"

    def bucket(
        self, items: Sequence[Tuple[np.ndarray, Sequence[Rect]]]
    ) -> Dict[tuple, List[int]]:
        """Group item indices by (H, W, dtype, rect-count bucket)."""
        buckets: Dict[tuple, List[int]] = defaultdict(list)
        for i, (pixels, rects) in enumerate(items):
            rb = _pow2_at_least(max(len(rects), 1))
            buckets[(pixels.shape[0], pixels.shape[1], pixels.dtype.name, rb)].append(i)
        return dict(buckets)

    # ------------------------------------------------------------- dispatch
    def run(
        self,
        items: Sequence[Tuple[np.ndarray, Sequence[Rect]]],
        *,
        sv: int = 1,
        recompress: bool = True,
    ) -> List[BatchOutput]:
        """Scrub (and recompress) a heterogeneous batch.

        items: per instance (pixels, rects). Pixels are blanked in place —
        callers pass freshly copied arrays (``ScrubStage`` copies the dataset
        first). Returns outputs aligned with ``items``.

        Submission and collection are pipelined: up to ``pipeline_depth``
        chunks are dispatched (device work queued asynchronously) before the
        oldest chunk's host entropy tail is drained, and chunks are always
        collected in submission order. On any failure the in-flight pack
        jobs are cancelled and the exception propagates — callers never see
        a partially filled output list.
        """
        use_kernel = self._resolve_use_kernel()
        out: List[Optional[BatchOutput]] = [None] * len(items)
        buckets = self.bucket(items)
        self.stats.bucket_keys.update(buckets.keys())
        self.stats.dispatch_groups += len(buckets)
        depth = max(1, int(self.pipeline_depth))
        inflight: deque = deque()
        try:
            for (H, W, dtype_name, rb), idxs in buckets.items():
                for c0 in range(0, len(idxs), self.max_batch):
                    chunk = idxs[c0 : c0 + self.max_batch]
                    inflight.append(
                        self._submit_chunk(
                            items, chunk, H, W, dtype_name, rb, sv, recompress, use_kernel
                        )
                    )
                    while len(inflight) >= depth:
                        self._collect_chunk(items, inflight.popleft(), sv, out)
            while inflight:
                self._collect_chunk(items, inflight.popleft(), sv, out)
        except BaseException:
            # crash containment: nothing submitted may leak — cancel queued
            # pack jobs (running ones are pure and write no shared state)
            # and let the exception escape with `out` discarded.
            for st in inflight:
                for job in st.jobs or ():
                    if hasattr(job, "cancel"):
                        job.cancel()
            raise
        return out  # every index was bucketed exactly once

    # -- submit phase ------------------------------------------------------
    def _submit_chunk(
        self, items, chunk, H, W, dtype_name, rb, sv, recompress, use_kernel
    ) -> _Chunk:
        st = _Chunk(chunk, H, W, dtype_name, rb)
        clk = getattr(self.tracer, "clock", None)
        st.t_submit = clk.now() if clk is not None else None
        self.stats.dispatches += 1
        self.stats.instances += len(chunk)
        bytes_in = sum(items[i][0].nbytes for i in chunk)
        with self.tracer.span(
            "kernel.dispatch",
            path="fused" if use_kernel else "host",
            batch=len(chunk),
            shape=f"{H}x{W}",
            dtype=dtype_name,
            bucket=rb,
            bytes_in=bytes_in,
        ):
            if use_kernel:
                self._submit_kernel(items, st, sv, recompress)
            else:
                self._submit_host(items, st, sv, recompress)
        return st

    def _submit_kernel(self, items, st, sv, recompress) -> None:
        """Issue the fused (or scrub-only) device dispatch for one padded
        chunk; device values stay asynchronous until collect."""
        # import here so host-only core code never pulls jax at module import
        from repro.kernels.fused.ops import fused_scrub_residuals
        from repro.kernels.scrub.ops import pack_rects, scrub_images

        chunk, H, W = st.idxs, st.H, st.W
        n = len(chunk)
        n_pad = _pow2_at_least(n, self.max_batch)
        stack = np.zeros((n_pad, H, W), np.dtype(st.dtype_name))
        for j, i in enumerate(chunk):
            stack[j] = items[i][0]
        rects = np.zeros((n_pad, st.rb, 4), np.int32)
        rects[:n] = pack_rects([list(items[i][1]) for i in chunk], R=st.rb)
        self.stats.padded_shapes.add((n_pad, H, W, st.dtype_name, st.rb))

        if recompress:
            res = fused_scrub_residuals(
                stack, rects, sv=sv, bits=st.bits, bh=self.bh, interpret=self.interpret
            )
            if self._use_device_entropy(True):
                from repro.kernels.jls import entropy

                st.u, st.rs = entropy.rice_prepass(
                    res, bh=self.bh, interpret=self.interpret
                )
                st.kind = "device_plan"
            else:
                st.res = res
                st.kind = "device_res"
            # host-side pixel blanking for the delivered object (banner
            # pixels only) happens at submit so collect is pure codec work
            for i in chunk:
                blank_inplace(items[i][0], items[i][1])
        else:
            st.scrubbed = scrub_images(stack, rects)
            st.kind = "scrub_only"

    def _submit_host(self, items, st, sv, recompress) -> None:
        """CPU path: blank + batched residuals now, queue the encode tail."""
        chunk = st.idxs
        for i in chunk:
            blank_inplace(items[i][0], items[i][1])
        if recompress:
            # per-instance residuals (not residuals_batch): one plane's int64
            # intermediates stay cache-resident, a whole chunk's do not
            st.jobs = self._submit_jobs(
                [
                    lambda px=items[i][0]: codec.rice_encode(codec.residuals(px, sv))
                    for i in chunk
                ]
            )
            st.kind = "host_encode"
        else:
            st.kind = "done"

    # -- collect phase -----------------------------------------------------
    def _collect_chunk(self, items, st: _Chunk, sv, out) -> None:
        chunk, H, W = st.idxs, st.H, st.W
        clk = getattr(self.tracer, "clock", None)

        if st.kind == "done":
            for i in chunk:
                out[i] = BatchOutput(pixels=items[i][0])
            return

        if st.kind == "scrub_only":
            scrubbed = np.asarray(st.scrubbed)  # blocks on the device here
            for j, i in enumerate(chunk):
                pixels = items[i][0]
                pixels[...] = scrubbed[j]
                out[i] = BatchOutput(pixels=pixels)
            return

        # recompress paths: the host Golomb-Rice tail — its own span so a
        # trace shows the host/device boundary (queue_s = how long the chunk
        # sat in flight behind newer dispatches, wait_s = device sync time)
        # NB: pool size / pipeline depth are deliberately NOT span attrs —
        # the trace digest must be identical for any host_workers setting
        with self.tracer.span(
            "kernel.entropy_code", batch=len(chunk), path=st.kind
        ) as sp:
            t0 = clk.now() if clk is not None else None
            if st.kind == "device_plan":
                from repro.kernels.jls import entropy

                rs = np.asarray(st.rs)  # device sync point
                ks = np.array(
                    [
                        codec._rice_k_from_sum(int(rs[j].sum()), H * W)
                        for j in range(len(chunk))
                    ],
                    np.int32,
                )
                lens_d, rem_d = entropy.rice_len_rem(
                    st.u, ks, bh=self.bh, interpret=self.interpret
                )
                u_np = np.asarray(st.u).reshape(st.u.shape[0], -1)
                lens_np, rem_np = np.asarray(lens_d), np.asarray(rem_d)
                st.jobs = self._submit_jobs(
                    [
                        lambda j=j: codec.rice_pack(
                            codec.rice_plan_from_prepass(
                                u_np[j], int(ks[j]), lens_np[j], rem_np[j]
                            )
                        )
                        for j in range(len(chunk))
                    ]
                )
                kparams = [int(k) for k in ks]
            elif st.kind == "device_res":
                res = np.asarray(st.res)  # device sync point
                st.jobs = self._submit_jobs(
                    [lambda rj=res[j]: codec.rice_encode(rj) for j in range(len(chunk))]
                )
                kparams = None
            else:  # host_encode — jobs were queued at submit
                kparams = None
            t1 = clk.now() if clk is not None else None

            total = 0
            for j, i in enumerate(chunk):
                result = self._job_result(st.jobs[j])
                if kparams is not None:
                    payload, k = result, kparams[j]
                else:
                    payload, k = result
                total += len(payload)
                out[i] = BatchOutput(
                    pixels=items[i][0],
                    payload=codec.pack_header(H, W, st.bits, sv, k, len(payload))
                    + payload,
                )
            sp.set(bytes_out=total)
            if clk is not None:
                sp.set(
                    queue_s=round(t0 - st.t_submit, 9),
                    wait_s=round(t1 - t0, 9),
                )

    # ------------------------------------------------------------- detection
    def detect_row_hits(
        self,
        entries: Sequence[Tuple[np.ndarray, float]],
        *,
        tile: Tuple[int, int] = (32, 128),
    ) -> List[np.ndarray]:
        """Batched text-band profile pass for the burned-in-PHI detector.

        entries: per instance (2D pixels, binarization threshold). Instances
        are bucketed by (H, W, dtype, threshold) — the detector rides the
        same shape-uniform dispatch discipline as the scrub kernel — and each
        chunk is one ``kernels/textdetect`` call (Pallas on accelerators, the
        bit-identical numpy oracle on CPU). Returns per-instance (H,) int32
        row glyph-hit profiles aligned with ``entries``.
        """
        use_kernel = self._resolve_use_kernel()
        out: List[Optional[np.ndarray]] = [None] * len(entries)
        buckets: Dict[tuple, List[int]] = defaultdict(list)
        for i, (pixels, thresh) in enumerate(entries):
            t = float(thresh)
            # a NaN key never equals itself: every instance would land in its
            # own bucket and get a private dispatch — reject it at the door
            if not math.isfinite(t):
                raise ValueError(
                    f"detector threshold must be finite, got {t!r} (entry {i})"
                )
            buckets[(pixels.shape[0], pixels.shape[1], pixels.dtype.name, t)].append(i)
        for (H, W, dtype_name, thresh), idxs in buckets.items():
            for c0 in range(0, len(idxs), self.max_batch):
                chunk = idxs[c0 : c0 + self.max_batch]
                self.stats.detect_dispatches += 1
                self.stats.detect_instances += len(chunk)
                with self.tracer.span(
                    "kernel.detect_dispatch",
                    path="textdetect" if use_kernel else "oracle",
                    batch=len(chunk),
                    shape=f"{H}x{W}",
                    dtype=dtype_name,
                    bytes_in=sum(entries[i][0].nbytes for i in chunk),
                ):
                    if use_kernel:
                        from repro.kernels.textdetect.ops import row_hit_profile

                        # pad the batch dim like the fused path: the jit cache
                        # only ever sees a small closed set of padded shapes
                        n_pad = _pow2_at_least(len(chunk), self.max_batch)
                        stack = np.zeros((n_pad, H, W), np.dtype(dtype_name))
                        for j, i in enumerate(chunk):
                            stack[j] = entries[i][0]
                        self.stats.padded_shapes.add((n_pad, H, W, dtype_name, "detect"))
                        hits = row_hit_profile(
                            stack, thresh=thresh, tile=tile, interpret=self.interpret
                        )
                    else:
                        stack = np.stack([entries[i][0] for i in chunk])
                        from repro.kernels.textdetect.ref import row_hits_np

                        hits = row_hits_np(stack, thresh, tile)
                    for j, i in enumerate(chunk):
                        out[i] = hits[j]
        return out  # every index was bucketed exactly once
