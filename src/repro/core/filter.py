"""Filter stage: accept or discard an instance based on metadata rules.

First stage of the paper's three-stage engine (Figure 2a). A rejected image
never reaches the researcher; the manifest records which rule fired.

Value comparison contract: equals/notequals/in rules compare through
``DicomDataset.matches`` (CS normalization — case/whitespace-insensitive),
the same normalization the metadata catalog applies at ingest, so a study
selected by a catalog query is judged by the filter under identical string
semantics. ``startswith`` stays byte-exact (UID prefixes are not CS).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.rules import FilterRule, parse_filter_script, script_sha
from repro.dicom.dataset import DicomDataset


@dataclass
class FilterDecision:
    accepted: bool
    rule: Optional[str] = None  # rule line that decided (None = default accept)


class FilterStage:
    def __init__(self, script_text: str) -> None:
        self.script_text = script_text
        self.rules: List[FilterRule] = parse_filter_script(script_text)
        self.sha = script_sha(script_text)

    def __call__(self, ds: DicomDataset) -> FilterDecision:
        for rule in self.rules:
            if rule.matches(ds):
                if rule.action == "accept":
                    return FilterDecision(True, rule.line)
                return FilterDecision(False, rule.line)
        return FilterDecision(True, None)

    def explain(self, ds: DicomDataset) -> List[Tuple[str, bool]]:
        """Per-rule trace, used by the scenario runner and rule debugging."""
        return [(r.line, r.matches(ds)) for r in self.rules]
