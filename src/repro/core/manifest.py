"""Per-request manifest: "indicates the transformations applied to each image,
along with success or failure states" (paper §Method).

Manifest entries record *actions*, never original PHI values — the manifest
travels with the de-identified output into the researcher's workspace.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Tuple


class Outcome(Enum):
    ANONYMIZED = "anonymized"  # passed filter, metadata anonymized (maybe scrubbed)
    FILTERED = "filtered"      # rejected by filter stage (not delivered)
    FAILED = "failed"          # processing error


@dataclass
class ManifestEntry:
    sop_uid_anon: str
    outcome: Outcome
    modality: str = ""
    filter_rule: Optional[str] = None          # which rule rejected it
    scrub_rects: List[Tuple[int, int, int, int]] = field(default_factory=list)
    tag_actions: Dict[str, str] = field(default_factory=dict)  # keyword -> action
    recompressed: bool = False
    compressed_bytes: int = 0
    original_bytes: int = 0
    error: str = ""
    worker_id: str = ""
    script_shas: Dict[str, str] = field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {
            "sop_uid_anon": self.sop_uid_anon,
            "outcome": self.outcome.value,
            "modality": self.modality,
            "filter_rule": self.filter_rule,
            "scrub_rects": [list(r) for r in self.scrub_rects],
            "tag_actions": self.tag_actions,
            "recompressed": self.recompressed,
            "compressed_bytes": self.compressed_bytes,
            "original_bytes": self.original_bytes,
            "error": self.error,
            "worker_id": self.worker_id,
            "script_shas": self.script_shas,
        }
        return d

    @staticmethod
    def from_dict(ed: dict) -> "ManifestEntry":
        return ManifestEntry(
            sop_uid_anon=ed["sop_uid_anon"],
            outcome=Outcome(ed["outcome"]),
            modality=ed.get("modality", ""),
            filter_rule=ed.get("filter_rule"),
            scrub_rects=[tuple(r) for r in ed.get("scrub_rects", [])],
            tag_actions=ed.get("tag_actions", {}),
            recompressed=ed.get("recompressed", False),
            compressed_bytes=ed.get("compressed_bytes", 0),
            original_bytes=ed.get("original_bytes", 0),
            error=ed.get("error", ""),
            worker_id=ed.get("worker_id", ""),
            script_shas=ed.get("script_shas", {}),
        )


@dataclass
class Manifest:
    request_id: str
    entries: List[ManifestEntry] = field(default_factory=list)

    def add(self, entry: ManifestEntry) -> None:
        self.entries.append(entry)

    def counts(self) -> Dict[str, int]:
        out = {o.value: 0 for o in Outcome}
        for e in self.entries:
            out[e.outcome.value] += 1
        out["scrubbed"] = sum(1 for e in self.entries if e.scrub_rects)
        return out

    def merge(self, other: "Manifest") -> None:
        self.entries.extend(other.entries)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(
            {"request_id": self.request_id, "counts": self.counts(),
             "entries": [e.to_dict() for e in self.entries]},
            indent=indent,
        )

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        m = Manifest(d["request_id"])
        for ed in d["entries"]:
            m.add(ManifestEntry.from_dict(ed))
        return m
