"""The de-identification pipeline: filter -> scrub -> anonymize (Figure 2a).

One :class:`DeidPipeline` instance is the unit each queue worker runs. It is
deliberately stateless across instances (all request state rides in the
:class:`DeidRequest`), which is what makes the horizontal scaling in
``repro.queueing``/``repro.distributed`` safe.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.anonymize import AnonymizerStage
from repro.core.batch import BatchedDeidExecutor
from repro.core.filter import FilterStage
from repro.core.manifest import Manifest, ManifestEntry, Outcome
from repro.core.pseudonym import PseudonymService, TrustMode
from repro.core.scrub import ScrubError, ScrubStage
from repro.core import scripts as default_scripts
from repro.dicom.dataset import DicomDataset
from repro.dicom.generator import SyntheticStudy


@dataclass
class DeidRequest:
    """One imaging study to de-identify under one research study's rules."""

    research_study: str        # IRB protocol / pre-IRB request id
    accession: str             # original imaging accession
    anon_accession: str
    anon_mrn: str
    jitter: int
    mode: str = TrustMode.POST_IRB.value

    def script_params(self) -> Dict[str, str]:
        return {
            "accession": self.anon_accession,
            "mrn": self.anon_mrn,
            "jitter": str(self.jitter),
            "uid_salt": f"{self.research_study}|{self.anon_accession}",
        }


def build_request(
    pseudo: PseudonymService, accession: str, mrn: str
) -> DeidRequest:
    """Central-server side: validate + mint pseudonyms for one accession
    (paper: 'a new anonymized accession number, patient MRN, and randomized
    date jitter specific to the specific research study are created')."""
    return DeidRequest(
        research_study=pseudo.study_id,
        accession=accession,
        anon_accession=pseudo.accession(accession),
        anon_mrn=pseudo.mrn(mrn),
        jitter=pseudo.jitter_for(mrn),
        mode=pseudo.mode.value,
    )


class DeidPipeline:
    def __init__(
        self,
        filter_script: Optional[str] = None,
        anonymizer_script: Optional[str] = None,
        scrub_script: Optional[str] = None,
        blank_fn=None,
        recompress: bool = True,
        batched: bool = True,
    ) -> None:
        self.filter = FilterStage(filter_script or default_scripts.DEFAULT_FILTER_SCRIPT)
        self.anonymizer = AnonymizerStage(
            anonymizer_script or default_scripts.DEFAULT_ANONYMIZER_SCRIPT
        )
        scrub_kwargs = {} if blank_fn is None else {"blank_fn": blank_fn}
        self.scrub = ScrubStage(
            scrub_script or default_scripts.DEFAULT_SCRUB_SCRIPT,
            recompress=recompress,
            **scrub_kwargs,
        )
        # shape-bucketed batch dispatch over each study's instances; the
        # per-instance loop survives as process_study_serial (fallback/oracle)
        self.executor: Optional[BatchedDeidExecutor] = (
            BatchedDeidExecutor() if batched else None
        )
        self.script_shas = {
            "filter": self.filter.sha,
            "anonymizer": self.anonymizer.sha,
            "scrubber": self.scrub.sha,
        }

    # ------------------------------------------------------------- instances
    def process_instance(
        self, ds: DicomDataset, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[Optional[DicomDataset], ManifestEntry]:
        """Run one SOP instance through the three stages."""
        params = request.script_params()
        try:
            decision = self.filter(ds)
            if not decision.accepted:
                entry = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FILTERED,
                    modality=str(ds.get("Modality", "")),
                    filter_rule=decision.rule,
                    original_bytes=ds.nbytes(),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )
                return None, entry

            scrubbed = self.scrub(ds)
            anon = self.anonymizer(scrubbed.dataset, params)
            entry = ManifestEntry(
                sop_uid_anon=str(anon.dataset.get("SOPInstanceUID", "")),
                outcome=Outcome.ANONYMIZED,
                modality=str(ds.get("Modality", "")),
                scrub_rects=list(scrubbed.rects),
                tag_actions=anon.tag_actions,
                recompressed=scrubbed.recompressed,
                compressed_bytes=scrubbed.compressed_bytes,
                original_bytes=ds.nbytes(),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            return anon.dataset, entry
        except ScrubError as e:
            entry = ManifestEntry(
                sop_uid_anon="",
                outcome=Outcome.FAILED,
                modality=str(ds.get("Modality", "")),
                original_bytes=ds.nbytes(),
                error=str(e),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            return None, entry

    # --------------------------------------------------------------- studies
    def process_study(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[List[DicomDataset], Manifest]:
        """De-identify every instance of a study.

        Routes through the shape-bucketed :class:`BatchedDeidExecutor` by
        default: filter everything, scrub the survivors in fused-kernel
        batches, then anonymize. Delivered order and manifest contents are
        identical to :meth:`process_study_serial` (tested), which remains the
        per-instance fallback/oracle path.
        """
        if self.executor is None:
            return self.process_study_serial(study, request, worker_id)
        manifest = Manifest(request_id=f"{request.research_study}/{request.anon_accession}")
        delivered: List[DicomDataset] = []
        params = request.script_params()
        entries: List[Optional[ManifestEntry]] = [None] * len(study.datasets)
        accepted: List[Tuple[int, DicomDataset]] = []
        for i, ds in enumerate(study.datasets):
            decision = self.filter(ds)
            if decision.accepted:
                accepted.append((i, ds))
            else:
                entries[i] = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FILTERED,
                    modality=str(ds.get("Modality", "")),
                    filter_rule=decision.rule,
                    original_bytes=ds.nbytes(),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )

        slots = self.scrub.scrub_study([ds for _, ds in accepted], self.executor)
        for (i, ds), (scrubbed, err) in zip(accepted, slots):
            if err is None:
                try:
                    anon = self.anonymizer(scrubbed.dataset, params)
                except ScrubError as e:  # parity with process_instance's catch scope
                    err = e
            if err is not None:
                entries[i] = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FAILED,
                    modality=str(ds.get("Modality", "")),
                    original_bytes=ds.nbytes(),
                    error=str(err),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )
                continue
            entries[i] = ManifestEntry(
                sop_uid_anon=str(anon.dataset.get("SOPInstanceUID", "")),
                outcome=Outcome.ANONYMIZED,
                modality=str(ds.get("Modality", "")),
                scrub_rects=list(scrubbed.rects),
                tag_actions=anon.tag_actions,
                recompressed=scrubbed.recompressed,
                compressed_bytes=scrubbed.compressed_bytes,
                original_bytes=ds.nbytes(),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            delivered.append(anon.dataset)  # accepted is in dataset order
        for entry in entries:
            assert entry is not None
            manifest.add(entry)
        return delivered, manifest

    def process_study_serial(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[List[DicomDataset], Manifest]:
        """Per-instance oracle path (the pre-batching hot loop)."""
        manifest = Manifest(request_id=f"{request.research_study}/{request.anon_accession}")
        delivered: List[DicomDataset] = []
        for ds in study.datasets:
            out, entry = self.process_instance(ds, request, worker_id)
            manifest.add(entry)
            if out is not None:
                delivered.append(out)
        return delivered, manifest
