"""The de-identification pipeline: filter -> scrub -> anonymize (Figure 2a).

One :class:`DeidPipeline` instance is the unit each queue worker runs. It is
deliberately stateless across instances (all request state rides in the
:class:`DeidRequest`), which is what makes the horizontal scaling in
``repro.queueing``/``repro.distributed`` safe.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import DEID_EXECUTE
from repro.core.anonymize import AnonymizerStage
from repro.core.batch import BatchedDeidExecutor
from repro.core.filter import FilterStage
from repro.core.manifest import Manifest, ManifestEntry, Outcome
from repro.core.pseudonym import PseudonymService, TrustMode
from repro.core.scrub import ScrubError, ScrubStage
from repro.core import scripts as default_scripts
from repro.dicom.dataset import DicomDataset
from repro.dicom.generator import SyntheticStudy
from repro.obs.trace import NULL_TRACER

if TYPE_CHECKING:  # type-only: repro.lake imports stay lazy (no import cycle)
    from repro.lake.fingerprint import RulesetFingerprint
    from repro.lake.store import ResultLake


@dataclass
class DeidRequest:
    """One imaging study to de-identify under one research study's rules."""

    research_study: str        # IRB protocol / pre-IRB request id
    accession: str             # original imaging accession
    anon_accession: str
    anon_mrn: str
    jitter: int
    mode: str = TrustMode.POST_IRB.value

    def script_params(self) -> Dict[str, str]:
        return {
            "accession": self.anon_accession,
            "mrn": self.anon_mrn,
            "jitter": str(self.jitter),
            "uid_salt": f"{self.research_study}|{self.anon_accession}",
        }


def build_request(
    pseudo: PseudonymService, accession: str, mrn: str
) -> DeidRequest:
    """Central-server side: validate + mint pseudonyms for one accession
    (paper: 'a new anonymized accession number, patient MRN, and randomized
    date jitter specific to the specific research study are created')."""
    return DeidRequest(
        research_study=pseudo.study_id,
        accession=accession,
        anon_accession=pseudo.accession(accession),
        anon_mrn=pseudo.mrn(mrn),
        jitter=pseudo.jitter_for(mrn),
        mode=pseudo.mode.value,
    )


@dataclass
class StudyDeidResult:
    """Everything one study de-identification produced.

    ``instance_keys`` is aligned with the study's datasets and empty when no
    result lake is attached; ``cache_hits``/``cache_misses`` count per-instance
    lake lookups for this study only.
    """

    delivered: List[DicomDataset]
    manifest: Manifest
    instance_keys: List[str] = field(default_factory=list)
    cache_hits: int = 0
    cache_misses: int = 0


class DeidPipeline:
    def __init__(
        self,
        filter_script: Optional[str] = None,
        anonymizer_script: Optional[str] = None,
        scrub_script: Optional[str] = None,
        blank_fn=None,
        recompress: bool = True,
        batched: bool = True,
        lake: Optional["ResultLake"] = None,
        detector_policy=None,
        tracer=None,
        registry=None,
        ledger=None,
    ) -> None:
        self.filter = FilterStage(filter_script or default_scripts.DEFAULT_FILTER_SCRIPT)
        self.anonymizer = AnonymizerStage(
            anonymizer_script or default_scripts.DEFAULT_ANONYMIZER_SCRIPT
        )
        scrub_kwargs = {} if blank_fn is None else {"blank_fn": blank_fn}
        self.scrub = ScrubStage(
            scrub_script or default_scripts.DEFAULT_SCRUB_SCRIPT,
            recompress=recompress,
            policy=detector_policy,
            registry=registry,
            ledger=ledger,
            **scrub_kwargs,
        )
        # deterministic tracing (repro.obs): run_study opens per-study spans;
        # the executor emits per-dispatch kernel profiling spans under them
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # audit ledger (repro.audit): one deid_execute record per run_study
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        # shape-bucketed batch dispatch over each study's instances; the
        # per-instance loop survives as process_study_serial (fallback/oracle)
        self.executor: Optional[BatchedDeidExecutor] = (
            BatchedDeidExecutor(tracer=self.tracer, registry=registry)
            if batched else None
        )
        self.script_shas = {
            "filter": self.filter.sha,
            "anonymizer": self.anonymizer.sha,
            "scrubber": self.scrub.sha,
        }
        # optional content-addressed result cache (DESIGN.md §6); per-instance
        # short-circuit happens in run_study, workers write study records back
        self.lake = lake
        self._fingerprint: Optional["RulesetFingerprint"] = None

    def ruleset_fingerprint(self) -> "RulesetFingerprint":
        """Fingerprint of this pipeline's full rule surface (scripts + device
        scrub geometry + output-shaping config). Computed once: scripts and
        config are immutable per pipeline."""
        if self._fingerprint is None:
            from repro.lake.fingerprint import RulesetFingerprint, callable_identity

            config = (
                f"recompress={self.scrub.recompress}|sv={self.scrub.sv}|"
                f"blank={callable_identity(self.scrub.blank_fn)}"
            )
            # detector version + policy knobs: editing either must force a
            # cold serve (DESIGN.md §9) — "" preserves pre-detector keys for
            # pipelines with no policy attached AND for mode="off" (whose
            # delivered bytes are byte-identical to the legacy path, tested)
            detector = (
                self.scrub.policy.fingerprint_identity
                if self.scrub.policy is not None
                else ""
            )
            self._fingerprint = RulesetFingerprint.of(
                self.script_shas, config=config, detector=detector
            )
        return self._fingerprint

    # ------------------------------------------------------------- instances
    def process_instance(
        self, ds: DicomDataset, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[Optional[DicomDataset], ManifestEntry]:
        """Run one SOP instance through the three stages."""
        params = request.script_params()
        try:
            decision = self.filter(ds)
            if not decision.accepted:
                entry = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FILTERED,
                    modality=str(ds.get("Modality", "")),
                    filter_rule=decision.rule,
                    original_bytes=ds.nbytes(),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )
                return None, entry

            scrubbed = self.scrub(ds)
            anon = self.anonymizer(scrubbed.dataset, params)
            entry = ManifestEntry(
                sop_uid_anon=str(anon.dataset.get("SOPInstanceUID", "")),
                outcome=Outcome.ANONYMIZED,
                modality=str(ds.get("Modality", "")),
                scrub_rects=list(scrubbed.rects),
                tag_actions=anon.tag_actions,
                recompressed=scrubbed.recompressed,
                compressed_bytes=scrubbed.compressed_bytes,
                original_bytes=ds.nbytes(),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            return anon.dataset, entry
        except ScrubError as e:
            entry = ManifestEntry(
                sop_uid_anon="",
                outcome=Outcome.FAILED,
                modality=str(ds.get("Modality", "")),
                original_bytes=ds.nbytes(),
                error=str(e),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            return None, entry

    # --------------------------------------------------------------- studies
    def _deid_datasets(
        self, datasets: Sequence[DicomDataset], request: DeidRequest, worker_id: str
    ) -> List[Tuple[Optional[DicomDataset], ManifestEntry]]:
        """Run the three stages over a list of instances, returning aligned
        (delivered-or-None, entry) pairs. Uses the shape-bucketed executor
        when attached; falls back to the per-instance path otherwise."""
        if self.executor is None:
            return [self.process_instance(ds, request, worker_id) for ds in datasets]
        params = request.script_params()
        pairs: List[Optional[Tuple[Optional[DicomDataset], ManifestEntry]]] = [
            None
        ] * len(datasets)
        accepted: List[Tuple[int, DicomDataset]] = []
        for i, ds in enumerate(datasets):
            decision = self.filter(ds)
            if decision.accepted:
                accepted.append((i, ds))
            else:
                entry = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FILTERED,
                    modality=str(ds.get("Modality", "")),
                    filter_rule=decision.rule,
                    original_bytes=ds.nbytes(),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )
                pairs[i] = (None, entry)

        slots = self.scrub.scrub_study([ds for _, ds in accepted], self.executor)
        for (i, ds), (scrubbed, err) in zip(accepted, slots):
            if err is None:
                try:
                    anon = self.anonymizer(scrubbed.dataset, params)
                except ScrubError as e:  # parity with process_instance's catch scope
                    err = e
            if err is not None:
                entry = ManifestEntry(
                    sop_uid_anon="",
                    outcome=Outcome.FAILED,
                    modality=str(ds.get("Modality", "")),
                    original_bytes=ds.nbytes(),
                    error=str(err),
                    worker_id=worker_id,
                    script_shas=self.script_shas,
                )
                pairs[i] = (None, entry)
                continue
            entry = ManifestEntry(
                sop_uid_anon=str(anon.dataset.get("SOPInstanceUID", "")),
                outcome=Outcome.ANONYMIZED,
                modality=str(ds.get("Modality", "")),
                scrub_rects=list(scrubbed.rects),
                tag_actions=anon.tag_actions,
                recompressed=scrubbed.recompressed,
                compressed_bytes=scrubbed.compressed_bytes,
                original_bytes=ds.nbytes(),
                worker_id=worker_id,
                script_shas=self.script_shas,
            )
            pairs[i] = (anon.dataset, entry)
        for p in pairs:  # loud, not silent: a dropped slot is a lost instance
            assert p is not None
        return pairs  # type: ignore[return-value]

    def run_study(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str = ""
    ) -> StudyDeidResult:
        """De-identify every instance of a study.

        With a result lake attached, each instance is first looked up by its
        content-addressed key — hits replay the cached result (byte-identical
        to the cold path, tested) and only the cold remainder flows through
        filter/scrub/anonymize; fresh results are written back. Without a
        lake this is the plain batched path.
        """
        manifest = Manifest(request_id=f"{request.research_study}/{request.anon_accession}")
        with self.tracer.span(
            "pipeline.run_study",
            accession=request.accession,
            instances=len(study.datasets),
        ) as _study_span:
            result = self._run_study_traced(study, request, worker_id, manifest, _study_span)
        return result

    def _run_study_traced(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str,
        manifest: Manifest, _study_span,
    ) -> StudyDeidResult:
        if self.lake is None:
            pairs = self._deid_datasets(study.datasets, request, worker_id)
            result = StudyDeidResult([], manifest)
        else:
            from repro.lake.fingerprint import cache_key, instance_digest, request_salt
            from repro.lake.records import decode_instance_record, encode_instance_record

            ruleset = self.ruleset_fingerprint().digest
            salt = request_salt(request)
            keys = [
                cache_key(instance_digest(ds), ruleset, salt) for ds in study.datasets
            ]
            slots: List[Optional[Tuple[Optional[DicomDataset], ManifestEntry]]] = [
                None
            ] * len(keys)
            cold: List[int] = []
            for i, key in enumerate(keys):
                blob = self.lake.get(key)
                if blob is None:
                    cold.append(i)
                else:
                    slots[i] = decode_instance_record(blob)
            cold_pairs = self._deid_datasets(
                [study.datasets[i] for i in cold], request, worker_id
            )
            assert len(cold_pairs) == len(cold)
            for i, pair in zip(cold, cold_pairs):
                slots[i] = pair
                self.lake.put(keys[i], encode_instance_record(*pair))
            for s in slots:  # every instance is either a hit or a cold result
                assert s is not None
            pairs = slots  # type: ignore[assignment]
            result = StudyDeidResult(
                [], manifest, instance_keys=keys,
                cache_hits=len(keys) - len(cold), cache_misses=len(cold),
            )
        _study_span.set(lake_hits=result.cache_hits, cold=result.cache_misses)
        for out, entry in pairs:
            manifest.add(entry)
            if out is not None:
                result.delivered.append(out)
        self.ledger.append(
            DEID_EXECUTE,
            accession=request.accession,
            project=request.research_study,
            instances=len(study.datasets),
            lake_hits=result.cache_hits,
            cold=result.cache_misses,
            ruleset=self.ruleset_fingerprint().digest,
        )
        return result

    def process_study(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[List[DicomDataset], Manifest]:
        """Tuple façade over :meth:`run_study`. Delivered order and manifest
        contents are identical to :meth:`process_study_serial` (tested), which
        remains the per-instance fallback/oracle path."""
        result = self.run_study(study, request, worker_id)
        return result.delivered, result.manifest

    def process_study_serial(
        self, study: SyntheticStudy, request: DeidRequest, worker_id: str = ""
    ) -> Tuple[List[DicomDataset], Manifest]:
        """Per-instance oracle path (the pre-batching hot loop)."""
        manifest = Manifest(request_id=f"{request.research_study}/{request.anon_accession}")
        delivered: List[DicomDataset] = []
        for ds in study.datasets:
            out, entry = self.process_instance(ds, request, worker_id)
            manifest.add(entry)
            if out is not None:
                delivered.append(out)
        return delivered, manifest
