"""Pseudonymization service: anonymized codes + date jitter (paper §Method).

Two trust modes, exactly as the paper defines them:

* **PRE_IRB** (non-human-subject research): codes are derived from an
  *ephemeral* random key that is never persisted — "can never be reversed and
  linked to identified patient data".
* **POST_IRB**: codes are derived from a per-research-study key and a linkage
  map is retained, so the IRB-approved study can "request links between the
  anonymized images and the original patient identifiers".

Date jitter is randomized **per (research study, patient)** and applied to all
dates of that patient uniformly — this keeps longitudinal intervals intact
(DICOM Retain Longitudinal Temporal Information With Modified Dates option)
while decorrelating absolute dates across research studies.
"""
from __future__ import annotations

import base64
import datetime as _dt
import hashlib
import hmac
import os
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Optional


class TrustMode(Enum):
    PRE_IRB = "pre_irb"
    POST_IRB = "post_irb"


def _code(key: bytes, kind: str, value: str, n: int = 10) -> str:
    mac = hmac.new(key, f"{kind}|{value}".encode(), hashlib.sha256).digest()
    return base64.b32encode(mac).decode("ascii")[:n]


@dataclass
class PseudonymService:
    study_id: str  # the research study (IRB protocol), not the imaging study
    mode: TrustMode = TrustMode.POST_IRB
    key: Optional[bytes] = None
    jitter_days: int = 30  # jitter drawn from [-jitter_days, +jitter_days] \ {0}
    _links: Dict[str, str] = field(default_factory=dict)  # anon -> original

    def __post_init__(self) -> None:
        if self.key is None:
            if self.mode is TrustMode.PRE_IRB:
                # ephemeral, never persisted: irreversibility by construction
                self.key = os.urandom(32)
            else:
                raise ValueError("POST_IRB mode requires a persistent study key")

    # ----------------------------------------------------------------- codes
    def accession(self, original: str) -> str:
        anon = "RA" + _code(self.key, "accession", original)
        self._maybe_link(anon, original)
        return anon

    def mrn(self, original: str) -> str:
        anon = "RP" + _code(self.key, "mrn", original)
        self._maybe_link(anon, original)
        return anon

    def _maybe_link(self, anon: str, original: str) -> None:
        if self.mode is TrustMode.POST_IRB:
            self._links[anon] = original

    def relink(self, anon: str) -> str:
        """IRB-approved reverse lookup. Forbidden (empty map) in PRE_IRB."""
        if self.mode is not TrustMode.POST_IRB:
            raise PermissionError("re-identification is not permitted for pre-IRB data")
        return self._links[anon]

    def linkage_table(self) -> Dict[str, str]:
        if self.mode is not TrustMode.POST_IRB:
            raise PermissionError("no linkage table exists for pre-IRB data")
        return dict(self._links)

    # ---------------------------------------------------------------- jitter
    def jitter_for(self, mrn: str) -> int:
        """Deterministic per-(study, patient) jitter, never zero."""
        mac = hmac.new(self.key, f"jitter|{mrn}".encode(), hashlib.sha256).digest()
        span = 2 * self.jitter_days  # values 0..2J-1 -> [-J..-1, 1..J]
        v = int.from_bytes(mac[:4], "big") % span
        return v - self.jitter_days if v < self.jitter_days else v - self.jitter_days + 1

    @staticmethod
    def jitter_date(da: str, days: int) -> str:
        """Apply jitter to a DICOM DA (YYYYMMDD) value. Malformed or
        calendar-overflowing values are emptied (fail closed: a date we cannot
        jitter must not pass through identified)."""
        if not da or len(da) != 8:
            return ""
        try:
            d = _dt.date(int(da[:4]), int(da[4:6]), int(da[6:8])) + _dt.timedelta(days=days)
        except (ValueError, OverflowError):
            return ""
        return d.strftime("%Y%m%d")
