"""CTP-style rule scripts: filter, anonymizer, and scrubber DSLs.

The paper extracts MIRC CTP's DICOM *filtering* and *anonymizing* components
and drives them with site-maintained scripts (stanford-filter.script,
stanford-anonymizer.script, stanford-scrubber.script). We reproduce that
contract: rules live in human-readable text scripts, are parsed once into
rule objects, and are executed by the pipeline stages. Scripts are versioned
artifacts — their SHA goes into every manifest entry, which is what makes
on-demand re-de-identification reproducible (the paper's core requirement
that vendor black-box APIs could not meet).

Grammar (one rule per line, ``#`` comments):

Filter script::

    reject <Keyword> <op> ["value"] [unless <exemption>]
    accept <Keyword> <op> ["value"]          # short-circuit accept
    reject builtin:<predicate> [unless <exemption>]

  ops: equals | notequals | contains | startswith | in | empty | exists | missing
  builtins: us_not_whitelisted (device-registry lookup), video_sop_class

Anonymizer script::

    set <Keyword> <template>    # @param(name) and @hash(Keyword) substitution
    empty <Keyword>
    remove <Keyword>
    keep <Keyword>
    hashuid <Keyword>
    jitterdate <Keyword>
    removeprivate
    removefreetext
    default keep|remove

Scrubber script::

    scrub <Modality> <Make> <Model> <RowsxCols> (x,y,w,h) [(x,y,w,h) ...]
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import DeviceKey, Rect, registry

# --------------------------------------------------------------------- filter
# equals/notequals/in are implemented via DicomDataset.matches (shared CS
# normalization — case/whitespace-insensitive, the same the catalog uses at
# ingest) inside FilterRule.matches, so they have no entry here. startswith
# stays byte-exact — it is used for UID prefixes, which are never CS.
_MATCHES_OPS = frozenset({"equals", "notequals", "in"})
_FILTER_OPS: Dict[str, Callable[[str, str], bool]] = {
    "contains": lambda v, arg: arg.upper() in v.upper(),
    "startswith": lambda v, arg: v.startswith(arg),
    "empty": lambda v, arg: v == "",
    "exists": lambda v, arg: True,  # presence checked separately
    "missing": lambda v, arg: False,
}


def _builtin_us_not_whitelisted(ds: DicomDataset) -> bool:
    if ds.get("Modality") != "US":
        return False
    res = ds.resolution()
    if res is None:
        return True
    key = DeviceKey("US", str(ds.get("Manufacturer", "")), str(ds.get("ManufacturerModelName", "")), *res)
    return not registry().us_whitelisted(key)


def _builtin_video_sop_class(ds: DicomDataset) -> bool:
    return str(ds.get("SOPClassUID", "")).startswith("1.2.840.10008.5.1.4.1.1.77.1.4")


BUILTIN_PREDICATES: Dict[str, Callable[[DicomDataset], bool]] = {
    "us_not_whitelisted": _builtin_us_not_whitelisted,
    "video_sop_class": _builtin_video_sop_class,
}

# Exemptions: the paper marks some reject categories "may be bypassed by
# specific whitelisting rules based on other attributes".
EXEMPTIONS: Dict[str, Callable[[DicomDataset], bool]] = {
    # e.g. derived CT localizers are safe: no burned-in demographics
    "derived_localizer": lambda ds: ds.image_type_contains("LOCALIZER")
    and ds.get("Modality") in ("CT", "MR"),
    # secondary captures from a known-safe converter station
    "trusted_sc_station": lambda ds: str(ds.get("StationName", "")).startswith("SAFE"),
}


@dataclass(frozen=True)
class FilterRule:
    action: str  # "reject" | "accept"
    keyword: Optional[str]  # None for builtin rules
    op: Optional[str]
    arg: str = ""
    builtin: Optional[str] = None
    unless: Optional[str] = None
    line: str = ""

    def matches(self, ds: DicomDataset) -> bool:
        if self.builtin is not None:
            hit = BUILTIN_PREDICATES[self.builtin](ds)
        else:
            present = self.keyword in ds
            if self.op == "exists":
                hit = present
            elif self.op == "missing":
                hit = not present
            elif not present:
                hit = False
            elif self.op == "equals":
                hit = ds.matches(self.keyword, self.arg)
            elif self.op == "notequals":
                hit = not ds.matches(self.keyword, self.arg)
            elif self.op == "in":
                hit = any(ds.matches(self.keyword, a) for a in self.arg.split(","))
            else:
                hit = _FILTER_OPS[self.op](str(ds.get(self.keyword, "")), self.arg)
        if hit and self.unless and EXEMPTIONS[self.unless](ds):
            return False
        return hit


_FILTER_RE = re.compile(
    r"^(reject|accept)\s+(?:builtin:(\w+)|(\w+)\s+(\w+)(?:\s+\"([^\"]*)\")?)"
    r"(?:\s+unless\s+(\w+))?$"
)


def parse_filter_script(text: str) -> List[FilterRule]:
    rules: List[FilterRule] = []
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        m = _FILTER_RE.match(line)
        if not m:
            raise ValueError(f"bad filter rule: {raw!r}")
        action, builtin, kw, op, arg, unless = m.groups()
        if builtin is not None:
            if builtin not in BUILTIN_PREDICATES:
                raise ValueError(f"unknown builtin {builtin!r}")
            rules.append(FilterRule(action, None, None, "", builtin, unless, line))
        else:
            if op not in _FILTER_OPS and op not in _MATCHES_OPS:
                raise ValueError(f"unknown op {op!r} in {raw!r}")
            if unless and unless not in EXEMPTIONS:
                raise ValueError(f"unknown exemption {unless!r}")
            rules.append(FilterRule(action, kw, op, arg or "", None, unless, line))
    return rules


# ----------------------------------------------------------------- anonymizer
@dataclass(frozen=True)
class AnonRule:
    action: str  # set/empty/remove/keep/hashuid/jitterdate/removeprivate/removefreetext/default
    keyword: Optional[str] = None
    template: str = ""
    line: str = ""


_TEMPLATE_RE = re.compile(r"@(param|hash)\(([^)]+)\)")


def render_template(template: str, params: Dict[str, str], ds: DicomDataset) -> str:
    def sub(m: re.Match) -> str:
        kind, name = m.group(1), m.group(2).strip()
        if kind == "param":
            if name not in params:
                raise KeyError(f"missing script parameter {name!r}")
            return str(params[name])
        # @hash(Keyword): stable one-way digest of the original value
        return hashlib.sha256(str(ds.get(name, "")).encode()).hexdigest()[:16]

    return _TEMPLATE_RE.sub(sub, template)


def parse_anonymizer_script(text: str) -> List[AnonRule]:
    rules: List[AnonRule] = []
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split(None, 2)
        action = parts[0]
        if action in ("removeprivate", "removefreetext"):
            rules.append(AnonRule(action, line=line))
        elif action == "default":
            if len(parts) != 2 or parts[1] not in ("keep", "remove"):
                raise ValueError(f"bad default rule: {raw!r}")
            rules.append(AnonRule("default", template=parts[1], line=line))
        elif action in ("set",):
            if len(parts) != 3:
                raise ValueError(f"bad set rule: {raw!r}")
            rules.append(AnonRule(action, parts[1], parts[2], line=line))
        elif action in ("empty", "remove", "keep", "hashuid", "jitterdate"):
            if len(parts) != 2:
                raise ValueError(f"bad {action} rule: {raw!r}")
            rules.append(AnonRule(action, parts[1], line=line))
        else:
            raise ValueError(f"unknown anonymizer action {action!r} in {raw!r}")
    return rules


# -------------------------------------------------------------------- scrubber
@dataclass(frozen=True)
class ScrubRule:
    key: Tuple[str, str, str, int, int]  # modality, make, model, rows, cols
    rects: Tuple[Rect, ...]


_SCRUB_RE = re.compile(
    r"^scrub\s+(\S+)\s+(\S+)\s+(\S+)\s+(\d+)x(\d+)\s+((?:\(\s*\d+\s*,\s*\d+\s*,\s*\d+\s*,\s*\d+\s*\)\s*)+)$"
)
_RECT_RE = re.compile(r"\(\s*(\d+)\s*,\s*(\d+)\s*,\s*(\d+)\s*,\s*(\d+)\s*\)")


def parse_scrub_script(text: str) -> Dict[Tuple[str, str, str, int, int], Tuple[Rect, ...]]:
    out: Dict[Tuple[str, str, str, int, int], Tuple[Rect, ...]] = {}
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        m = _SCRUB_RE.match(line)
        if not m:
            raise ValueError(f"bad scrub rule: {raw!r}")
        mod, make, model, rows, cols, rects_s = m.groups()
        rects = tuple(
            (int(a), int(b), int(c), int(d)) for a, b, c, d in _RECT_RE.findall(rects_s)
        )
        # makes with spaces are encoded with underscores in scripts
        out[(mod, make.replace("_", " "), model.replace("_", " "), int(rows), int(cols))] = rects
    return out


def emit_scrub_script(header: str = "") -> str:
    """Generate the site scrub script from the device registry (DESIGN.md §3:
    generator and rules share the device ground truth, mirroring the paper's
    per-device rule derivation)."""
    reg = registry()
    lines = [f"# {header}" if header else "# auto-generated site scrubber script"]
    keys: List[DeviceKey] = list(reg.all_us_variants())
    from repro.dicom.devices import FIXED_DEVICES

    keys += [d for d in FIXED_DEVICES if d.make != "UnknownMake"]
    for key in keys:
        rects = reg.scrub_rects(key)
        if not rects:
            continue
        rect_s = " ".join(f"({x},{y},{w},{h})" for x, y, w, h in rects)
        lines.append(
            f"scrub {key.modality} {key.make.replace(' ', '_')} "
            f"{key.model.replace(' ', '_')} {key.rows}x{key.cols} {rect_s}"
        )
    return "\n".join(lines) + "\n"


def script_sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:12]
