"""Cucumber-style regression scenarios (paper Figure 2b).

The paper's regression suite is human-readable Gherkin executed against the
pipeline ("If any of these tests fail, the regression test results in
failure"). This module reproduces that contract: a small Gherkin-subset
parser + runner whose steps match the paper's wording:

    Given the pipeline uses the anonymizer script, "<name>"
    Given the pipeline uses the pixel script, "<name>"
    Given the pipeline uses the filter script, "<name>"
    And script parameter "<key>" is "<value>"
    Scenario: <title>
      Given the DICOM directory "<virtual path>"
      When ran through the deid pipeline
      Then the images SHOULD be anonymized
      Then the images SHOULD NOT pass the filter
      Then the resulting images should be scrubbed at x,y,w,h

Virtual DICOM directories are resolved against the seeded generator:
  dicom-phi/<MOD>/Anonymize              clean study of that modality
  dicom-phi/<MOD>/Filter                 problem objects (paper Discussion)
  dicom-phi/<MOD>/Scrub/<Make>/<Model>/<RxC>   one instance of that device
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.manifest import Outcome
from repro.core.pipeline import DeidPipeline, DeidRequest
from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import DeviceKey
from repro.dicom.generator import PROBLEM_KINDS, StudyGenerator


@dataclass
class Scenario:
    title: str
    directory: str = ""
    expectations: List[Tuple[str, object]] = field(default_factory=list)


@dataclass
class Feature:
    title: str
    params: Dict[str, str] = field(default_factory=dict)
    scripts: Dict[str, str] = field(default_factory=dict)
    scenarios: List[Scenario] = field(default_factory=list)


_RECT_RE = re.compile(r"scrubbed at\s+(\d+)\s*,\s*(\d+)\s*,\s*(\d+)\s*,\s*(\d+)")


class FeatureParseError(ValueError):
    """A feature file the runner cannot execute. Carries the 1-based line
    number and offending text so the regression-suite author sees exactly
    which step is malformed (the paper's suite is written by humans)."""

    def __init__(self, lineno: int, line: str, why: str) -> None:
        super().__init__(f"line {lineno}: {why}: {line!r}")
        self.lineno = lineno
        self.line = line
        self.why = why


def parse_feature(text: str) -> Feature:
    feature = Feature("")
    scenario: Optional[Scenario] = None
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        low = line.lower()
        if low.startswith("feature:"):
            feature.title = line.split(":", 1)[1].strip()
        elif low.startswith("background:"):
            scenario = None
        elif low.startswith("scenario:"):
            scenario = Scenario(line.split(":", 1)[1].strip())
            feature.scenarios.append(scenario)
        elif "uses the" in low and "script" in low:
            m = re.search(r'uses the (\w+) script,?\s+"([^"]+)"', line)
            if not m:
                raise FeatureParseError(
                    lineno, raw, 'bad script step (want: uses the <kind> script, "<name>")'
                )
            feature.scripts[m.group(1)] = m.group(2)
        elif low.startswith(("and script parameter", "given script parameter")):
            m = re.search(r'parameter\s+"([^"]+)"\s+is\s+"([^"]+)"', line)
            if not m:
                raise FeatureParseError(
                    lineno, raw, 'bad parameter step (want: parameter "<key>" is "<value>")'
                )
            feature.params[m.group(1)] = m.group(2)
        elif "the dicom directory" in low:
            m = re.search(r'"([^"]+)"', line)
            if m is None:
                raise FeatureParseError(lineno, raw, "directory step without a quoted path")
            if scenario is None:
                raise FeatureParseError(
                    lineno, raw, "Given directory outside any Scenario block"
                )
            scenario.directory = m.group(1)
        elif low.startswith("when"):
            continue  # single action: ran through the pipeline
        elif low.startswith("then") or low.startswith("and the resulting"):
            if scenario is None:
                raise FeatureParseError(lineno, raw, "Then step outside any Scenario block")
            if "should not pass the filter" in low:
                scenario.expectations.append(("filtered", True))
            elif "should be anonymized" in low:
                scenario.expectations.append(("anonymized", True))
            elif "jittered" in low:
                scenario.expectations.append(("jittered", True))
            elif "scrubbed at" in low:
                m = _RECT_RE.search(line)
                if m is None:
                    raise FeatureParseError(
                        lineno, raw, "bad scrub expectation (want: scrubbed at x,y,w,h)"
                    )
                scenario.expectations.append(("scrub_rect", tuple(int(g) for g in m.groups())))
            else:
                raise FeatureParseError(lineno, raw, "unknown Then step")
    return feature


class VirtualDicomTree:
    """Resolves the feature files' virtual directories to generated datasets."""

    def __init__(self, seed: int = 99) -> None:
        self.gen = StudyGenerator(seed)

    def resolve(self, path: str) -> List[DicomDataset]:
        parts = path.strip("/").split("/")
        assert parts[0] == "dicom-phi", path
        modality = parts[1]
        kind = parts[2]
        if kind == "Anonymize":
            return self.gen.gen_study(f"SCN-{modality}-anon", modality=modality, n_images=3).datasets
        if kind == "Filter":
            # dicom-phi/<MOD>/Filter            -> the classic six problem objects
            # dicom-phi/<MOD>/Filter/<problem>  -> one specific PROBLEM_KINDS entry
            if len(parts) > 3:
                p = parts[3]
                if p not in PROBLEM_KINDS:
                    raise KeyError(f"unknown problem kind {p!r} in {path!r}")
                kinds = [p]
            else:
                kinds = PROBLEM_KINDS[:6]
            out = []
            for p in kinds:
                s = self.gen.gen_study(f"SCN-{modality}-{p}", modality=modality, n_images=0, problem=p)
                out.append(s.datasets[-1])
            return out
        if kind == "Scrub":
            make, model, res = parts[3], parts[4], parts[5]
            rows, cols = (int(x) for x in res.split("x"))
            dev = DeviceKey(modality, make.replace("_", " "), model.replace("_", " "), rows, cols)
            return self.gen.gen_study(f"SCN-{dev.id()}", device=dev, n_images=1).datasets
        raise KeyError(path)


@dataclass
class ScenarioResult:
    scenario: str
    passed: bool
    detail: str = ""


def run_feature(feature: Feature, tree: Optional[VirtualDicomTree] = None) -> List[ScenarioResult]:
    tree = tree or VirtualDicomTree()
    pipeline = DeidPipeline(recompress=False)  # scripts "default" -> site scripts
    request = DeidRequest(
        research_study="SCENARIO",
        accession="SRC",
        anon_accession=feature.params.get("accession", "ACN123"),
        anon_mrn=feature.params.get("mrn", "MRN123"),
        jitter=int(feature.params.get("jitter", "-6")),
    )
    results: List[ScenarioResult] = []
    for scn in feature.scenarios:
        datasets = tree.resolve(scn.directory)
        outputs = [pipeline.process_instance(ds, request) for ds in datasets]
        ok, detail = True, ""
        for kind, arg in scn.expectations:
            if kind == "filtered":
                bad = [e for _, e in outputs if e.outcome is not Outcome.FILTERED]
                if bad:
                    ok, detail = False, f"{len(bad)} instances passed the filter"
            elif kind == "anonymized":
                for out, e in outputs:
                    if e.outcome is not Outcome.ANONYMIZED:
                        ok, detail = False, f"outcome {e.outcome}"
                    elif out.get("AccessionNumber") != request.anon_accession:
                        ok, detail = False, "accession not replaced"
                    elif out.get("PatientID") != request.anon_mrn:
                        ok, detail = False, "mrn not replaced"
            elif kind == "jittered":
                for out, e in outputs:
                    if e.outcome is Outcome.ANONYMIZED and "StudyDate" in out:
                        src = [d for d in datasets if d.get("SOPClassUID")]
                        if out["StudyDate"] == src[0].get("StudyDate"):
                            ok, detail = False, "date not jittered"
            elif kind == "scrub_rect":
                x, y, w, h = arg
                for out, e in outputs:
                    if out is None:
                        ok, detail = False, "instance filtered, expected scrub"
                        continue
                    region = out.pixels[y : y + h, x : x + w]
                    if region.size and region.max() != 0:
                        ok, detail = False, f"region {arg} not blank"
        results.append(ScenarioResult(scn.title, ok, detail))
    return results
