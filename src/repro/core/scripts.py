"""Default site scripts (the stanford-*.script analogues).

These encode the paper's Discussion list verbatim: every category the
pipeline must exclude, plus the Basic Application Confidentiality Profile
(Clean Graphics + Retain Longitudinal Temporal Information With Modified
Dates) tag policy for the anonymizer.
"""
from __future__ import annotations

from repro.core.rules import emit_scrub_script

# Paper Discussion, items 1-3: categorical exclusions.
DEFAULT_FILTER_SCRIPT = """
# stanford-filter.script (reproduction)
# 1. analog film digitizers: PHI anywhere on film, any orientation
reject Manufacturer equals "Vidar"
# 2a. encapsulated PDF documents
reject SOPClassUID startswith "1.2.840.10008.5.1.4.1.1.104"
# 2b. structured report documents
reject Modality in "SR,KO"
reject SOPClassUID startswith "1.2.840.10008.5.1.4.1.1.88"
# 2c. presentation state objects
reject Modality equals "PR"
reject SOPClassUID startswith "1.2.840.10008.5.1.4.1.1.11"
# 2d. uncommon modality attributes
reject Modality in "RAW,OT,DOC,PLAN"
# 2e. secondary capture objects (*bypassable)
reject SOPClassUID startswith "1.2.840.10008.5.1.4.1.1.7" unless trusted_sc_station
# 2f. burned-in annotation declared by the device (*bypassable)
reject BurnedInAnnotation equals "YES" unless trusted_sc_station
# 2g. ConversionType present but empty
reject ConversionType equals ""
# 2h. derived / secondary image types (*bypassable)
reject ImageType contains "DERIVED" unless derived_localizer
reject ImageType contains "SECONDARY" unless derived_localizer
# 3. video capture devices
reject builtin:video_sop_class
# ultrasound is whitelist-only (paper Table 2)
reject builtin:us_not_whitelisted
# images without pixel geometry cannot be scrubbed -> reject
reject Rows missing
reject Columns missing
"""

# DICOM Basic Application Confidentiality Profile + Clean Graphics +
# Retain Longitudinal Temporal Information With Modified Dates.
DEFAULT_ANONYMIZER_SCRIPT = """
# stanford-anonymizer.script (reproduction)
set AccessionNumber @param(accession)
set PatientID @param(mrn)
set PatientName @param(mrn)
remove PatientBirthDate
remove PatientBirthTime
keep PatientSex
keep PatientAge
remove OtherPatientIDs
remove OtherPatientNames
remove PatientAddress
remove PatientTelephoneNumbers
remove AdditionalPatientHistory
remove ReferringPhysicianName
remove PhysiciansOfRecord
remove PerformingPhysicianName
remove OperatorsName
remove InstitutionName
remove InstitutionAddress
remove InstitutionalDepartmentName
remove DeviceSerialNumber
remove StationName
jitterdate StudyDate
jitterdate SeriesDate
jitterdate AcquisitionDate
jitterdate ContentDate
empty StudyTime
empty SeriesTime
empty AcquisitionTime
empty ContentTime
hashuid SOPInstanceUID
hashuid StudyInstanceUID
hashuid SeriesInstanceUID
set StudyID @param(accession)
keep SeriesNumber
keep InstanceNumber
keep Modality
keep Manufacturer
keep ManufacturerModelName
keep SoftwareVersions
keep Rows
keep Columns
keep BitsAllocated
keep BitsStored
keep SamplesPerPixel
keep BurnedInAnnotation
keep ImageType
keep ConversionType
keep BodyPartExamined
keep SOPClassUID
keep TransferSyntaxUID
removeprivate
removefreetext
default remove
"""

# The scrubber script is generated from the device registry (DESIGN.md §3).
DEFAULT_SCRUB_SCRIPT = emit_scrub_script("stanford-scrubber.script (reproduction)")
