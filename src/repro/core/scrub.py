"""Scrub stage: blank PHI pixel regions and recompress (paper Figure 2a).

Looks up the device variant's scrub rectangles in the site scrub script,
blanks them ("replaced by black pixels"), and recompresses with the
JPEG-Lossless-style codec. The blanking compute itself is pluggable:

* ``numpy_blank`` — host reference path (single instance);
* ``repro.kernels.scrub.ops.scrub_images`` — the Pallas TPU kernel, used by
  the distributed farm for batched scrubbing (DESIGN.md §3).

Defense in depth: an ultrasound instance with no scrub rule should have been
filtered upstream; the stage re-checks and fails closed rather than passing
un-scrubbed US pixels through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import DETECTOR_DECISION
from repro.core.rules import parse_scrub_script, script_sha
from repro.detect.policy import DetectorPolicy
from repro.detect.regions import detect_bands_for, merge_rects, policy_thresh
from repro.detect.report import DetectionReport, DetectStats
from repro.dicom import codec
from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import DeviceKey, Rect, registry


def numpy_blank(pixels: np.ndarray, rects: Sequence[Rect]) -> np.ndarray:
    """Reference blanking: set each (x, y, w, h) region to 0.

    Slice ends clamp to 0 so a rect lying entirely above/left of the frame
    (y + h <= 0 or x + w <= 0) is a no-op — a raw ``min(H, y + h)`` would go
    negative and wrap around to blank nearly the whole frame.
    """
    out = pixels.copy()
    H, W = out.shape[:2]
    for x, y, w, h in rects:
        out[max(0, y) : max(0, min(H, y + h)), max(0, x) : max(0, min(W, x + w))] = 0
    return out


class ScrubError(RuntimeError):
    pass


@dataclass
class ScrubResult:
    dataset: DicomDataset
    rects: List[Rect] = field(default_factory=list)
    recompressed: bool = False
    compressed_bytes: int = 0
    detection: Optional[DetectionReport] = None


class ScrubStage:
    def __init__(
        self,
        script_text: str,
        blank_fn: Callable[[np.ndarray, Sequence[Rect]], np.ndarray] = numpy_blank,
        recompress: bool = True,
        sv: int = 1,
        policy: Optional[DetectorPolicy] = None,
        registry=None,
        ledger=None,
    ) -> None:
        self.script_text = script_text
        self.rules = parse_scrub_script(script_text)
        self.sha = script_sha(script_text)
        self.blank_fn = blank_fn
        self.recompress = recompress
        self.sv = sv
        # burned-in pixel-PHI detector policy (DESIGN.md §9); None and
        # mode="off" are both the legacy registry-only behavior
        self.policy = policy
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        # registry: optional shared MetricsRegistry so fleet-level snapshots
        # see repro_detect_* totals across every pipeline
        self.detect_stats = DetectStats(registry)

    def rects_for(self, ds: DicomDataset) -> Optional[Tuple[Rect, ...]]:
        res = ds.resolution()
        if res is None:
            return None
        key = (
            str(ds.get("Modality", "")),
            str(ds.get("Manufacturer", "")),
            str(ds.get("ManufacturerModelName", "")),
            res[0],
            res[1],
        )
        return self.rules.get(key)

    # ---------------------------------------------------------- rect resolution
    def _device_key(self, ds: DicomDataset) -> DeviceKey:
        res = ds.resolution() or (0, 0)
        return DeviceKey(
            str(ds.get("Modality", "")),
            str(ds.get("Manufacturer", "")),
            str(ds.get("ManufacturerModelName", "")),
            int(res[0]),
            int(res[1]),
        )

    def _detect_thresh(self, ds: DicomDataset) -> float:
        """Binarization threshold for this instance (shared derivation —
        the batched pre-pass buckets executor dispatches by it)."""
        return policy_thresh(ds, self.policy)

    def _wants_detection(self, ds: DicomDataset, registry_hit: bool) -> bool:
        """Batched pre-pass predicate: will :meth:`_resolve_rects` scan this
        instance's pixels? (US misses fail closed before detection; only
        single-plane 2D frames are scannable.)"""
        if self.policy is None or not self.policy.enabled:
            return False
        if ds.pixels is None or ds.pixels.ndim != 2:
            return False
        if not registry_hit and ds.get("Modality") == "US":
            return False
        return self.policy.wants_detection(registry_hit)

    def _resolve_rects(
        self, ds: DicomDataset, row_hits: Optional[np.ndarray] = None
    ) -> Tuple[Tuple[Rect, ...], Optional[DetectionReport]]:
        """Rects to blank for this instance (+ the detection audit report when
        a policy is active); raises :class:`ScrubError` on the fail-closed
        cases shared by the serial and batched paths.

        ``row_hits`` is the precomputed per-row glyph-hit profile from a
        batched executor dispatch — bit-identical to the host oracle computed
        here when absent, so serial and batched paths stay byte-identical.
        """
        if ds.pixels is None:
            raise ScrubError("no pixel data to scrub (object should have been filtered)")
        rects = self.rects_for(ds)
        registry_hit = rects is not None
        policy = self.policy
        if policy is not None and policy.enabled:
            self.detect_stats.instances += 1
            if registry_hit:
                self.detect_stats.registry_hits += 1
        if not registry_hit:
            # an unknown (manufacturer, model) is counted and surfaced as a
            # worker/fleet metric in every mode — detector on, off, or absent
            # — a coverage gap must never pass through silently
            self.detect_stats.unknown_lookups += 1
            registry().note_unknown(self._device_key(ds))
        if not registry_hit and ds.get("Modality") == "US":
            # fail closed: whitelist miss must never pass pixels through —
            # the detector complements the US whitelist, it never bypasses it
            raise ScrubError(
                f"no scrub rule for ultrasound variant "
                f"{ds.get('Manufacturer')}/{ds.get('ManufacturerModelName')}/"
                f"{ds.resolution()} — filter should have rejected it"
            )
        if policy is None or not policy.enabled:
            return tuple(rects or ()), None

        report = DetectionReport(
            sop_uid=str(ds.get("SOPInstanceUID", "")),
            modality=str(ds.get("Modality", "")),
            device=self._device_key(ds).id(),
            registry_hit=registry_hit,
            registry_rects=list(rects or ()),
            tau=policy.tau_for(str(ds.get("Modality", ""))),
        )
        combined: List[Rect] = list(rects or ())
        if self._wants_detection(ds, registry_hit):
            from repro.kernels.phi_detect.ops import stored_max_value

            report.ceiling = stored_max_value(ds)
            report.thresh = report.ceiling * policy.binarize_frac
            report.detector_ran = True
            self.detect_stats.detector_runs += 1
            bands, drects = detect_bands_for(
                ds, policy, row_hits=row_hits, thresh=report.thresh
            )
            report.bands = bands
            report.detector_rects = drects
            if bands:
                self.detect_stats.detected += 1
                self.detect_stats.bands += len(bands)
            combined.extend(drects)
            # each detector run is a PHI decision: which pixels get blanked,
            # under which versioned policy — auditable per instance
            self.ledger.append(
                DETECTOR_DECISION,
                modality=report.modality,
                device=report.device,
                registry_hit=registry_hit,
                detected=bool(bands),
                bands=len(bands),
                detector_sha=policy.digest,
            )
        # registry + detector unions routinely overlap: normalize so the
        # fused kernel never double-blanks a tile (blanked set unchanged)
        applied = merge_rects(combined)
        report.applied_rects = list(applied)
        return tuple(applied), report

    def __call__(self, ds: DicomDataset) -> ScrubResult:
        rects, detection = self._resolve_rects(ds)
        return self._scrub_resolved(ds, rects, detection)

    def _scrub_resolved(
        self, ds: DicomDataset, rects: Tuple[Rect, ...], detection: Optional[DetectionReport]
    ) -> ScrubResult:
        """Blank + recompress with rects already resolved (shared by the
        serial path and the batched path's per-instance fallback, so rect
        resolution — and its detector scan/stats — runs exactly once)."""
        out = ds.copy()
        result = ScrubResult(out, list(rects), detection=detection)
        if rects:
            out.pixels = np.asarray(self.blank_fn(out.pixels, rects))
        if self.recompress and out.pixels is not None:
            # "recompressed using the JPEG Lossless syntax"
            compressed = codec.encode(out.pixels, self.sv)
            result.recompressed = True
            result.compressed_bytes = len(compressed)
            out["TransferSyntaxUID"] = "1.2.840.10008.1.2.4.70"
        return result

    # ------------------------------------------------------------- batched
    def scrub_study(
        self, datasets: Sequence[DicomDataset], executor
    ) -> List[Tuple[Optional[ScrubResult], Optional[ScrubError]]]:
        """Batched equivalent of calling the stage once per instance.

        Instances the executor supports are bucketed and run through the fused
        scrub+JLS kernel (``repro.core.batch.BatchedDeidExecutor``); the rest
        (multi-sample frames, exotic dtypes, non-rectangle ``blank_fn``) take
        the per-instance oracle path. Per-instance errors stay per-instance:
        the result list is aligned with ``datasets`` and each slot holds
        either a :class:`ScrubResult` or the :class:`ScrubError` it raised.
        """
        slots: List[Tuple[Optional[ScrubResult], Optional[ScrubError]]] = [
            (None, None)
        ] * len(datasets)
        # custom blank_fns batch only if they declare rectangle-zero semantics
        rect_semantics = getattr(
            self.blank_fn, "rect_blank_semantics", self.blank_fn is numpy_blank
        )
        # detection pre-pass: instances the policy will scan ride the
        # shape-bucketed executor in batched kernel dispatches; their per-row
        # hit profiles are handed to _resolve_rects (bit-identical to the
        # host oracle it would otherwise run per instance)
        hits_for: Dict[int, np.ndarray] = {}
        if executor is not None and self.policy is not None and self.policy.enabled:
            scan_idx: List[int] = []
            scan_items: List[Tuple[np.ndarray, float]] = []
            for i, ds in enumerate(datasets):
                if self._wants_detection(ds, self.rects_for(ds) is not None):
                    scan_idx.append(i)
                    scan_items.append((ds.pixels, self._detect_thresh(ds)))
            if scan_items:
                profiles = executor.detect_row_hits(scan_items, tile=self.policy.tile)
                hits_for = dict(zip(scan_idx, profiles))
        batch_idx: List[int] = []
        items: List[Tuple[np.ndarray, List[Rect]]] = []
        for i, ds in enumerate(datasets):
            try:
                rects, detection = self._resolve_rects(ds, row_hits=hits_for.get(i))
            except ScrubError as e:
                slots[i] = (None, e)
                continue
            batchable = (
                executor is not None
                and rect_semantics
                and executor.supports(ds.pixels, self.recompress)
                # nothing to batch: no blanking and no recompression work
                and (rects or self.recompress)
            )
            if batchable:
                out = ds.copy()
                slots[i] = (ScrubResult(out, list(rects), detection=detection), None)
                batch_idx.append(i)
                items.append((out.pixels, list(rects)))
            else:
                # rects (and any detector scan) are already resolved above;
                # re-resolving via self(ds) would double-run the detector
                try:
                    slots[i] = (self._scrub_resolved(ds, rects, detection), None)
                except ScrubError as e:  # e.g. a refusing custom blank_fn —
                    slots[i] = (None, e)  # same containment as the serial path

        if items:
            outputs = executor.run(items, sv=self.sv, recompress=self.recompress)
            for i, bo in zip(batch_idx, outputs):
                result = slots[i][0]
                assert result is not None
                result.dataset.pixels = bo.pixels
                if self.recompress:
                    result.recompressed = True
                    result.compressed_bytes = len(bo.payload or b"")
                    result.dataset["TransferSyntaxUID"] = "1.2.840.10008.1.2.4.70"
        return slots
