"""Scrub stage: blank PHI pixel regions and recompress (paper Figure 2a).

Looks up the device variant's scrub rectangles in the site scrub script,
blanks them ("replaced by black pixels"), and recompresses with the
JPEG-Lossless-style codec. The blanking compute itself is pluggable:

* ``numpy_blank`` — host reference path (single instance);
* ``repro.kernels.scrub.ops.scrub_images`` — the Pallas TPU kernel, used by
  the distributed farm for batched scrubbing (DESIGN.md §3).

Defense in depth: an ultrasound instance with no scrub rule should have been
filtered upstream; the stage re-checks and fails closed rather than passing
un-scrubbed US pixels through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rules import parse_scrub_script, script_sha
from repro.dicom import codec
from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import Rect


def numpy_blank(pixels: np.ndarray, rects: Sequence[Rect]) -> np.ndarray:
    """Reference blanking: set each (x, y, w, h) region to 0.

    Slice ends clamp to 0 so a rect lying entirely above/left of the frame
    (y + h <= 0 or x + w <= 0) is a no-op — a raw ``min(H, y + h)`` would go
    negative and wrap around to blank nearly the whole frame.
    """
    out = pixels.copy()
    H, W = out.shape[:2]
    for x, y, w, h in rects:
        out[max(0, y) : max(0, min(H, y + h)), max(0, x) : max(0, min(W, x + w))] = 0
    return out


class ScrubError(RuntimeError):
    pass


@dataclass
class ScrubResult:
    dataset: DicomDataset
    rects: List[Rect] = field(default_factory=list)
    recompressed: bool = False
    compressed_bytes: int = 0


class ScrubStage:
    def __init__(
        self,
        script_text: str,
        blank_fn: Callable[[np.ndarray, Sequence[Rect]], np.ndarray] = numpy_blank,
        recompress: bool = True,
        sv: int = 1,
    ) -> None:
        self.script_text = script_text
        self.rules = parse_scrub_script(script_text)
        self.sha = script_sha(script_text)
        self.blank_fn = blank_fn
        self.recompress = recompress
        self.sv = sv

    def rects_for(self, ds: DicomDataset) -> Optional[Tuple[Rect, ...]]:
        res = ds.resolution()
        if res is None:
            return None
        key = (
            str(ds.get("Modality", "")),
            str(ds.get("Manufacturer", "")),
            str(ds.get("ManufacturerModelName", "")),
            res[0],
            res[1],
        )
        return self.rules.get(key)

    def _resolve_rects(self, ds: DicomDataset) -> Tuple[Rect, ...]:
        """Rects to blank for this instance; raises :class:`ScrubError` on the
        fail-closed cases shared by the serial and batched paths."""
        if ds.pixels is None:
            raise ScrubError("no pixel data to scrub (object should have been filtered)")
        rects = self.rects_for(ds)
        if rects is None:
            if ds.get("Modality") == "US":
                # fail closed: whitelist miss must never pass pixels through
                raise ScrubError(
                    f"no scrub rule for ultrasound variant "
                    f"{ds.get('Manufacturer')}/{ds.get('ManufacturerModelName')}/"
                    f"{ds.resolution()} — filter should have rejected it"
                )
            rects = ()
        return tuple(rects)

    def __call__(self, ds: DicomDataset) -> ScrubResult:
        rects = self._resolve_rects(ds)
        out = ds.copy()
        result = ScrubResult(out, list(rects))
        if rects:
            out.pixels = np.asarray(self.blank_fn(out.pixels, rects))
        if self.recompress and out.pixels is not None:
            # "recompressed using the JPEG Lossless syntax"
            compressed = codec.encode(out.pixels, self.sv)
            result.recompressed = True
            result.compressed_bytes = len(compressed)
            out["TransferSyntaxUID"] = "1.2.840.10008.1.2.4.70"
        return result

    # ------------------------------------------------------------- batched
    def scrub_study(
        self, datasets: Sequence[DicomDataset], executor
    ) -> List[Tuple[Optional[ScrubResult], Optional[ScrubError]]]:
        """Batched equivalent of calling the stage once per instance.

        Instances the executor supports are bucketed and run through the fused
        scrub+JLS kernel (``repro.core.batch.BatchedDeidExecutor``); the rest
        (multi-sample frames, exotic dtypes, non-rectangle ``blank_fn``) take
        the per-instance oracle path. Per-instance errors stay per-instance:
        the result list is aligned with ``datasets`` and each slot holds
        either a :class:`ScrubResult` or the :class:`ScrubError` it raised.
        """
        slots: List[Tuple[Optional[ScrubResult], Optional[ScrubError]]] = [
            (None, None)
        ] * len(datasets)
        # custom blank_fns batch only if they declare rectangle-zero semantics
        rect_semantics = getattr(
            self.blank_fn, "rect_blank_semantics", self.blank_fn is numpy_blank
        )
        batch_idx: List[int] = []
        items: List[Tuple[np.ndarray, List[Rect]]] = []
        for i, ds in enumerate(datasets):
            try:
                rects = self._resolve_rects(ds)
            except ScrubError as e:
                slots[i] = (None, e)
                continue
            batchable = (
                executor is not None
                and rect_semantics
                and executor.supports(ds.pixels, self.recompress)
                # nothing to batch: no blanking and no recompression work
                and (rects or self.recompress)
            )
            if batchable:
                out = ds.copy()
                slots[i] = (ScrubResult(out, list(rects)), None)
                batch_idx.append(i)
                items.append((out.pixels, list(rects)))
            else:
                try:
                    slots[i] = (self(ds), None)
                except ScrubError as e:  # same containment as the serial path
                    slots[i] = (None, e)

        if items:
            outputs = executor.run(items, sv=self.sv, recompress=self.recompress)
            for i, bo in zip(batch_idx, outputs):
                result = slots[i][0]
                assert result is not None
                result.dataset.pixels = bo.pixels
                if self.recompress:
                    result.recompressed = True
                    result.compressed_bytes = len(bo.payload or b"")
                    result.dataset["TransferSyntaxUID"] = "1.2.840.10008.1.2.4.70"
        return slots
