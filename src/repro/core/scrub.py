"""Scrub stage: blank PHI pixel regions and recompress (paper Figure 2a).

Looks up the device variant's scrub rectangles in the site scrub script,
blanks them ("replaced by black pixels"), and recompresses with the
JPEG-Lossless-style codec. The blanking compute itself is pluggable:

* ``numpy_blank`` — host reference path (single instance);
* ``repro.kernels.scrub.ops.scrub_images`` — the Pallas TPU kernel, used by
  the distributed farm for batched scrubbing (DESIGN.md §3).

Defense in depth: an ultrasound instance with no scrub rule should have been
filtered upstream; the stage re-checks and fails closed rather than passing
un-scrubbed US pixels through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rules import parse_scrub_script, script_sha
from repro.dicom import codec
from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import Rect


def numpy_blank(pixels: np.ndarray, rects: Sequence[Rect]) -> np.ndarray:
    """Reference blanking: set each (x, y, w, h) region to 0."""
    out = pixels.copy()
    H, W = out.shape[:2]
    for x, y, w, h in rects:
        out[max(0, y) : min(H, y + h), max(0, x) : min(W, x + w)] = 0
    return out


class ScrubError(RuntimeError):
    pass


@dataclass
class ScrubResult:
    dataset: DicomDataset
    rects: List[Rect] = field(default_factory=list)
    recompressed: bool = False
    compressed_bytes: int = 0


class ScrubStage:
    def __init__(
        self,
        script_text: str,
        blank_fn: Callable[[np.ndarray, Sequence[Rect]], np.ndarray] = numpy_blank,
        recompress: bool = True,
        sv: int = 1,
    ) -> None:
        self.script_text = script_text
        self.rules = parse_scrub_script(script_text)
        self.sha = script_sha(script_text)
        self.blank_fn = blank_fn
        self.recompress = recompress
        self.sv = sv

    def rects_for(self, ds: DicomDataset) -> Optional[Tuple[Rect, ...]]:
        res = ds.resolution()
        if res is None:
            return None
        key = (
            str(ds.get("Modality", "")),
            str(ds.get("Manufacturer", "")),
            str(ds.get("ManufacturerModelName", "")),
            res[0],
            res[1],
        )
        return self.rules.get(key)

    def __call__(self, ds: DicomDataset) -> ScrubResult:
        if ds.pixels is None:
            raise ScrubError("no pixel data to scrub (object should have been filtered)")
        rects = self.rects_for(ds)
        if rects is None:
            if ds.get("Modality") == "US":
                # fail closed: whitelist miss must never pass pixels through
                raise ScrubError(
                    f"no scrub rule for ultrasound variant "
                    f"{ds.get('Manufacturer')}/{ds.get('ManufacturerModelName')}/"
                    f"{ds.resolution()} — filter should have rejected it"
                )
            rects = ()
        out = ds.copy()
        result = ScrubResult(out, list(rects))
        if rects:
            out.pixels = np.asarray(self.blank_fn(out.pixels, rects))
        if self.recompress and out.pixels is not None:
            # "recompressed using the JPEG Lossless syntax"
            compressed = codec.encode(out.pixels, self.sv)
            result.recompressed = True
            result.compressed_bytes = len(compressed)
            out["TransferSyntaxUID"] = "1.2.840.10008.1.2.4.70"
        return result
