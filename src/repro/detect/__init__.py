"""Burned-in pixel-PHI detection subsystem (DESIGN.md §9).

Registry-fallback text-band detection: ``kernels/textdetect`` reduces pixels
to projection profiles (Pallas on accelerators, bit-identical numpy oracle on
hosts), ``regions`` turns profiles into full-width blank rectangles,
``policy`` decides when the detector runs (registry-first / union / off) and
versions the behavior into the ruleset fingerprint, ``report`` carries the
per-instance audit trail.
"""
from repro.detect.policy import DETECTOR_VERSION, DetectorPolicy
from repro.detect.regions import (
    bands_from_hits,
    detect_bands_for,
    detect_bands_np,
    merge_rects,
    policy_thresh,
    rects_from_bands,
)
from repro.detect.report import DetectionReport, DetectStats

__all__ = [
    "DETECTOR_VERSION",
    "DetectorPolicy",
    "DetectionReport",
    "DetectStats",
    "bands_from_hits",
    "detect_bands_for",
    "detect_bands_np",
    "merge_rects",
    "policy_thresh",
    "rects_from_bands",
]
