"""DetectorPolicy: when the detector runs and how confident it must be.

The policy is the versioned contract between the device registry and the
pixel-PHI detector (DESIGN.md §9):

* ``registry_first`` (default) — registry geometry wins when the (modality,
  manufacturer, model, resolution) variant is known; the detector runs only
  on registry *misses* (unknown devices), which is exactly the gap that used
  to pass pixels through silently.
* ``union`` — the detector always runs and its bands are merged with the
  registry rects (belt and braces, e.g. while qualifying a new ruleset).
* ``off`` — registry-only, the pre-detector behavior. This is the negative
  control the sim's PHI-boundary invariant is tested against.

Ultrasound stays whitelist-only in every mode (paper Table 2): an unknown US
variant is rejected by the filter and fails closed in the scrub stage — the
detector is a complement to the whitelist, never a bypass of it.

The policy digests into :class:`repro.lake.fingerprint.RulesetFingerprint`
(together with :data:`DETECTOR_VERSION`), so editing a threshold — or
shipping a new detector — structurally invalidates every cached de-id result
minted under the old behavior.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Tuple

# Bumped whenever kernel/oracle/band-extraction semantics change: the version
# rides the ruleset fingerprint, so a new detector forces a cold serve.
DETECTOR_VERSION = "textdetect-v1"

MODES = ("off", "registry_first", "union")

# Glyph strokes are burned at (or near) the stored sample ceiling; anatomy in
# this corpus tops out around half of it. Binarizing at 60% of the ceiling
# keeps the hit mask empty on clean tissue and dense on burned-in text. This
# is THE binarize fraction — ``kernels/textdetect/ops`` and the policy
# default both read it, so direct kernel users and the pipeline can never
# silently diverge.
DEFAULT_BINARIZE_FRAC = 0.6


@dataclass(frozen=True)
class DetectorPolicy:
    """Frozen (hashable, digestable) detector configuration.

    ``row_frac`` is the default per-row glyph-hit fraction a row must clear
    to count as text; ``modality_row_frac`` overrides it per modality (e.g.
    a stricter threshold for DX where bright hardware edges are common).
    ``binarize_frac`` scales the dtype/BitsStored ceiling into the glyph
    threshold (see :data:`DEFAULT_BINARIZE_FRAC` rationale).
    """

    mode: str = "registry_first"
    binarize_frac: float = DEFAULT_BINARIZE_FRAC
    row_frac: float = 0.04
    modality_row_frac: Tuple[Tuple[str, float], ...] = ()
    min_band_rows: int = 2
    pad_rows: int = 2
    tile: Tuple[int, int] = (32, 128)

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"unknown detector mode {self.mode!r}; one of {MODES}")

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    def wants_detection(self, registry_hit: bool) -> bool:
        """Should the detector run for an instance with/without a registry
        scrub rule? (US never reaches here on a miss — it fails closed.)"""
        if self.mode == "union":
            return True
        if self.mode == "registry_first":
            return not registry_hit
        return False

    def tau_for(self, modality: str) -> float:
        for mod, frac in self.modality_row_frac:
            if mod == modality:
                return frac
        return self.row_frac

    @property
    def fingerprint_identity(self) -> str:
        """What the ruleset fingerprint folds in. ``mode="off"`` maps to the
        empty (pre-detector) identity: delivered bytes are provably those of
        a policy-less pipeline (tested), so a fleet staging the detector
        dark must keep serving its lake warm — and the other knobs are
        irrelevant while off, so they must not invalidate anything either."""
        return self.digest if self.enabled else ""

    @property
    def digest(self) -> str:
        """Stable identity of (detector version, policy knobs) — the value
        folded into the ruleset fingerprint (via :attr:`fingerprint_identity`)."""
        canon = "|".join(
            [
                DETECTOR_VERSION,
                self.mode,
                repr(self.binarize_frac),
                repr(self.row_frac),
                repr(tuple(sorted(self.modality_row_frac))),
                repr(self.min_band_rows),
                repr(self.pad_rows),
                repr(tuple(self.tile)),
            ]
        )
        return hashlib.sha256(canon.encode()).hexdigest()
