"""Band proposal geometry: profiles -> bands -> blank rectangles.

Host half of the burned-in-text detector (DESIGN.md §9). Consumes the
per-row glyph-hit counts produced by ``kernels/textdetect`` (Pallas kernel
or numpy oracle — bit-identical, so the rectangles below are too) and turns
them into the rectangles the scrub stage blanks:

* :func:`bands_from_hits` — rows whose hit count clears the width-relative
  threshold, grouped into contiguous bands, filtered by minimum height,
  padded, and re-merged.
* :func:`rects_from_bands` — bands become **full-width** blank rects. The
  column profile could trim a band horizontally, but glyph gaps (the dim
  inter-stroke pixels) carry PHI residue outside the hit columns, so
  trimming would fail *open*; full-width bands fail closed and text banners
  are band-shaped anyway. Column extent stays a report statistic.
* :func:`merge_rects` — exact-union rect normalization, shared with the
  scrub stage's registry+detector union: drops empties and contained rects,
  merges pairs whose union is exactly a rectangle (same column extent with
  overlapping/touching row ranges, or vice versa). The blanked pixel set is
  provably unchanged — only duplicates and double-covered tiles go away.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.dicom.devices import Rect

Band = Tuple[int, int]  # [y0, y1) row range


def bands_from_hits(
    hits: np.ndarray,
    width: int,
    *,
    row_frac: float,
    min_rows: int = 2,
    pad_rows: int = 2,
) -> List[Band]:
    """Group hot rows into candidate text bands.

    ``hits`` is the (H,) per-row glyph-hit count; a row is *hot* when it has
    at least ``ceil(row_frac * width)`` hits (integer compare — deterministic
    across platforms). Contiguous hot rows form a band; bands shorter than
    ``min_rows`` are dropped (speckle), survivors are padded by ``pad_rows``
    on both sides, clipped to the frame, and merged where padding made them
    overlap or touch.
    """
    H = int(hits.shape[0])
    need = max(1, int(np.ceil(row_frac * width)))
    hot = np.asarray(hits) >= need
    bands: List[Band] = []
    y = 0
    while y < H:
        if not hot[y]:
            y += 1
            continue
        y0 = y
        while y < H and hot[y]:
            y += 1
        if y - y0 >= min_rows:
            bands.append((max(0, y0 - pad_rows), min(H, y + pad_rows)))
    # padding may have fused neighbours
    merged: List[Band] = []
    for y0, y1 in bands:
        if merged and y0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], y1))
        else:
            merged.append((y0, y1))
    return merged


def rects_from_bands(bands: Sequence[Band], width: int) -> List[Rect]:
    """Full-width blank rects, one per band ((x, y, w, h) convention)."""
    return [(0, y0, width, y1 - y0) for y0, y1 in bands]


def _contains(a: Rect, b: Rect) -> bool:
    ax, ay, aw, ah = a
    bx, by, bw, bh = b
    return ax <= bx and ay <= by and bx + bw <= ax + aw and by + bh <= ay + ah


def _exact_union(a: Rect, b: Rect) -> Rect | None:
    """The union of a and b when it is exactly a rectangle, else None.

    Two cases: same column extent with overlapping-or-touching row ranges
    (stacked bands), or same row extent with overlapping-or-touching column
    ranges (side-by-side blocks). Anything else would over-blank, so it is
    left alone — merging here must never change the blanked pixel set.
    """
    ax, ay, aw, ah = a
    bx, by, bw, bh = b
    if ax == bx and aw == bw and not (ay + ah < by or by + bh < ay):
        y0 = min(ay, by)
        return (ax, y0, aw, max(ay + ah, by + bh) - y0)
    if ay == by and ah == bh and not (ax + aw < bx or bx + bw < ax):
        x0 = min(ax, bx)
        return (x0, ay, max(ax + aw, bx + bw) - x0, ah)
    return None


def merge_rects(rects: Sequence[Rect]) -> List[Rect]:
    """Normalize a blank-rect list without changing the blanked pixel set.

    Drops degenerate rects (w <= 0 or h <= 0 — pack_rects padding
    convention), dedupes, drops rects contained in another, and merges pairs
    whose union is exactly a rectangle, to a fixpoint. Registry + detector
    unions routinely produce overlapping and stacked rects; after this pass
    the fused kernel never blanks the same tile twice and the rect-count
    bucket stays small. Output is sorted (y, x, h, w) — deterministic
    regardless of input order.
    """
    work = sorted({(int(x), int(y), int(w), int(h)) for x, y, w, h in rects
                   if w > 0 and h > 0}, key=lambda r: (r[1], r[0], r[3], r[2]))
    changed = True
    while changed:
        changed = False
        out: List[Rect] = []
        for r in work:
            placed = False
            for i, q in enumerate(out):
                if _contains(q, r):
                    placed = True
                    break
                if _contains(r, q):
                    out[i] = r
                    placed = True
                    changed = True
                    break
                u = _exact_union(q, r)
                if u is not None:
                    out[i] = u
                    placed = True
                    changed = True
                    break
            if not placed:
                out.append(r)
        work = sorted(set(out), key=lambda r: (r[1], r[0], r[3], r[2]))
    return list(work)


def detect_bands_np(
    pixels: np.ndarray,
    *,
    thresh: float,
    row_frac: float,
    tile: Tuple[int, int] = (32, 128),
    min_rows: int = 2,
    pad_rows: int = 2,
    row_hits: np.ndarray | None = None,
) -> Tuple[List[Band], List[Rect]]:
    """One-image host detection: (bands, full-width blank rects).

    ``row_hits`` short-circuits the profile computation when a batched
    executor dispatch already produced it (kernel path); otherwise the numpy
    oracle runs — the two are bit-identical, so callers may mix freely.
    """
    H, W = pixels.shape[:2]
    if row_hits is None:
        from repro.kernels.textdetect.ref import row_hits_np

        row_hits = row_hits_np(pixels[None], thresh, tile)[0]
    bands = bands_from_hits(
        row_hits, W, row_frac=row_frac, min_rows=min_rows, pad_rows=pad_rows
    )
    return bands, rects_from_bands(bands, W)


def policy_thresh(ds, policy) -> float:
    """Binarization threshold for one dataset under a policy: the stored
    sample ceiling (BitsStored-aware, ``phi_detect``'s single derivation
    point) times the policy's fraction."""
    from repro.kernels.phi_detect.ops import stored_max_value

    return stored_max_value(ds) * policy.binarize_frac


def detect_bands_for(
    ds, policy, row_hits: np.ndarray | None = None, thresh: float | None = None
) -> Tuple[List[Band], List[Rect]]:
    """Dataset-level detection under a :class:`~repro.detect.DetectorPolicy`
    — the ONE place the ceiling -> threshold -> policy-knob forwarding
    lives. The scrub stage, the sim's PHI audit, and the catalog's
    ``burned_in_detected`` ingest column all call this, so their standards
    cannot drift apart."""
    if thresh is None:
        thresh = policy_thresh(ds, policy)
    return detect_bands_np(
        ds.pixels,
        thresh=thresh,
        row_frac=policy.tau_for(str(ds.get("Modality", ""))),
        tile=policy.tile,
        min_rows=policy.min_band_rows,
        pad_rows=policy.pad_rows,
        row_hits=row_hits,
    )
