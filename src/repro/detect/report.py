"""Per-instance detection reports and aggregate counters (auditing).

A :class:`DetectionReport` is attached to every :class:`ScrubResult` the
scrub stage produces while a :class:`DetectorPolicy` is active — it records
what the registry knew, whether the detector ran, under which thresholds,
and which rectangles were ultimately applied. The fleet surfaces the
aggregate :class:`DetectStats` as worker metrics (unknown-device lookups
are a first-class signal, not a silent pass-through).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

from repro.dicom.devices import Rect
from repro.detect.policy import DETECTOR_VERSION
from repro.obs.metrics import StatsShim

Band = Tuple[int, int]


@dataclass
class DetectionReport:
    """Everything one instance's rect resolution decided, for auditing."""

    sop_uid: str = ""
    modality: str = ""
    device: str = ""                 # DeviceKey.id() of the instance's tags
    registry_hit: bool = False
    detector_ran: bool = False
    ceiling: float = 0.0             # stored sample ceiling used
    thresh: float = 0.0              # binarization threshold used
    tau: float = 0.0                 # row-fraction threshold used
    bands: List[Band] = field(default_factory=list)
    detector_rects: List[Rect] = field(default_factory=list)
    registry_rects: List[Rect] = field(default_factory=list)
    applied_rects: List[Rect] = field(default_factory=list)
    version: str = DETECTOR_VERSION

    @property
    def detected(self) -> bool:
        """True when the detector ran and proposed at least one band."""
        return self.detector_ran and bool(self.bands)


class DetectStats(StatsShim):
    """Aggregate scrub-stage counters (worker metrics pull deltas of these).

    Attribute surface is unchanged; values are ``repro_detect_*`` counters so
    a shared registry sees the fleet-wide totals across pipelines.
    """

    _SUBSYSTEM = "detect"
    _FIELDS = (
        "instances",        # instances that went through rect resolution
        "registry_hits",    # resolved from the scrub script / registry
        "unknown_lookups",  # registry misses (unknown manufacturer/model)
        "detector_runs",    # instances the detector actually scanned
        "detected",         # scans that proposed at least one band
        "bands",            # total bands proposed
    )
