from repro.dicom.tags import TAGS, TagInfo, keyword_for
from repro.dicom.dataset import DicomDataset, new_uid
from repro.dicom.generator import StudyGenerator, SyntheticStudy
from repro.dicom import codec

__all__ = [
    "TAGS",
    "TagInfo",
    "keyword_for",
    "DicomDataset",
    "new_uid",
    "StudyGenerator",
    "SyntheticStudy",
    "codec",
]
