"""JPEG-Lossless-style codec (DICOM transfer syntax 1.2.840.10008.1.2.4.70).

The paper's scrub stage recompresses blanked images with the JPEG Lossless
syntax. Real JPEG-Lossless = per-pixel predictor (selection values 1-7) +
Huffman entropy coding. We implement the same two-phase structure:

* **prediction** — vectorizable; the numpy implementation here doubles as the
  oracle for the Pallas ``kernels/jls`` TPU kernel (prediction is pointwise on
  shifted planes, a perfect VPU workload);
* **entropy coding** — Golomb-Rice with per-image parameter + escape codes.
  Entropy coding is sequential bit-packing with no TPU analogue (see
  DESIGN.md §3); it stays on the host, exactly like the paper keeps it on CPU.

Round-trips are exact (lossless) — asserted by unit + property tests.
"""
from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

MAGIC = b"RJLS"
_QMAX = 23  # unary quotient cap; larger quotients use a 32-bit escape


# --------------------------------------------------------------- prediction
def predict(img: np.ndarray, sv: int = 1) -> np.ndarray:
    """Predicted plane for selection value ``sv`` (JPEG lossless T.81 Annex H).

    Border convention: (0,0) predicted by 2^(P-1); row 0 by Ra (left);
    column 0 by Rb (above). Works on any unsigned integer dtype.
    """
    if img.ndim != 2:
        raise ValueError("predict expects a 2D plane")
    bits = img.dtype.itemsize * 8
    x = img.astype(np.int64)
    ra = np.empty_like(x)  # left
    rb = np.empty_like(x)  # above
    rc = np.empty_like(x)  # above-left
    ra[:, 1:], ra[:, 0] = x[:, :-1], 0
    rb[1:, :], rb[0, :] = x[:-1, :], 0
    rc[1:, 1:], rc[0, :], rc[1:, 0] = x[:-1, :-1], 0, 0

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(f"selection value must be 1..7, got {sv}")

    # border overrides (same for every sv)
    pred[0, 1:] = ra[0, 1:]
    pred[1:, 0] = rb[1:, 0]
    pred[0, 0] = 1 << (bits - 1)
    return pred


def residuals(img: np.ndarray, sv: int = 1) -> np.ndarray:
    """Signed modulo-2^P residuals, centered in [-2^(P-1), 2^(P-1))."""
    bits = img.dtype.itemsize * 8
    mask = (1 << bits) - 1
    r = (img.astype(np.int64) - predict(img, sv)) & mask
    r = np.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    return r.astype(np.int32)


def reconstruct(res: np.ndarray, sv: int, bits: int) -> np.ndarray:
    """Invert :func:`residuals`. sv 1/2 use vectorized cumsum; others loop."""
    mask = (1 << bits) - 1
    r = res.astype(np.int64)
    H, W = r.shape
    if sv == 1:
        # column 0 reconstructs downward, rows reconstruct left->right
        col0 = np.cumsum(r[:, 0], axis=0) + (1 << (bits - 1))
        rows = r.copy()
        rows[:, 0] = col0
        out = np.cumsum(rows, axis=1)
        return (out & mask).astype(np.uint16 if bits > 8 else np.uint8)
    if sv == 2:
        row0 = np.cumsum(r[0, :], axis=0) + (1 << (bits - 1))
        cols = r.copy()
        cols[0, :] = row0
        out = np.cumsum(cols, axis=0)
        return (out & mask).astype(np.uint16 if bits > 8 else np.uint8)
    # general (sequential) path — used only for small images in tests
    out = np.zeros((H, W), np.int64)
    for i in range(H):
        for j in range(W):
            if i == 0 and j == 0:
                pred = 1 << (bits - 1)
            elif i == 0:
                pred = out[0, j - 1]
            elif j == 0:
                pred = out[i - 1, 0]
            else:
                ra, rb, rc = out[i, j - 1], out[i - 1, j], out[i - 1, j - 1]
                pred = {3: rc, 4: ra + rb - rc, 5: ra + ((rb - rc) >> 1),
                        6: rb + ((ra - rc) >> 1), 7: (ra + rb) >> 1}[sv]
            out[i, j] = (pred + r[i, j]) & mask
    return out.astype(np.uint16 if bits > 8 else np.uint8)


# --------------------------------------------------------------- rice coding
def _zigzag(r: np.ndarray) -> np.ndarray:
    return ((r.astype(np.int64) << 1) ^ (r.astype(np.int64) >> 63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.int64)
    return (u >> 1) ^ -(u & 1)


def _rice_k(u: np.ndarray) -> int:
    mean = float(u.mean()) if u.size else 0.0
    k = 0
    while (1 << k) < mean + 1 and k < 30:
        k += 1
    return k


def rice_encode(res: np.ndarray) -> Tuple[bytes, int]:
    """Vectorized Golomb-Rice encoder. Returns (payload, k)."""
    u = _zigzag(res.ravel())
    k = _rice_k(u)
    q = (u >> k).astype(np.int64)
    rem = (u & ((1 << k) - 1)).astype(np.uint64)
    esc = q > _QMAX
    # bit lengths: unary(q)+stop + k remainder; escape: QMAX+1 ones + stop + 64 raw
    lens = np.where(esc, _QMAX + 2 + 64, q + 1 + k)
    offs = np.concatenate([[0], np.cumsum(lens)])
    total = int(offs[-1])
    bits = np.zeros(total, np.uint8)

    # unary ones via range-marking + cumsum (vectorized run fill)
    delta = np.zeros(total + 1, np.int32)
    q_eff = np.where(esc, _QMAX + 1, q)
    nz = q_eff > 0
    np.add.at(delta, offs[:-1][nz], 1)
    np.add.at(delta, (offs[:-1] + q_eff)[nz], -1)
    bits[np.cumsum(delta[:-1]) > 0] = 1

    # remainder bits (k small): one vectorized pass per bit position
    if k and (~esc).any():
        base = (offs[:-1] + q + 1)[~esc]
        rne = rem[~esc]
        for j in range(k):
            bits[base + j] = (rne >> np.uint64(k - 1 - j)) & np.uint64(1)
    # escapes: rare; raw 64-bit value after the capped unary + stop
    for idx in np.flatnonzero(esc):
        base = int(offs[idx]) + _QMAX + 2
        val = int(u[idx])
        for j in range(64):
            bits[base + j] = (val >> (63 - j)) & 1
    return np.packbits(bits).tobytes(), k


def rice_decode(payload: bytes, k: int, n: int) -> np.ndarray:
    bits = np.unpackbits(np.frombuffer(payload, np.uint8))
    zeros = np.flatnonzero(bits == 0)
    out = np.empty(n, np.uint64)
    p = 0
    zi = 0
    for i in range(n):
        # find first zero at/after p (the unary terminator)
        zi = int(np.searchsorted(zeros, p))
        zpos = int(zeros[zi])
        q = zpos - p
        p = zpos + 1
        if q == _QMAX + 1:  # escape: raw 64-bit
            val = 0
            for j in range(64):
                val = (val << 1) | int(bits[p + j])
            p += 64
            out[i] = val
        else:
            rem = 0
            for j in range(k):
                rem = (rem << 1) | int(bits[p + j])
            p += k
            out[i] = (q << k) | rem
    return _unzigzag(out)


# --------------------------------------------------------------- container
def pack_header(h: int, w: int, bits: int, sv: int, k: int, nbytes: int) -> bytes:
    """Plane header: magic, dims, bits, sv, rice k, payload length.

    Single source of truth for the RJLS plane header layout — used by the
    pure-host :func:`encode`, the kernel-assisted ``kernels/jls`` encode path,
    and the fused batch executor, so the three streams stay byte-identical.
    """
    return MAGIC + b"P" + struct.pack("<IIBBBI", h, w, bits, sv, k, nbytes)


def encode(img: np.ndarray, sv: int = 1) -> bytes:
    """Encode a 2D unsigned-int plane. Header: magic, dims, bits, sv, k, nbytes."""
    if img.ndim == 3:  # multi-sample: encode planes back to back
        planes = [encode(img[..., c], sv) for c in range(img.shape[-1])]
        return MAGIC + b"M" + struct.pack("<H", len(planes)) + b"".join(
            struct.pack("<I", len(p)) + p for p in planes
        )
    bits = img.dtype.itemsize * 8
    res = residuals(img, sv)
    payload, k = rice_encode(res)
    return pack_header(img.shape[0], img.shape[1], bits, sv, k, len(payload)) + payload


def decode(buf: bytes) -> np.ndarray:
    if buf[:4] != MAGIC:
        raise ValueError("not an RJLS stream")
    kind = buf[4:5]
    if kind == b"M":
        (nplanes,) = struct.unpack("<H", buf[5:7])
        off = 7
        planes = []
        for _ in range(nplanes):
            (ln,) = struct.unpack("<I", buf[off : off + 4])
            off += 4
            planes.append(decode(buf[off : off + ln]))
            off += ln
        return np.stack(planes, axis=-1)
    H, W, bits, sv, k, nbytes = struct.unpack("<IIBBBI", buf[5:20])
    payload = buf[20 : 20 + nbytes]
    res = rice_decode(payload, k, H * W).reshape(H, W).astype(np.int32)
    return reconstruct(res, sv, bits)


def compression_ratio(img: np.ndarray, sv: int = 1) -> float:
    return img.nbytes / max(1, len(encode(img, sv)))
