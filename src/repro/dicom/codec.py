"""JPEG-Lossless-style codec (DICOM transfer syntax 1.2.840.10008.1.2.4.70).

The paper's scrub stage recompresses blanked images with the JPEG Lossless
syntax. Real JPEG-Lossless = per-pixel predictor (selection values 1-7) +
Huffman entropy coding. We implement the same two-phase structure:

* **prediction** — vectorizable; the numpy implementation here doubles as the
  oracle for the Pallas ``kernels/jls`` TPU kernel (prediction is pointwise on
  shifted planes, a perfect VPU workload);
* **entropy coding** — Golomb-Rice with per-image parameter + escape codes.
  The coder is split into two phases (DESIGN.md §12): a **plan** phase
  (:func:`rice_plan`) that derives the zigzag magnitudes, the Rice parameter
  ``k``, per-symbol code lengths, and their prefix-sum bit offsets — all
  vectorizable, and computable on the accelerator by the ``kernels/jls``
  entropy pre-pass — and a **pack** phase (:func:`rice_pack`) that splices
  the variable-length codes into the final bitstream with word-level
  scatter-OR writes. Only the pack splice is inherently host work.

Round-trips are exact (lossless) — asserted by unit + property tests.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

MAGIC = b"RJLS"
_QMAX = 23  # unary quotient cap; larger quotients use a 32-bit escape


# --------------------------------------------------------------- prediction
def predict(img: np.ndarray, sv: int = 1) -> np.ndarray:
    """Predicted plane for selection value ``sv`` (JPEG lossless T.81 Annex H).

    Border convention: (0,0) predicted by 2^(P-1); row 0 by Ra (left);
    column 0 by Rb (above). Works on any unsigned integer dtype.
    """
    if img.ndim != 2:
        raise ValueError("predict expects a 2D plane")
    bits = img.dtype.itemsize * 8
    x = img.astype(np.int64)
    ra = np.empty_like(x)  # left
    rb = np.empty_like(x)  # above
    rc = np.empty_like(x)  # above-left
    ra[:, 1:], ra[:, 0] = x[:, :-1], 0
    rb[1:, :], rb[0, :] = x[:-1, :], 0
    rc[1:, 1:], rc[0, :], rc[1:, 0] = x[:-1, :-1], 0, 0

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(f"selection value must be 1..7, got {sv}")

    # border overrides (same for every sv)
    pred[0, 1:] = ra[0, 1:]
    pred[1:, 0] = rb[1:, 0]
    pred[0, 0] = 1 << (bits - 1)
    return pred


def residuals(img: np.ndarray, sv: int = 1) -> np.ndarray:
    """Signed modulo-2^P residuals, centered in [-2^(P-1), 2^(P-1))."""
    bits = img.dtype.itemsize * 8
    mask = (1 << bits) - 1
    r = (img.astype(np.int64) - predict(img, sv)) & mask
    r = np.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    return r.astype(np.int32)


def residuals_batch(imgs: np.ndarray, sv: int = 1) -> np.ndarray:
    """Batched :func:`residuals` over a uniform (N, H, W) stack.

    Bit-identical to calling :func:`residuals` per plane (property-tested) —
    the predictor is pointwise over shifted planes, so batching just moves
    the shifts one axis over. Used by the batched executor's host path so a
    chunk pays one vectorized pass instead of N small ones.
    """
    if imgs.ndim != 3:
        raise ValueError("residuals_batch expects an (N, H, W) stack")
    bits = imgs.dtype.itemsize * 8
    x = imgs.astype(np.int64)
    N, H, W = x.shape
    zc = np.zeros((N, H, 1), np.int64)
    zr = np.zeros((N, 1, W), np.int64)
    ra = np.concatenate([zc, x[:, :, :-1]], axis=2)   # left
    rb = np.concatenate([zr, x[:, :-1, :]], axis=1)   # above
    rc = np.concatenate([zr, ra[:, :-1, :]], axis=1)  # above-left

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(f"selection value must be 1..7, got {sv}")

    pred[:, 0, 1:] = ra[:, 0, 1:]
    pred[:, 1:, 0] = rb[:, 1:, 0]
    pred[:, 0, 0] = 1 << (bits - 1)

    mask = (1 << bits) - 1
    r = (x - pred) & mask
    r = np.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    return r.astype(np.int32)


def reconstruct(res: np.ndarray, sv: int, bits: int) -> np.ndarray:
    """Invert :func:`residuals`. sv 1/2 use vectorized cumsum; others loop."""
    mask = (1 << bits) - 1
    r = res.astype(np.int64)
    H, W = r.shape
    if sv == 1:
        # column 0 reconstructs downward, rows reconstruct left->right
        col0 = np.cumsum(r[:, 0], axis=0) + (1 << (bits - 1))
        rows = r.copy()
        rows[:, 0] = col0
        out = np.cumsum(rows, axis=1)
        return (out & mask).astype(np.uint16 if bits > 8 else np.uint8)
    if sv == 2:
        row0 = np.cumsum(r[0, :], axis=0) + (1 << (bits - 1))
        cols = r.copy()
        cols[0, :] = row0
        out = np.cumsum(cols, axis=0)
        return (out & mask).astype(np.uint16 if bits > 8 else np.uint8)
    # general (sequential) path — used only for small images in tests
    out = np.zeros((H, W), np.int64)
    for i in range(H):
        for j in range(W):
            if i == 0 and j == 0:
                pred = 1 << (bits - 1)
            elif i == 0:
                pred = out[0, j - 1]
            elif j == 0:
                pred = out[i - 1, 0]
            else:
                ra, rb, rc = out[i, j - 1], out[i - 1, j], out[i - 1, j - 1]
                pred = {3: rc, 4: ra + rb - rc, 5: ra + ((rb - rc) >> 1),
                        6: rb + ((ra - rc) >> 1), 7: (ra + rb) >> 1}[sv]
            out[i, j] = (pred + r[i, j]) & mask
    return out.astype(np.uint16 if bits > 8 else np.uint8)


# --------------------------------------------------------------- rice coding
def _zigzag(r: np.ndarray) -> np.ndarray:
    return ((r.astype(np.int64) << 1) ^ (r.astype(np.int64) >> 63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.int64)
    return (u >> 1) ^ -(u & 1)


def _rice_k_from_sum(total: int, size: int) -> int:
    """Rice parameter from the exact integer sum of the zigzag magnitudes.

    The exact-sum form lets the device entropy pre-pass hand back per-row
    integer sums and still land on the same ``k`` as the host (bit-identity
    across the two plan paths is what keeps batched == serial).
    """
    mean = total / size if size else 0.0
    k = 0
    while (1 << k) < mean + 1 and k < 30:
        k += 1
    return k


def _rice_k(u: np.ndarray) -> int:
    return _rice_k_from_sum(int(u.sum(dtype=np.uint64)), u.size)


@dataclass
class RicePlan:
    """Phase-1 output of the Golomb-Rice coder: everything except the splice.

    ``u`` are the zigzag magnitudes, ``lens`` the per-symbol code lengths,
    ``offs`` their exclusive prefix-sum bit offsets (len n+1). ``rem`` is the
    optional pre-extracted k-bit remainder word per symbol — the device
    entropy pre-pass hands it back so the host pack never touches ``u`` for
    non-escape symbols.
    """

    k: int
    u: np.ndarray
    q: np.ndarray
    esc: np.ndarray
    lens: np.ndarray
    offs: np.ndarray
    rem: Optional[np.ndarray] = None

    @property
    def total_bits(self) -> int:
        return int(self.offs[-1])


def rice_plan(res: np.ndarray) -> RicePlan:
    """Host plan phase: zigzag, k, quotients, code lengths, bit offsets."""
    u = _zigzag(res.ravel())
    k = _rice_k(u)
    return _plan_from_u(u, k)


def _plan_from_u(u: np.ndarray, k: int) -> RicePlan:
    q = (u >> np.uint64(k)).astype(np.int64)
    esc = q > _QMAX
    # bit lengths: unary(q)+stop + k remainder; escape: QMAX+1 ones + stop + 64 raw
    lens = np.where(esc, _QMAX + 2 + 64, q + 1 + k)
    offs = np.empty(lens.size + 1, np.int64)
    offs[0] = 0
    np.cumsum(lens, out=offs[1:])
    return RicePlan(k=k, u=u, q=q, esc=esc, lens=lens, offs=offs)


def rice_plan_from_prepass(
    u: np.ndarray, k: int, lens: np.ndarray, rem: Optional[np.ndarray] = None
) -> RicePlan:
    """Plan from the device entropy pre-pass (``kernels/jls`` length kernel):
    the device already computed zigzag magnitudes, per-symbol code lengths,
    and remainder words; the host only prefix-sums the lengths. Bit-identical
    to :func:`rice_plan` on the same residuals (parity-tested)."""
    u = u.ravel().astype(np.uint64)
    q = (u >> np.uint64(k)).astype(np.int64)
    esc = q > _QMAX
    lens = lens.ravel().astype(np.int64)
    offs = np.empty(lens.size + 1, np.int64)
    offs[0] = 0
    np.cumsum(lens, out=offs[1:])
    return RicePlan(
        k=k, u=u, q=q, esc=esc, lens=lens, offs=offs,
        rem=None if rem is None else rem.ravel().astype(np.uint64),
    )


def _scatter_field(
    words: np.ndarray, pos: np.ndarray, val: np.ndarray, nbits: np.ndarray
) -> None:
    """OR variable-width bit fields into an MSB-first uint64 word stream.

    ``val`` (uint64) is written so its bit ``nbits-1`` lands at stream bit
    position ``pos``. Fields are <= 64 bits, so each spans at most two words;
    fields never overlap, so scatter-add == scatter-or (``np.add.at`` takes
    the fast unbuffered path).
    """
    idx = (pos >> 6).astype(np.int64)
    sh = 64 - (pos & 63) - nbits  # left shift into the first word (may be <0)
    lo = sh < 0
    first = np.where(
        lo,
        val >> (-sh).clip(min=0).astype(np.uint64),
        val << sh.clip(min=0).astype(np.uint64),
    )
    np.add.at(words, idx, first)
    if lo.any():
        # low -sh bits spill left-aligned into the next word; the uint64
        # left shift drops the already-written high bits for free
        np.add.at(words, idx[lo] + 1, val[lo] << (64 + sh[lo]).astype(np.uint64))


def rice_pack(plan: RicePlan) -> bytes:
    """Pack phase: splice the planned codes into the final byte stream.

    Word-level construction — two vectorized scatter passes (one per field
    kind) over uint64 words instead of materializing one byte per *bit* —
    byte-identical to the legacy bit-array packer (property-tested).
    """
    total = plan.total_bits
    words = np.zeros((total + 63) // 64 + 1, np.uint64)
    offs = plan.offs[:-1]
    k = plan.k
    ne = ~plan.esc
    if ne.any():
        # non-escape: unary(q) ones + stop + k remainder is one contiguous
        # field of q+1+k <= QMAX+1+k bits: ((2^q - 1) << (k+1)) | rem
        q = plan.q[ne].astype(np.uint64)
        rem = (
            plan.rem[ne]
            if plan.rem is not None
            else plan.u[ne] & np.uint64((1 << k) - 1)
        )
        val = (((np.uint64(1) << q) - np.uint64(1)) << np.uint64(k + 1)) | rem
        _scatter_field(words, offs[ne], val, plan.lens[ne])
    if plan.esc.any():
        eoffs = offs[plan.esc]
        ones = np.full(eoffs.size, ((1 << (_QMAX + 1)) - 1) << 1, np.uint64)
        _scatter_field(
            words, eoffs, ones, np.full(eoffs.size, _QMAX + 2, np.int64)
        )
        _scatter_field(
            words,
            eoffs + _QMAX + 2,
            plan.u[plan.esc],
            np.full(eoffs.size, 64, np.int64),
        )
    return words.astype(">u8").tobytes()[: (total + 7) // 8]


def rice_encode(res: np.ndarray) -> Tuple[bytes, int]:
    """Golomb-Rice encoder (plan + pack). Returns (payload, k)."""
    plan = rice_plan(res)
    return rice_pack(plan), plan.k


def rice_decode(payload: bytes, k: int, n: int) -> np.ndarray:
    """Vectorized Golomb-Rice decoder.

    Fast path assumes no escape codes: with a fixed k-bit field after every
    unary terminator, "index of the next terminator zero" is a function of
    the current one alone (``nxt``), so the parse is a pointer chase with an
    O(1) body plus fully vectorized remainder extraction. The first escape
    symbol always surfaces as a decoded quotient of QMAX+1 (the parse is
    exact up to that point), which falls back to the sequential decoder.
    """
    bits = np.unpackbits(np.frombuffer(payload, np.uint8))
    if n == 0:
        return np.empty(0, np.int64)
    zeros = np.flatnonzero(bits == 0)
    Z = zeros.size
    # successor map in terminator-index space: given terminator z, the next
    # terminator is the first zero at/after zeros[z]+1+k; Z is a sticky
    # "ran off the stream" sentinel so gathers never go out of bounds
    nxt = np.empty(Z + 1, np.int64)
    np.searchsorted(zeros, zeros + (1 + k), side="left", sorter=None).astype(
        np.int64
    ).clip(max=Z, out=nxt[:Z])
    nxt[Z] = Z
    t = _chase(nxt, Z, n)
    if t is None or t[-1] >= Z:
        return _rice_decode_sequential(bits, zeros, k, n)
    zpos = zeros[t]
    starts = np.empty(n, np.int64)
    starts[0] = 0
    starts[1:] = zpos[:-1] + 1 + k
    q = zpos - starts
    if (q > _QMAX).any() or (q < 0).any():  # first escape decodes as QMAX+1
        return _rice_decode_sequential(bits, zeros, k, n)
    rem = np.zeros(n, np.uint64)
    for j in range(k):  # k vectorized passes, not n*k scalar reads
        rem = (rem << np.uint64(1)) | bits[zpos + 1 + j].astype(np.uint64)
    return _unzigzag((q.astype(np.uint64) << np.uint64(k)) | rem)


_CHASE_STRIDE = 8


def _chase(nxt: np.ndarray, Z: int, n: int) -> Optional[np.ndarray]:
    """First n elements of the orbit 0, nxt[0], nxt[nxt[0]], ...

    The orbit is inherently sequential, but composing the successor map with
    itself (``g8 = nxt^8``) cuts the Python-level chase to n/8 iterations;
    the skipped intermediates are recovered with 7 vectorized gathers.
    Returns None when the orbit hits the sentinel Z early (invalid parse).
    """
    if n < 4 * _CHASE_STRIDE:
        out = np.empty(n, np.int64)
        cur = 0
        for i in range(n):
            out[i] = cur
            cur = nxt[cur]
        return None if out[-1] >= Z else out
    g2 = nxt[nxt]
    g4 = g2[g2]
    g8 = g4[g4]
    heads = np.empty(n // _CHASE_STRIDE, np.int64)
    cur = 0
    for i in range(heads.size):
        heads[i] = cur
        cur = g8[cur]
    if heads[-1] >= Z:
        return None
    t = np.empty((heads.size + 1) * _CHASE_STRIDE, np.int64)
    cols = t[: heads.size * _CHASE_STRIDE].reshape(heads.size, _CHASE_STRIDE)
    cols[:, 0] = heads
    for j in range(1, _CHASE_STRIDE):
        cols[:, j] = nxt[cols[:, j - 1]]
    for i in range(heads.size * _CHASE_STRIDE, n):  # tail, < STRIDE steps
        t[i] = cur
        cur = nxt[cur]
    return t[:n]


def _rice_decode_sequential(
    bits: np.ndarray, zeros: np.ndarray, k: int, n: int
) -> np.ndarray:
    """Escape-capable sequential parse (list-backed bit reads, O(log Z)
    terminator lookups) — only streams containing escape codes land here."""
    out = np.empty(n, np.uint64)
    bl = bits.tolist()
    p = 0
    for i in range(n):
        zpos = int(zeros[np.searchsorted(zeros, p)])  # the unary terminator
        q = zpos - p
        p = zpos + 1
        if q == _QMAX + 1:  # escape: raw 64-bit
            val = 0
            for j in range(64):
                val = (val << 1) | bl[p + j]
            p += 64
            out[i] = val
        else:
            rem = 0
            for j in range(k):
                rem = (rem << 1) | bl[p + j]
            p += k
            out[i] = (q << k) | rem
    return _unzigzag(out)


# --------------------------------------------------------------- container
def pack_header(h: int, w: int, bits: int, sv: int, k: int, nbytes: int) -> bytes:
    """Plane header: magic, dims, bits, sv, rice k, payload length.

    Single source of truth for the RJLS plane header layout — used by the
    pure-host :func:`encode`, the kernel-assisted ``kernels/jls`` encode path,
    and the fused batch executor, so the three streams stay byte-identical.
    """
    return MAGIC + b"P" + struct.pack("<IIBBBI", h, w, bits, sv, k, nbytes)


def encode(img: np.ndarray, sv: int = 1) -> bytes:
    """Encode a 2D unsigned-int plane. Header: magic, dims, bits, sv, k, nbytes."""
    if img.ndim == 3:  # multi-sample: encode planes back to back
        planes = [encode(img[..., c], sv) for c in range(img.shape[-1])]
        return MAGIC + b"M" + struct.pack("<H", len(planes)) + b"".join(
            struct.pack("<I", len(p)) + p for p in planes
        )
    bits = img.dtype.itemsize * 8
    res = residuals(img, sv)
    payload, k = rice_encode(res)
    return pack_header(img.shape[0], img.shape[1], bits, sv, k, len(payload)) + payload


def decode(buf: bytes) -> np.ndarray:
    if buf[:4] != MAGIC:
        raise ValueError("not an RJLS stream")
    kind = buf[4:5]
    if kind == b"M":
        (nplanes,) = struct.unpack("<H", buf[5:7])
        off = 7
        planes = []
        for _ in range(nplanes):
            (ln,) = struct.unpack("<I", buf[off : off + 4])
            off += 4
            planes.append(decode(buf[off : off + ln]))
            off += ln
        return np.stack(planes, axis=-1)
    H, W, bits, sv, k, nbytes = struct.unpack("<IIBBBI", buf[5:20])
    payload = buf[20 : 20 + nbytes]
    res = rice_decode(payload, k, H * W).reshape(H, W).astype(np.int32)
    return reconstruct(res, sv, bits)


def compression_ratio(img: np.ndarray, sv: int = 1) -> float:
    return img.nbytes / max(1, len(encode(img, sv)))
