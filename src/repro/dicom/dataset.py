"""In-memory DICOM dataset model.

A :class:`DicomDataset` is an ordered mapping of keyword -> value plus an
optional pixel array (numpy, HxW or HxWxC). Private tags (odd groups) are kept
in a separate ``private`` dict keyed by (group, element) hex strings, because
the de-identification engine treats them categorically (remove-all unless
whitelisted), mirroring CTP's behaviour.

The dataset is deliberately *not* a jax type: metadata handling is host-side
control plane. Pixel data crosses into jax only inside the scrub stage.
"""
from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.dicom.tags import TAGS

_UID_ROOT = "1.2.840.99999.2.1"  # research root, not a registered OID
_uid_counter = itertools.count(1)


def normalize_cs(value: Any) -> str:
    """Normalize a CS-like string value for comparison: collapse internal
    whitespace runs, strip, uppercase. DICOM CS values are case-insensitive
    and frequently space-padded by devices; every metadata comparison in the
    engine (filter rules, catalog dictionary encoding) goes through this one
    function so the two layers can never disagree about what "equal" means."""
    return " ".join(str(value).split()).upper()


def new_uid(entropy: Optional[str] = None) -> str:
    """Generate a DICOM UID. Deterministic when ``entropy`` is given."""
    if entropy is not None:
        h = int.from_bytes(hashlib.sha256(entropy.encode()).digest()[:8], "big")
        return f"{_UID_ROOT}.{h}"
    return f"{_UID_ROOT}.{next(_uid_counter)}"


@dataclass
class DicomDataset:
    """One SOP instance (a single DICOM image/object)."""

    elements: Dict[str, Any] = field(default_factory=dict)
    private: Dict[str, Any] = field(default_factory=dict)
    pixels: Optional[np.ndarray] = None
    # Encapsulated payload for non-image objects (PDF/SR), mirrors real DICOM.
    encapsulated: Optional[bytes] = None

    # -- mapping-ish interface ----------------------------------------------
    def get(self, keyword: str, default: Any = None) -> Any:
        return self.elements.get(keyword, default)

    def __getitem__(self, keyword: str) -> Any:
        return self.elements[keyword]

    def __setitem__(self, keyword: str, value: Any) -> None:
        if keyword not in TAGS:
            raise KeyError(f"unknown DICOM keyword {keyword!r}; add it to repro.dicom.tags")
        self.elements[keyword] = value

    def __contains__(self, keyword: str) -> bool:
        return keyword in self.elements

    def __delitem__(self, keyword: str) -> None:
        del self.elements[keyword]

    def keys(self) -> Iterator[str]:
        return iter(self.elements.keys())

    def pop(self, keyword: str, default: Any = None) -> Any:
        return self.elements.pop(keyword, default)

    # -- helpers ---------------------------------------------------------------
    @property
    def shape(self) -> Optional[Tuple[int, ...]]:
        return None if self.pixels is None else tuple(self.pixels.shape)

    def nbytes(self) -> int:
        n = sum(len(str(v)) for v in self.elements.values())
        if self.pixels is not None:
            n += self.pixels.nbytes
        if self.encapsulated is not None:
            n += len(self.encapsulated)
        return n

    def matches(self, keyword: str, value: Any) -> bool:
        """Case/whitespace-insensitive equality against a tag value (CS-like
        semantics via :func:`normalize_cs`). False when the tag is absent.
        Shared by the filter stage's equals/notequals/in ops and the catalog's
        dictionary encoding."""
        if keyword not in self.elements:
            return False
        return normalize_cs(self.elements[keyword]) == normalize_cs(value)

    def image_type_contains(self, token: str) -> bool:
        it = self.get("ImageType", "")
        parts = it.split("\\") if isinstance(it, str) else list(it)
        return token.upper() in [p.upper() for p in parts]

    def resolution(self) -> Optional[Tuple[int, int]]:
        r, c = self.get("Rows"), self.get("Columns")
        if r is None or c is None:
            return None
        return int(r), int(c)

    def copy(self) -> "DicomDataset":
        return DicomDataset(
            elements=dict(self.elements),
            private=dict(self.private),
            pixels=None if self.pixels is None else self.pixels.copy(),
            encapsulated=self.encapsulated,
        )

    def summary(self) -> str:
        return (
            f"<DicomDataset {self.get('Modality','?')} {self.get('Manufacturer','?')}"
            f"/{self.get('ManufacturerModelName','?')} {self.shape} "
            f"sop={self.get('SOPInstanceUID','?')[-8:]}>"
        )
