"""Device registry: makes, models, resolutions, and PHI burn-in geometry.

This is the single source of truth shared by (a) the synthetic study generator,
which burns PHI text into the regions a given device stamps, and (b) the scrub
rule scripts, which blank those regions. That mirrors the paper's methodology:
scrub rules are derived per (make, model, resolution) from observed device
behaviour (Figure 2a), and ultrasound is *whitelist-only* (Table 2) because its
burn-in layout varies per resolution even within one model.

Counts reproduce paper Table 2: 11 ultrasound makes, the listed model counts and
resolution-variation counts (e.g. GE: 35 models, 151 resolution variants).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

Rect = Tuple[int, int, int, int]  # x, y, w, h  (paper's Fig 2b convention)

# --- Table 2 (paper): ultrasound makes -> (model count, resolution variations) ---
ULTRASOUND_TABLE2: Dict[str, Tuple[int, int]] = {
    "GE": (35, 151),
    "Siemens": (13, 24),
    "Acuson": (2, 14),
    "Philips": (12, 22),
    "Toshiba": (13, 24),
    "SonoSite": (6, 7),
    "Zonare": (3, 4),
    "BK Medical": (3, 7),
    "Aloka": (7, 10),
    "SuperSonic Imaging": (1, 15),
    "Samsung": (8, 16),
}

_US_RESOLUTIONS: List[Tuple[int, int]] = [
    (480, 640), (600, 800), (768, 1024), (720, 960), (960, 1280),
    (576, 768), (480, 720), (540, 720), (768, 1280), (1080, 1920),
    (624, 832), (712, 952), (480, 800), (664, 888), (600, 1024),
]


def _h(s: str) -> int:
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8], "big")


@dataclass(frozen=True)
class DeviceKey:
    modality: str
    make: str
    model: str
    rows: int
    cols: int

    def id(self) -> str:
        return f"{self.modality}/{self.make}/{self.model}/{self.rows}x{self.cols}"


def _synth_rects(key: DeviceKey, n: int) -> List[Rect]:
    """Deterministic pseudo-random burn-in rectangles for a device variant.

    Layouts imitate real devices: a top banner (patient name/MRN), a corner
    block (institution / tech initials), and optionally a bottom strip
    (measurements). Geometry is hash-derived so every (make, model, resolution)
    differs — the property the paper cites as making ultrasound hard.
    """
    rects: List[Rect] = []
    seed = _h(key.id())
    H, W = key.rows, key.cols
    # top banner, always present
    bh = 16 + (seed % 5) * 8
    rects.append((0, 0, W, min(bh, H // 4)))
    if n >= 2:  # corner block
        cw, ch = W // 4 + (seed >> 8) % 32, 24 + (seed >> 16) % 40
        side = (seed >> 24) % 2
        x = 0 if side else max(0, W - cw)
        y = min(H - ch - 1, bh + 4 + (seed >> 32) % 16)
        rects.append((x, y, min(cw, W), min(ch, H - y)))
    if n >= 3:  # bottom strip
        sh = 10 + (seed >> 40) % 14
        rects.append((0, max(0, H - sh), W, sh))
    return rects[:n]


def _variant_resolution(make: str, model: str, i: int) -> Tuple[int, int]:
    """Unique-per-(model, i) resolution: a base mode plus device-specific
    crop offsets in multiples of 8 (how real US consoles vary: same probe
    mode, different screen layout)."""
    base_r, base_c = _US_RESOLUTIONS[_h(f"{make}/{model}") % len(_US_RESOLUTIONS)]
    return base_r + 8 * (i % 40), base_c + 8 * (i // 40 * 3 + (_h(f"{model}/{i}") % 3))


def build_ultrasound_whitelist() -> Dict[str, List[DeviceKey]]:
    """Expand Table 2 counts into concrete device variants, per make.

    Resolution variants are distributed across models round-robin so the total
    per make matches the paper's 'Resolution variations' column exactly.
    """
    out: Dict[str, List[DeviceKey]] = {}
    for make, (n_models, n_res_vars) in ULTRASOUND_TABLE2.items():
        models = [f"{make.upper().replace(' ', '')}-U{i+1:02d}" for i in range(n_models)]
        # GE's flagship gets the long tail (paper: LOGIQE9 alone had 38 resolutions)
        if make == "GE":
            models[0] = "LOGIQE9"
        variants: List[DeviceKey] = []
        per_model_count: Dict[str, int] = {m: 0 for m in models}
        i = 0
        while len(variants) < n_res_vars:
            if make == "GE" and len(variants) < 38:
                model = models[0]
            else:
                model = models[i % n_models]
            rows, cols = _variant_resolution(make, model, per_model_count[model])
            per_model_count[model] += 1
            key = DeviceKey("US", make, model, rows, cols)
            if key not in variants:
                variants.append(key)
            i += 1
        out[make] = variants
    return out


# --- Non-US modalities: a small registry of representative devices -------------
FIXED_DEVICES: List[DeviceKey] = [
    DeviceKey("CT", "GE", "Discovery", 512, 512),       # paper Fig 2b PET/CT fusion
    DeviceKey("CT", "Siemens", "SOMATOM", 512, 512),
    DeviceKey("CT", "Toshiba", "Aquilion", 512, 512),
    DeviceKey("MR", "GE", "SIGNA", 256, 256),
    DeviceKey("MR", "Siemens", "Skyra", 320, 320),
    DeviceKey("PT", "GE", "Discovery", 512, 512),
    DeviceKey("DX", "Philips", "DigitalDiagnost", 2022, 2022),
    DeviceKey("DX", "GE", "Definium", 2500, 2048),
    DeviceKey("CR", "Fuji", "FCR", 1760, 2140),
    DeviceKey("US", "UnknownMake", "Mystery-1", 480, 640),  # NOT whitelisted -> filtered
]

# Vidar film digitizer: always filtered (paper Discussion item 1).
VIDAR_DEVICE = DeviceKey("DX", "Vidar", "FilmScanner", 2048, 2048)


class DeviceRegistry:
    """Resolves scrub geometry and whitelist membership for device variants."""

    def __init__(self) -> None:
        self.us_whitelist = build_ultrasound_whitelist()
        self._us_index: Dict[str, DeviceKey] = {}
        for make, variants in self.us_whitelist.items():
            for v in variants:
                self._us_index[v.id()] = v
        self._fixed: Dict[str, DeviceKey] = {d.id(): d for d in FIXED_DEVICES}
        # unknown (manufacturer, model) lookups: counted and surfaced as a
        # worker/fleet metric — an unknown device is a PHI-coverage gap the
        # detector must absorb, never a silent pass-through
        self.unknown_lookups: Dict[Tuple[str, str], int] = {}

    # -- membership ----------------------------------------------------------
    def known(self, key: DeviceKey) -> bool:
        """Is this (modality, make, model, resolution) variant registered?"""
        return key.id() in self._fixed or key.id() in self._us_index

    def note_unknown(self, key: DeviceKey) -> None:
        """Record an unknown-device lookup (scrub-script miss)."""
        mk = (key.make, key.model)
        self.unknown_lookups[mk] = self.unknown_lookups.get(mk, 0) + 1

    def unknown_lookup_total(self) -> int:
        return sum(self.unknown_lookups.values())

    # -- scrub geometry ------------------------------------------------------
    def scrub_rects(self, key: DeviceKey) -> List[Rect]:
        """Regions this device burns PHI into (and rules must blank)."""
        if key.modality == "US":
            return _synth_rects(key, 3)  # US: heaviest burn-in (paper Discussion)
        if key.modality in ("PT", "CT") and key.make == "GE" and key.model == "Discovery":
            # paper Fig 2b literal regions for the GE PET/CT fusion
            return [(256, 0, 256, 22), (300, 22, 212, 80), (10, 478, 100, 10)]
        if key.modality in ("DX", "CR"):
            return _synth_rects(key, 2)
        if key.modality in ("CT", "MR", "PT"):
            return _synth_rects(key, 1)  # occasional dose/info banner
        return []

    # -- whitelist -----------------------------------------------------------
    def us_whitelisted(self, key: DeviceKey) -> bool:
        return key.id() in self._us_index

    def all_us_variants(self) -> List[DeviceKey]:
        return list(self._us_index.values())

    def table2_stats(self) -> Dict[str, Tuple[int, int]]:
        """(models, resolution variations) per make — reproduces paper Table 2."""
        out = {}
        for make, variants in self.us_whitelist.items():
            out[make] = (len({v.model for v in variants}), len(variants))
        return out


_REGISTRY: DeviceRegistry | None = None


def registry() -> DeviceRegistry:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = DeviceRegistry()
    return _REGISTRY
