"""Deterministic synthetic DICOM study generator.

Stands in for the clinical PACS feed (no real PHI exists in this environment).
Reproduces the *statistical shape* of the paper's archive (Figure 1): study
mix dominated by diagnostic x-ray, image counts dominated by CT/MR (a CT study
has hundreds-to-thousands of slices); and the *adversarial content* the
pipeline must handle: burned-in PHI text at device-specific regions, PDFs, SR
documents, secondary captures, Vidar film scans, etc. (paper Discussion list).

Everything is seeded: the same (seed, accession) always yields bit-identical
studies, which the regression suite and exactly-once tests rely on.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.dicom.dataset import DicomDataset, new_uid
from repro.dicom.devices import DeviceKey, FIXED_DEVICES, VIDAR_DEVICE, Rect, registry

# Figure 1 (paper): studies dominated by x-ray; images dominated by CT/MR.
MODALITY_STUDY_MIX = {"DX": 0.40, "CR": 0.12, "CT": 0.20, "MR": 0.13, "US": 0.10, "PT": 0.05}
IMAGES_PER_STUDY = {"CT": (80, 600), "MR": (60, 400), "PT": (100, 400), "US": (4, 40), "DX": (1, 4), "CR": (1, 3)}
_PIXEL_DTYPE = {"CT": np.uint16, "MR": np.uint16, "PT": np.uint16, "US": np.uint8, "DX": np.uint16, "CR": np.uint16}
_MAXVAL = {np.uint16: 4095, np.uint8: 255}

PROBLEM_KINDS = [
    "pdf", "sr", "presentation_state", "raw_modality", "secondary_capture",
    "burned_in_yes", "conversion_type_empty", "derived", "vidar", "video",
]

_FIRST = ["JANE", "JOHN", "MARIA", "WEI", "PRIYA", "OMAR", "SOFIA", "LIAM"]
_LAST = ["DOE", "SMITH", "GARCIA", "CHEN", "PATEL", "HASSAN", "ROSSI", "KIM"]

# BodyPartExamined mix per modality — gives the metadata catalog a realistic
# anatomical dimension to select cohorts on (no PHI content).
_BODY_PARTS = {
    "CT": ["CHEST", "ABDOMEN", "HEAD", "PELVIS"],
    "MR": ["BRAIN", "SPINE", "KNEE"],
    "PT": ["WHOLEBODY", "CHEST"],
    "US": ["ABDOMEN", "HEART", "THYROID"],
    "DX": ["CHEST", "HAND", "FOOT", "SPINE"],
    "CR": ["CHEST", "ANKLE"],
}


@dataclass
class SyntheticStudy:
    accession: str
    mrn: str
    patient_name: str
    study_uid: str
    study_date: str
    modality: str
    device: DeviceKey
    body_part: str = ""
    datasets: List[DicomDataset] = field(default_factory=list)
    # ground truth for tests: regions that contain burned-in PHI, per instance
    phi_rects: Dict[str, List[Rect]] = field(default_factory=dict)

    def nbytes(self) -> int:
        return sum(d.nbytes() for d in self.datasets)


class StudyGenerator:
    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self.registry = registry()

    # ---------------------------------------------------------------- internals
    def _rng(self, *key: object) -> np.random.Generator:
        h = hashlib.sha256(("|".join(map(str, (self.seed,) + key))).encode()).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "big"))

    def _pick_device(self, modality: str, rng: np.random.Generator) -> DeviceKey:
        if modality == "US":
            variants = self.registry.all_us_variants()
            return variants[int(rng.integers(len(variants)))]
        cands = [d for d in FIXED_DEVICES if d.modality == modality]
        return cands[int(rng.integers(len(cands)))]

    # resolutions novel (manufacturer, model) variants show up with — modest
    # sizes (sim corpora carry many of these), deliberately not tile-aligned
    # so the detector's padding path is exercised end to end
    _UNKNOWN_RES = {
        "CT": (320, 512), "MR": (288, 320), "PT": (320, 512),
        "DX": (520, 648), "CR": (520, 648),
    }

    def unknown_device(self, salt: str, modality: Optional[str] = None) -> DeviceKey:
        """A device variant *outside* the registry (novel manufacturer/model).

        The registry still synthesizes burn-in geometry for it (``scrub_rects``
        is hash-derived for any key), so :meth:`gen_study` burns PHI text into
        deterministic regions — but the scrub script has no rule for the
        variant, which is exactly the coverage gap the detector subsystem
        exists to close. US is excluded: unknown ultrasound is whitelist-
        rejected upstream, never detector-scrubbed (paper Table 2).
        """
        rng = self._rng("unknown-device", salt)
        if modality is None or modality == "US":
            mods = sorted(self._UNKNOWN_RES)
            modality = mods[int(rng.integers(len(mods)))]
        rows, cols = self._UNKNOWN_RES[modality]
        key = DeviceKey(
            modality,
            f"Novel{int(rng.integers(100)):02d}",
            f"NX-{int(rng.integers(1000)):03d}",
            rows,
            cols,
        )
        assert not self.registry.known(key), key
        return key

    def _background(self, rng: np.random.Generator, rows: int, cols: int, dtype) -> np.ndarray:
        """Cheap anatomy-ish background: radial falloff + low-freq noise."""
        maxv = _MAXVAL[dtype]
        y = np.linspace(-1, 1, rows, dtype=np.float32)[:, None]
        x = np.linspace(-1, 1, cols, dtype=np.float32)[None, :]
        body = np.clip(1.0 - (x * x + y * y), 0, 1)
        noise = rng.random((-(-rows // 16), -(-cols // 16)), dtype=np.float32)
        noise = np.kron(noise, np.ones((16, 16), np.float32))[:rows, :cols]
        img = (0.55 * body + 0.25 * noise) * maxv * 0.6
        return img.astype(dtype)

    def _burn_text(self, img: np.ndarray, rect: Rect, rng: np.random.Generator) -> None:
        """Burn a synthetic text banner: high-contrast glyph-like strokes."""
        x, y, w, h = rect
        H, W = img.shape[:2]
        x2, y2 = min(x + w, W), min(y + h, H)
        if x >= x2 or y >= y2:
            return
        maxv = _MAXVAL[img.dtype.type]
        region = img[y:y2, x:x2]
        # vertical stroke pattern with glyph-ish gaps: strong horiz gradients
        strokes = (np.arange(region.shape[1]) // 3) % 2 == 0
        mask = np.broadcast_to(strokes, region.shape).copy()
        mask &= rng.random(region.shape) < 0.85
        region[mask] = maxv
        region[~mask] = (region[~mask] * 0.1).astype(img.dtype)

    # ---------------------------------------------------------------- instances
    def _make_instance(
        self,
        study: SyntheticStudy,
        series_uid: str,
        idx: int,
        device: DeviceKey,
        burn_rects: List[Rect],
        rng: np.random.Generator,
    ) -> DicomDataset:
        dtype = _PIXEL_DTYPE[device.modality]
        ds = DicomDataset()
        ds["SOPClassUID"] = f"1.2.840.10008.5.1.4.1.1.{ {'CT':'2','MR':'4','US':'6.1','PT':'128','DX':'1.1','CR':'1'}[device.modality] }"
        ds["SOPInstanceUID"] = new_uid(f"{study.accession}/{series_uid}/{idx}")
        ds["StudyInstanceUID"] = study.study_uid
        ds["SeriesInstanceUID"] = series_uid
        ds["StudyID"] = study.accession
        ds["SeriesNumber"] = 1
        ds["InstanceNumber"] = idx + 1
        ds["AccessionNumber"] = study.accession
        ds["PatientName"] = study.patient_name
        ds["PatientID"] = study.mrn
        ds["PatientBirthDate"] = "19600101"
        ds["PatientSex"] = "O"
        ds["PatientAge"] = "064Y"
        ds["ReferringPhysicianName"] = "REF^DOCTOR"
        ds["OperatorsName"] = "TECH^ONE"
        ds["InstitutionName"] = "STANFORD HOSPITAL"
        ds["InstitutionAddress"] = "300 Pasteur Dr, Palo Alto CA"
        ds["StudyDate"] = study.study_date
        ds["SeriesDate"] = study.study_date
        ds["AcquisitionDate"] = study.study_date
        ds["ContentDate"] = study.study_date
        ds["StudyTime"] = "081500"
        ds["SeriesTime"] = "081730"
        ds["Modality"] = device.modality
        ds["Manufacturer"] = device.make
        ds["ManufacturerModelName"] = device.model
        if study.body_part:
            ds["BodyPartExamined"] = study.body_part
        ds["DeviceSerialNumber"] = f"SN{int(rng.integers(1e6)):06d}"
        ds["StationName"] = f"STA{int(rng.integers(100)):02d}"
        ds["Rows"] = device.rows
        ds["Columns"] = device.cols
        ds["BitsAllocated"] = 16 if dtype == np.uint16 else 8
        # stored sample depth: 12-bit data in 16-bit words, full range for u8
        ds["BitsStored"] = 12 if dtype == np.uint16 else 8
        ds["SamplesPerPixel"] = 1
        ds["BurnedInAnnotation"] = "NO"
        ds["ImageType"] = "ORIGINAL\\PRIMARY\\AXIAL"
        ds["SeriesDescription"] = f"{device.modality} series"
        ds["StudyDescription"] = f"{device.modality} study for MRN {study.mrn}"  # PHI leak vector
        ds["PatientComments"] = f"Patient {study.patient_name} seen by Dr. House"  # PHI leak vector
        ds.private["(0009,0010)"] = "VENDOR PRIVATE CREATOR"
        ds.private["(0009,1001)"] = f"internal-id-{study.mrn}"

        img = self._background(rng, device.rows, device.cols, dtype)
        for rect in burn_rects:
            self._burn_text(img, rect, rng)
        ds.pixels = img
        if burn_rects:
            study.phi_rects[ds["SOPInstanceUID"]] = list(burn_rects)
        return ds

    # ---------------------------------------------------------------- studies
    def gen_study(
        self,
        accession: str,
        modality: Optional[str] = None,
        n_images: Optional[int] = None,
        device: Optional[DeviceKey] = None,
        problem: Optional[str] = None,
    ) -> SyntheticStudy:
        """Generate one study. ``problem`` injects a paper-Discussion pathology."""
        rng = self._rng("study", accession)
        if modality is None:
            mods, probs = zip(*MODALITY_STUDY_MIX.items())
            modality = str(rng.choice(mods, p=np.array(probs) / sum(probs)))
        if device is None:
            device = VIDAR_DEVICE if problem == "vidar" else self._pick_device(modality, rng)
        modality = device.modality
        if n_images is None:
            lo, hi = IMAGES_PER_STUDY[modality]
            n_images = int(rng.integers(lo, hi + 1))

        mrn = f"{int(rng.integers(1e7)):08d}"
        name = f"{_LAST[int(rng.integers(len(_LAST)))]}^{_FIRST[int(rng.integers(len(_FIRST)))]}"
        y, m, d = 2015 + int(rng.integers(5)), 1 + int(rng.integers(12)), 1 + int(rng.integers(28))
        parts = _BODY_PARTS.get(modality, ["CHEST"])
        study = SyntheticStudy(
            accession=accession,
            mrn=mrn,
            patient_name=name,
            study_uid=new_uid(f"study/{accession}"),
            study_date=f"{y:04d}{m:02d}{d:02d}",
            modality=modality,
            device=device,
            body_part=parts[int(rng.integers(len(parts)))],
        )
        series_uid = new_uid(f"series/{accession}/1")
        burn_rects = self.registry.scrub_rects(device)
        # CT/MR: only a subset of slices carry the burned-in banner (dose screens)
        for i in range(n_images):
            inst_rng = self._rng("inst", accession, i)
            if modality in ("CT", "MR", "PT"):
                rects = burn_rects if (i % 17 == 0) else []
            else:
                rects = burn_rects
            study.datasets.append(self._make_instance(study, series_uid, i, device, rects, inst_rng))

        if problem:
            study.datasets.append(self._make_problem_instance(study, series_uid, problem, rng))
        return study

    def _make_problem_instance(
        self, study: SyntheticStudy, series_uid: str, kind: str, rng: np.random.Generator
    ) -> DicomDataset:
        """Instances the filter stage must reject (paper Discussion items 1-3)."""
        assert kind in PROBLEM_KINDS, kind
        ds = self._make_instance(study, series_uid, 9999, study.device, [], rng)
        if kind == "pdf":
            ds["SOPClassUID"] = "1.2.840.10008.5.1.4.1.1.104.1"  # Encapsulated PDF
            ds.encapsulated = b"%PDF-1.4 synthetic report for " + study.patient_name.encode()
            ds.pixels = None
        elif kind == "sr":
            ds["SOPClassUID"] = "1.2.840.10008.5.1.4.1.1.88.11"  # Basic Text SR
            ds["Modality"] = "SR"
            ds.pixels = None
        elif kind == "presentation_state":
            ds["SOPClassUID"] = "1.2.840.10008.5.1.4.1.1.11.1"  # GSPS
            ds["Modality"] = "PR"
            ds.pixels = None
        elif kind == "raw_modality":
            ds["Modality"] = "RAW"
        elif kind == "secondary_capture":
            ds["SOPClassUID"] = "1.2.840.10008.5.1.4.1.1.7"  # Secondary Capture
            ds["ImageType"] = "DERIVED\\SECONDARY"
        elif kind == "burned_in_yes":
            ds["BurnedInAnnotation"] = "YES"
        elif kind == "conversion_type_empty":
            ds["ConversionType"] = ""
        elif kind == "derived":
            ds["ImageType"] = "DERIVED\\PRIMARY\\REFORMATTED"
        elif kind == "vidar":
            ds["Manufacturer"] = "Vidar"
            ds["ConversionType"] = "DF"  # digitized film
        elif kind == "video":
            ds["SOPClassUID"] = "1.2.840.10008.5.1.4.1.1.77.1.4.1"  # Video Photographic
            ds["ConversionType"] = "SI"
        return ds

    # ---------------------------------------------------------------- batches
    def gen_request(self, accessions: List[str], modality: Optional[str] = None, **kw) -> List[SyntheticStudy]:
        return [self.gen_study(a, modality=modality, **kw) for a in accessions]
