"""Minimal DICOM data-dictionary: the tags the de-identification engine touches.

This is intentionally a *registry*, not a full PS3.6 dictionary: the paper's
pipeline only needs the identification-relevant subset plus the structural
attributes used by filter rules. Tags are addressed by keyword throughout the
codebase; ``(group, element)`` and VR are kept for fidelity (hex round-trips in
manifests, group-based rules like "remove all private groups").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class TagInfo:
    group: int
    element: int
    vr: str  # DICOM value representation, e.g. PN, LO, DA, UI, US, CS
    keyword: str

    @property
    def tag(self) -> Tuple[int, int]:
        return (self.group, self.element)

    def hex(self) -> str:
        return f"({self.group:04X},{self.element:04X})"


def _t(group: int, element: int, vr: str, keyword: str) -> TagInfo:
    return TagInfo(group, element, vr, keyword)


# --- Identity / demographics (HIPAA identifiers) -------------------------------
_ALL = [
    _t(0x0008, 0x0050, "SH", "AccessionNumber"),
    _t(0x0010, 0x0010, "PN", "PatientName"),
    _t(0x0010, 0x0020, "LO", "PatientID"),  # MRN
    _t(0x0010, 0x0030, "DA", "PatientBirthDate"),
    _t(0x0010, 0x0032, "TM", "PatientBirthTime"),
    _t(0x0010, 0x0040, "CS", "PatientSex"),
    _t(0x0010, 0x1000, "LO", "OtherPatientIDs"),
    _t(0x0010, 0x1001, "PN", "OtherPatientNames"),
    _t(0x0010, 0x1010, "AS", "PatientAge"),
    _t(0x0010, 0x1040, "LO", "PatientAddress"),
    _t(0x0010, 0x2154, "SH", "PatientTelephoneNumbers"),
    _t(0x0010, 0x21B0, "LT", "AdditionalPatientHistory"),
    _t(0x0008, 0x0090, "PN", "ReferringPhysicianName"),
    _t(0x0008, 0x1048, "PN", "PhysiciansOfRecord"),
    _t(0x0008, 0x1050, "PN", "PerformingPhysicianName"),
    _t(0x0008, 0x1070, "PN", "OperatorsName"),
    _t(0x0008, 0x0080, "LO", "InstitutionName"),
    _t(0x0008, 0x0081, "ST", "InstitutionAddress"),
    _t(0x0008, 0x1040, "LO", "InstitutionalDepartmentName"),
    # --- Dates / times (longitudinal temporal info, jittered not removed) -----
    _t(0x0008, 0x0020, "DA", "StudyDate"),
    _t(0x0008, 0x0021, "DA", "SeriesDate"),
    _t(0x0008, 0x0022, "DA", "AcquisitionDate"),
    _t(0x0008, 0x0023, "DA", "ContentDate"),
    _t(0x0008, 0x0030, "TM", "StudyTime"),
    _t(0x0008, 0x0031, "TM", "SeriesTime"),
    _t(0x0008, 0x0032, "TM", "AcquisitionTime"),
    _t(0x0008, 0x0033, "TM", "ContentTime"),
    # --- Structure / UIDs -------------------------------------------------------
    _t(0x0008, 0x0016, "UI", "SOPClassUID"),
    _t(0x0008, 0x0018, "UI", "SOPInstanceUID"),
    _t(0x0020, 0x000D, "UI", "StudyInstanceUID"),
    _t(0x0020, 0x000E, "UI", "SeriesInstanceUID"),
    _t(0x0020, 0x0010, "SH", "StudyID"),
    _t(0x0020, 0x0011, "IS", "SeriesNumber"),
    _t(0x0020, 0x0013, "IS", "InstanceNumber"),
    # --- Equipment (filter/scrub rule keys) ------------------------------------
    _t(0x0008, 0x0060, "CS", "Modality"),
    _t(0x0008, 0x0070, "LO", "Manufacturer"),
    _t(0x0008, 0x1090, "LO", "ManufacturerModelName"),
    _t(0x0018, 0x1000, "LO", "DeviceSerialNumber"),
    _t(0x0018, 0x1020, "LO", "SoftwareVersions"),
    _t(0x0008, 0x1010, "SH", "StationName"),
    # --- Image structure --------------------------------------------------------
    _t(0x0028, 0x0010, "US", "Rows"),
    _t(0x0028, 0x0011, "US", "Columns"),
    _t(0x0028, 0x0100, "US", "BitsAllocated"),
    _t(0x0028, 0x0101, "US", "BitsStored"),
    _t(0x0028, 0x0002, "US", "SamplesPerPixel"),
    _t(0x0028, 0x0301, "CS", "BurnedInAnnotation"),
    _t(0x0008, 0x0008, "CS", "ImageType"),
    _t(0x0008, 0x0064, "CS", "ConversionType"),
    _t(0x0008, 0x103E, "LO", "SeriesDescription"),
    _t(0x0008, 0x1030, "LO", "StudyDescription"),
    _t(0x0018, 0x0015, "CS", "BodyPartExamined"),
    _t(0x0002, 0x0010, "UI", "TransferSyntaxUID"),
    _t(0x7FE0, 0x0010, "OW", "PixelData"),
    # --- Misc free text (PHI leak vectors) --------------------------------------
    _t(0x0008, 0x4000, "LT", "IdentifyingComments"),
    _t(0x0010, 0x4000, "LT", "PatientComments"),
    _t(0x0020, 0x4000, "LT", "ImageComments"),
    _t(0x0032, 0x1060, "LO", "RequestedProcedureDescription"),
    _t(0x0040, 0x0254, "LO", "PerformedProcedureStepDescription"),
]

TAGS: Dict[str, TagInfo] = {t.keyword: t for t in _ALL}
_BY_TAG: Dict[Tuple[int, int], TagInfo] = {t.tag: t for t in _ALL}

# Tag groups used by rule scripts.
UID_KEYWORDS = [k for k, t in TAGS.items() if t.vr == "UI" and k != "TransferSyntaxUID"]
DATE_KEYWORDS = [k for k, t in TAGS.items() if t.vr == "DA"]
TIME_KEYWORDS = [k for k, t in TAGS.items() if t.vr == "TM"]
PERSON_KEYWORDS = [k for k, t in TAGS.items() if t.vr == "PN"]
FREETEXT_KEYWORDS = [k for k, t in TAGS.items() if t.vr in ("LT", "ST")]


def keyword_for(tag: Tuple[int, int]) -> Optional[str]:
    info = _BY_TAG.get(tag)
    return info.keyword if info else None


def is_private(tag: Tuple[int, int]) -> bool:
    """Private DICOM tags have odd group numbers."""
    return tag[0] % 2 == 1
