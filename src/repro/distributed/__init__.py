# Data-plane distribution: shard_map scrub farm over device meshes, elastic
# pool resizing driven by the autoscaler, and gradient compression for the
# training plane.
from repro.distributed.scrub_farm import ScrubFarm, bucket_by_resolution
from repro.distributed.elastic import ElasticFarmController
from repro.distributed.compression import (
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
    CompressionState,
)

__all__ = [
    "ScrubFarm",
    "bucket_by_resolution",
    "ElasticFarmController",
    "int8_compress",
    "int8_decompress",
    "topk_compress",
    "topk_decompress",
    "CompressionState",
]
