"""Gradient compression for cross-pod data parallelism (DESIGN.md §5).

At 1000+ nodes the cross-pod gradient all-reduce rides the slow DCI links, so
the trainer offers two standard compressors, both with **error feedback** so
compression noise is fed back into the next step instead of lost (Seide et
al. / Karimireddy et al. — convergence-safe at these rates):

* ``int8``  — per-tensor symmetric quantization: 4x fewer bytes on the wire;
* ``topk``  — magnitude sparsification to k fraction: ~(1/k)x fewer bytes.

Both are pure-jnp (jit/pjit-safe) and compose with any optimizer. The wire
format is (payload, scale/indices) pairs; the roofline benefit shows up in
the collective term of EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    """Error-feedback residual, one per compressed tensor."""

    residual: jax.Array

    @staticmethod
    def init(shape, dtype=jnp.float32) -> "CompressionState":
        return CompressionState(jnp.zeros(shape, dtype))


# ------------------------------------------------------------------- int8
def int8_compress(
    grad: jax.Array, state: CompressionState
) -> Tuple[jax.Array, jax.Array, CompressionState]:
    """-> (int8 payload, f32 scale, new state). Wire bytes: n + 4."""
    g = grad + state.residual
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, CompressionState(g - deq)


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------------- top-k
def topk_compress(
    grad: jax.Array, state: CompressionState, k_frac: float = 0.01
) -> Tuple[jax.Array, jax.Array, CompressionState]:
    """-> (values, flat indices, new state). Wire bytes: k*(4+4)."""
    g = grad + state.residual
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    kept = jnp.zeros_like(flat).at[idx].set(sel).reshape(g.shape)
    return sel, idx, CompressionState(g - kept)


def topk_decompress(vals: jax.Array, idx: jax.Array, shape, size: int) -> jax.Array:
    return jnp.zeros((size,), vals.dtype).at[idx].set(vals).reshape(shape)


# ------------------------------------------------- all-reduce composition
def compressed_psum_int8(grad: jax.Array, state: CompressionState, axis_name: str):
    """int8-compress locally, all-reduce the dequantized payload, return mean.

    Note the collective itself still moves f32 under XLA on CPU; on TPU the
    int8 payload crosses the wire and the scale rides sideband — the 4x
    collective-bytes saving is what EXPERIMENTS.md §Perf models.
    """
    q, scale, new_state = int8_compress(grad, state)
    deq = int8_decompress(q, scale)
    return jax.lax.pmean(deq, axis_name), new_state
