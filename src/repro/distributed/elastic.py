"""Elastic farm controller: autoscaler targets -> device-mesh rebuilds.

The paper's pool adds/deletes VM instances with queue depth. A TPU farm
cannot conjure chips, but it can (a) resize the *active* sub-mesh it
dispatches to, releasing slices back to the scheduler, and (b) survive device
loss by re-meshing around failed hardware. Both are modeled here against the
host device pool; the same controller drives real slices in production.

Failure model: ``mark_failed(device_index)`` removes a device from the pool
(as a health-check would), triggering a rebuild at the next reconcile. The
in-flight batch on a failed device is lost — which is safe end to end,
because the queue lease for that work expires and redelivers (tested in
tests/test_distributed.py::test_device_failure_recovery).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax

from repro.distributed.scrub_farm import ScrubFarm
from repro.utils.logging import get_logger

log = get_logger("distributed.elastic")


@dataclass
class MeshEvent:
    t: float
    kind: str  # "resize" | "device-failure"
    size: int
    detail: str = ""


class ElasticFarmController:
    def __init__(self, devices: Optional[List[jax.Device]] = None, clock=None) -> None:
        self.pool: List[jax.Device] = list(devices) if devices is not None else list(jax.devices())
        self.healthy: List[bool] = [True] * len(self.pool)
        self.clock = clock
        self.events: List[MeshEvent] = []
        self.active = 0
        self.farm: Optional[ScrubFarm] = None
        self.rebuilds = 0

    def _now(self) -> float:
        return self.clock.now() if self.clock else 0.0

    def healthy_devices(self) -> List[jax.Device]:
        return [d for d, ok in zip(self.pool, self.healthy) if ok]

    def mark_failed(self, device_index: int) -> None:
        if self.healthy[device_index]:
            self.healthy[device_index] = False
            self.events.append(MeshEvent(self._now(), "device-failure", device_index))
            if self.farm is not None and self.active > len(self.healthy_devices()):
                # the active mesh includes the dead device: force re-mesh
                self.reconcile(self.active)

    def reconcile(self, target_workers: int) -> ScrubFarm:
        """Resize the active mesh to min(target, healthy). Returns the farm."""
        avail = self.healthy_devices()
        if not avail:
            # total pool loss: keep the last farm handle and surface an alert —
            # in production this pages the operator; work stays queued (leases
            # simply expire and redeliver when capacity returns)
            self.events.append(MeshEvent(self._now(), "alert", 0, "no healthy devices"))
            if self.farm is None:
                self.farm = ScrubFarm(self.pool[:1])
            return self.farm
        size = max(1, min(target_workers, len(avail)))
        if self.farm is None or size != self.active or any(
            d not in avail for d in self.farm.mesh.devices.flat
        ):
            self.farm = ScrubFarm(avail[:size])
            self.active = size
            self.rebuilds += 1
            self.events.append(MeshEvent(self._now(), "resize", size))
            log.debug("re-meshed farm to %d workers", size)
        return self.farm
