"""Distributed scrub farm: the paper's autoscaled worker pool as a device mesh.

The paper parallelizes de-identification across cloud VMs pulling from a
queue. On TPU the equivalent data plane is a 1-D device mesh with the image
batch sharded across the ``workers`` axis via ``jax.shard_map``; each device
runs the Pallas scrub kernel on its local shard. There is **no** cross-device
communication in the hot path — scrubbing is embarrassingly parallel, which
is exactly why the paper's design scales and why the farm's roofline is pure
HBM bandwidth (DESIGN.md §3).

Host-side responsibilities (this module):
  * resolution bucketing — studies mix 512x512 CT with 2500x2048 DX; batches
    must be shape-uniform per dispatch (the paper's per-resolution rules have
    the same effect);
  * batch padding to a multiple of the mesh size, cropped after;
  * writing scrubbed pixels back into the DICOM datasets.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 top-level API
    _shard_map = jax.shard_map
    _REPLICATION_KW = "check_vma"
except AttributeError:  # older jax: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _REPLICATION_KW = "check_rep"

from repro.dicom.dataset import DicomDataset
from repro.dicom.devices import Rect
from repro.kernels.scrub.ops import pack_rects, scrub_images


def bucket_by_resolution(
    datasets: Sequence[DicomDataset],
) -> Dict[Tuple[int, int], List[int]]:
    """Group dataset indices by pixel resolution (H, W)."""
    buckets: Dict[Tuple[int, int], List[int]] = defaultdict(list)
    for i, ds in enumerate(datasets):
        if ds.pixels is not None:
            buckets[ds.pixels.shape[:2]].append(i)
    return dict(buckets)


class ScrubFarm:
    """shard_map-wrapped batched scrubbing over a 1-D ``workers`` mesh."""

    def __init__(self, devices: Sequence[jax.Device] | None = None) -> None:
        devices = list(devices) if devices is not None else jax.devices()
        self.mesh = Mesh(np.array(devices), axis_names=("workers",))
        self.n = len(devices)
        self._fns: dict = {}

    # ------------------------------------------------------------- core op
    def _sharded_fn(self, dtype, rect_count: int):
        key = (jnp.dtype(dtype).name, rect_count)
        if key not in self._fns:

            def local(images, rects):
                # per-device shard: batch slice, full images; kernel does tiles
                return scrub_images(images, rects)

            fn = _shard_map(
                local,
                mesh=self.mesh,
                in_specs=(P("workers"), P("workers")),
                out_specs=P("workers"),
                # pallas_call's out_shape carries no varying-mesh-axes info;
                # the farm is embarrassingly parallel so nothing to check
                **{_REPLICATION_KW: False},
            )
            self._fns[key] = jax.jit(fn)
        return self._fns[key]

    def scrub_batch(self, images: np.ndarray, rect_lists: Sequence[Sequence[Rect]]) -> np.ndarray:
        """images: (N, H, W); rect_lists: ragged per-image rects. Shards the
        batch over the mesh, scrubs, returns (N, H, W)."""
        N = images.shape[0]
        rects = pack_rects(rect_lists, R=max(4, max((len(r) for r in rect_lists), default=1)))
        pad = (-N) % self.n
        if pad:
            images = np.concatenate([images, np.zeros((pad,) + images.shape[1:], images.dtype)])
            rects = np.concatenate([rects, np.zeros((pad,) + rects.shape[1:], rects.dtype)])
        sharding = NamedSharding(self.mesh, P("workers"))
        imgs_dev = jax.device_put(jnp.asarray(images), sharding)
        rects_dev = jax.device_put(jnp.asarray(rects), sharding)
        out = self._sharded_fn(images.dtype, rects.shape[1])(imgs_dev, rects_dev)
        return np.asarray(out)[:N]

    # ------------------------------------------------------- dataset plane
    def process_datasets(
        self,
        datasets: Sequence[DicomDataset],
        rects_for,
    ) -> Dict[int, List[Rect]]:
        """Scrub a heterogeneous batch of datasets in resolution buckets.

        ``rects_for(ds) -> Optional[tuple[Rect, ...]]`` is typically
        ``ScrubStage.rects_for``. Pixels are modified in place; returns
        {dataset index: applied rects} for manifest recording.
        """
        applied: Dict[int, List[Rect]] = {}
        buckets = bucket_by_resolution(datasets)
        for (H, W), idxs in buckets.items():
            todo: List[int] = []
            rl: List[List[Rect]] = []
            for i in idxs:
                rects = rects_for(datasets[i])
                if rects:
                    todo.append(i)
                    rl.append(list(rects))
                    applied[i] = list(rects)
            if not todo:
                continue
            stack = np.stack([datasets[i].pixels for i in todo])
            out = self.scrub_batch(stack, rl)
            for j, i in enumerate(todo):
                datasets[i].pixels = out[j]
        return applied
