# Continuous change-feed ingest (DESIGN.md §10): simulated PACS change
# sequence, durable crash-replayable checkpoint, at-least-once pooler handoff
# with effect-idempotent apply, backoff + circuit breaker for feed outages.
from repro.ingest.checkpoint import Checkpoint
from repro.ingest.feed import (
    ChangeEvent,
    FeedMutation,
    FeedOutage,
    PacsFeed,
    seeded_mutations,
)
from repro.ingest.pooler import (
    AppliedOp,
    ApplierStats,
    ChangePooler,
    IngestApplier,
    PoolerCrash,
    PoolerStats,
)

__all__ = [
    "AppliedOp",
    "ApplierStats",
    "ChangeEvent",
    "ChangePooler",
    "Checkpoint",
    "FeedMutation",
    "FeedOutage",
    "IngestApplier",
    "PacsFeed",
    "PoolerCrash",
    "PoolerStats",
    "seeded_mutations",
]
