"""Durable, crash-replayable pooler checkpoint (DESIGN.md §10).

Journal-style append log (JSONL, fsync per record) shared by the two halves
of the ingest process:

* ``seen`` records — the :class:`~repro.ingest.pooler.ChangePooler` appends
  one *after* publishing a feed event into the broker. The resume cursor
  (:meth:`Checkpoint.floor`) is the largest contiguous seen seq, so a crash
  between publish and ``seen`` re-polls and re-publishes the event — that is
  the at-least-once half of the contract.
* ``op`` records — the :class:`~repro.ingest.pooler.IngestApplier` appends
  one *before* acking a delivery, with the terminal outcome (``applied`` /
  ``dup`` / ``stale``). Redelivery of an already-outcome'd seq is acked
  without effect — that is the effect-idempotent half.

Replay tolerates a torn tail write (crash mid-append): every fully-written
record is recovered and the partial fragment is truncated away, same contract
as ``repro.queueing.journal``.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Dict, List, Set

from repro.utils.wal import append_jsonl, replay_jsonl


class Checkpoint:
    def __init__(self, path: str | os.PathLike) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.seen: Set[int] = set()
        self.outcomes: Dict[int, dict] = {}          # seq -> op record
        self.outcome_log: List[dict] = []            # op records, append order
        self.applied_etag: Dict[str, str] = {}       # accession -> last applied etag
        self.applied_seq: Dict[str, int] = {}        # accession -> max applied seq
        self.double_applied: List[int] = []          # seqs with >1 op record
        self.torn_tail = 0
        self.corrupt_lines = 0  # malformed non-final lines skipped at replay
        self._floor = 0
        if self.path.exists():
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    # -------------------------------------------------------------- replay
    def _absorb(self, rec: dict) -> None:
        kind = rec.get("kind")
        if kind == "seen" and "seq" in rec:
            self.seen.add(int(rec["seq"]))
        elif kind == "op" and "seq" in rec:
            seq = int(rec["seq"])
            if seq in self.outcomes:
                # must never happen live (the applier checks before writing);
                # recorded so the monotonicity checker can prove it didn't
                self.double_applied.append(seq)
            self.outcomes[seq] = rec
            self.outcome_log.append(rec)
            if rec.get("outcome") == "applied":
                acc = rec.get("accession", "")
                if rec.get("op") == "delete":
                    self.applied_etag.pop(acc, None)
                else:
                    self.applied_etag[acc] = rec.get("etag", "")
                self.applied_seq[acc] = max(self.applied_seq.get(acc, 0), seq)

    def _replay(self) -> None:
        # Torn-tail repair + corrupt-line tolerance via the shared WAL helper.
        replay = replay_jsonl(self.path)
        self.torn_tail += replay.torn_tail
        self.corrupt_lines += replay.corrupt_lines
        for rec in replay.records:
            self._absorb(rec)
        self._refloor()

    def _refloor(self) -> None:
        while (self._floor + 1) in self.seen:
            self._floor += 1

    # ----------------------------------------------------------------- api
    def floor(self) -> int:
        """Largest N such that every seq in 1..N has been seen — the poll
        resume cursor. Seqs above the floor that were individually seen are
        deduped in memory, never lost."""
        return self._floor

    def _append(self, rec: dict) -> None:
        append_jsonl(self._fh, rec)

    def mark_seen(self, seq: int) -> None:
        if seq in self.seen:
            return
        self.seen.add(seq)
        self._refloor()
        self._append({"kind": "seen", "seq": seq})

    def mark_outcome(
        self,
        seq: int,
        accession: str,
        etag: str,
        op: str,
        outcome: str,
        rows: int = 0,
    ) -> None:
        """Record the terminal outcome for one feed seq. ``rows`` is the
        catalog delta this apply produced (the no-full-reingest counter)."""
        rec = {
            "kind": "op",
            "seq": seq,
            "accession": accession,
            "etag": etag,
            "op": op,
            "outcome": outcome,
            "rows": rows,
        }
        self._absorb(rec)
        self._append(rec)

    def has_outcome(self, seq: int) -> bool:
        return seq in self.outcomes

    def close(self) -> None:
        self._fh.close()
