"""Simulated clinical PACS change feed (ROADMAP: modeled on
``research-pacs-on-aws``'s change pooler source).

The PACS is the system of record: it commits create/update/delete mutations
to its own study inventory and appends one :class:`ChangeEvent` per commit to
a **monotonic change sequence**. Consumers poll the sequence with an
``after_seq`` cursor and fetch current study bytes separately — exactly the
DICOMweb changefeed shape, minus the network.

Delivery is deliberately imperfect, because that is what the pooler must be
robust to:

* ``outage`` — polls raise :class:`FeedOutage` (the pooler's backoff +
  circuit-breaker path);
* ``dup_rate`` — events may be delivered again in the same batch
  (at-least-once transport);
* ``shuffle`` — batch order is permuted (out-of-order delivery).

All delivery faults are drawn from :class:`repro.sim.events.HashRng` keyed by
(seed, poll counter, event seq), so a faulty feed is still a pure function of
its seed — the fleet simulator's bit-replayability contract extends through
the feed.
"""
from __future__ import annotations

import hashlib
import pickle
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.dicom.generator import StudyGenerator, SyntheticStudy

# NOTE: repro.sim.events.HashRng is imported lazily below — repro.sim's
# package __init__ pulls in the fleet harness, which imports this module
# (module-level import here would be a cycle).


class FeedOutage(RuntimeError):
    """The change feed is unreachable (network partition, PACS maintenance)."""


@dataclass(frozen=True)
class ChangeEvent:
    """One committed PACS mutation. ``etag`` is the PACS-side content digest
    of the committed version (empty for deletes) — the handoff dedup handle."""

    seq: int
    kind: str        # "create" | "update" | "delete"
    accession: str
    etag: str
    version: int


@dataclass(frozen=True)
class FeedMutation:
    """A scheduled PACS-side mutation (the feed's traffic model): data, not
    code, fixed before the run like every other simulator schedule."""

    t: float
    op: str          # "create" | "update" | "delete"
    accession: str


def seeded_mutations(
    seed: int,
    horizon: float,
    corpus: Sequence[str],
    n: int,
    *,
    create_fraction: float = 0.25,
    delete_fraction: float = 0.15,
) -> List[FeedMutation]:
    """Hash-seeded mutation schedule. Times are strictly increasing by
    construction (slot i lands in the i-th of n equal windows), so a delete is
    always scheduled after the create it targets. Deletes only target
    feed-created accessions — the initial corpus is referenced by traffic
    schedules built before the run, and deleting from under a scheduled cohort
    is a separate, explicitly-constructed experiment."""
    from repro.sim.events import HashRng

    rng = HashRng(seed, "feed-schedule")
    corpus = list(corpus)
    created: List[str] = []
    out: List[FeedMutation] = []
    for i in range(n):
        t = horizon * (i + rng.u("t", i)) / max(n, 1)
        u = rng.u("op", i)
        if u < create_fraction or not (corpus or created):
            acc = f"PACS{i:04d}"
            created.append(acc)
            out.append(FeedMutation(t, "create", acc))
        elif u < create_fraction + delete_fraction and created:
            acc = rng.choice(created, "del", i)
            created.remove(acc)
            out.append(FeedMutation(t, "delete", acc))
        else:
            pool = corpus + created
            out.append(FeedMutation(t, "update", rng.choice(pool, "upd", i)))
    return out


class PacsFeed:
    """The simulated PACS: committed study inventory + monotonic change log."""

    def __init__(
        self,
        seed: int,
        modality: Optional[str] = "CT",
        images_per_study: int = 3,
    ) -> None:
        self.seed = seed
        self.modality = modality
        self.images_per_study = images_per_study
        self._studies: Dict[str, SyntheticStudy] = {}
        self._etags: Dict[str, str] = {}
        self._versions: Dict[str, int] = {}
        self.events: List[ChangeEvent] = []
        self.last_seq = 0
        # delivery-fault knobs (chaos-tunable)
        self.outage = False
        self.dup_rate = 0.0
        self.shuffle = False
        self._polls = 0
        from repro.sim.events import HashRng

        self._rng = HashRng(seed, "pacs-feed")

    # ------------------------------------------------------------- commit side
    @staticmethod
    def _content_etag(study: SyntheticStudy) -> str:
        return hashlib.sha256(
            pickle.dumps(study, protocol=pickle.HIGHEST_PROTOCOL)
        ).hexdigest()

    def adopt(self, accession: str, study: SyntheticStudy) -> None:
        """Register an already-lake-resident study as version 0 without
        emitting a change event (the initial corpus predates the feed)."""
        self._studies[accession] = study
        self._etags[accession] = self._content_etag(study)
        self._versions[accession] = 0

    def commit(self, op: str, accession: str) -> Optional[ChangeEvent]:
        """Commit one mutation to the PACS and append its change event.
        Returns None for no-op commits (delete of an absent accession)."""
        if op == "delete":
            if accession not in self._studies:
                return None
            self._studies.pop(accession)
            self._etags.pop(accession)
            version = self._versions[accession]
            etag = ""
        elif op in ("create", "update"):
            version = self._versions.get(accession, 0) + 1
            # per-version generator seed: re-acquired bytes must differ from
            # every earlier version (new content => new etag)
            gen = StudyGenerator(self.seed + 7919 * version + 104729)
            study = gen.gen_study(
                accession, modality=self.modality, n_images=self.images_per_study
            )
            self._studies[accession] = study
            etag = self._content_etag(study)
            self._etags[accession] = etag
        else:
            raise ValueError(f"unknown feed op {op!r}")
        self._versions[accession] = version
        self.last_seq += 1
        ev = ChangeEvent(self.last_seq, op, accession, etag, version)
        self.events.append(ev)
        return ev

    # -------------------------------------------------------------- fetch side
    def fetch(self, accession: str) -> Optional[Tuple[SyntheticStudy, str]]:
        """Current committed (study, etag), or None when deleted/unknown."""
        study = self._studies.get(accession)
        if study is None:
            return None
        return study, self._etags[accession]

    def accessions(self) -> List[str]:
        return sorted(self._studies)

    # --------------------------------------------------------------- poll side
    def poll(self, after_seq: int, limit: int = 32) -> List[ChangeEvent]:
        """Events with ``seq > after_seq`` (at most ``limit`` distinct), with
        seeded duplicate/out-of-order delivery faults applied on top."""
        if self.outage:
            raise FeedOutage("change feed unreachable")
        self._polls += 1
        batch = [e for e in self.events if e.seq > after_seq][:limit]
        if self.dup_rate > 0.0:
            dups = [
                e for e in batch
                if self._rng.u("dup", self._polls, e.seq) < self.dup_rate
            ]
            batch = batch + dups
        if self.shuffle and len(batch) > 1:
            # permute by per-(poll, seq) draw; duplicates share a key, and
            # sorted() is stable, so the permutation is fully deterministic
            batch = sorted(
                batch, key=lambda e: self._rng.u("shuffle", self._polls, e.seq)
            )
        return batch
