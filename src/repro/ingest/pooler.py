"""ChangePooler + IngestApplier: continuous change-feed ingest (DESIGN.md §10).

The ingest process has two halves, modeled on ``research-pacs-on-aws``'s
``change_pooler``:

* :class:`ChangePooler` polls the PACS change sequence from the durable
  checkpoint's floor and hands each unseen event to the Broker —
  **at-least-once**: publish first, ``mark_seen`` second, so a crash between
  the two re-publishes and the applier dedups. Feed outages are absorbed by
  exponential backoff with seeded jitter; after ``breaker_threshold``
  consecutive failures the circuit breaker opens and polling stops entirely
  for ``breaker_cooldown`` seconds (no hammering a down PACS).
* :class:`IngestApplier` drains the broker and applies events to the imaging
  lake (:class:`~repro.storage.object_store.StudyStore`), which cascades the
  catalog delta (tombstone + append / remove). Every apply is
  **effect-idempotent**: dedup by ``(accession, etag)`` via the checkpoint,
  per-accession seq ordering fences out-of-order deliveries (an older event
  can never clobber newer bytes), and redeliveries of an already-outcome'd
  seq are acked without effect. Applies read the PACS's *current* bytes, so
  a burst of updates collapses into one apply plus effect-dedups.

Everything is driven by the shared SimClock and HashRng — a pooler crash,
restart, and catch-up replays bit-identically from one seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import INGEST_APPLY
from repro.ingest.checkpoint import Checkpoint
from repro.ingest.feed import ChangeEvent, FeedOutage, PacsFeed
from repro.obs.metrics import StatsShim
from repro.obs.trace import NULL_TRACER
from repro.queueing.broker import Broker
from repro.storage.object_store import StudyStore
from repro.utils.logging import get_logger

log = get_logger("ingest.pooler")


class PoolerCrash(RuntimeError):
    """Injected crash mid-batch (chaos): in-memory state is lost; recovery
    replays the durable checkpoint."""


class PoolerStats(StatsShim):
    """Pooler counters as real metrics (``repro_ingest_*``)."""

    _SUBSYSTEM = "ingest"
    _FIELDS = (
        "polls",
        "handed",          # events published into the broker
        "duplicates",      # feed redeliveries dropped against the seen set
        "outages",         # polls that hit FeedOutage
        "backoff_skips",   # polls skipped inside a backoff window
        "breaker_skips",   # polls skipped while the breaker was open
        "breaker_opens",
    )


class ChangePooler:
    def __init__(
        self,
        feed: PacsFeed,
        broker: Broker,
        checkpoint: Checkpoint,
        clock,
        *,
        seed: int = 0,
        batch: int = 32,
        base_backoff: float = 5.0,
        max_backoff: float = 300.0,
        jitter: float = 0.5,
        breaker_threshold: int = 3,
        breaker_cooldown: float = 120.0,
        tracer=None,
        registry=None,
    ) -> None:
        self.feed = feed
        self.broker = broker
        self.checkpoint = checkpoint
        self.clock = clock
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._registry = registry
        self.batch = batch
        self.base_backoff = base_backoff
        self.max_backoff = max_backoff
        self.jitter = jitter
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown = breaker_cooldown
        self.failures = 0
        self.next_poll_at = 0.0
        self.breaker_open_until: Optional[float] = None
        self.stats = PoolerStats(registry)
        # lazy import: repro.sim's package __init__ imports the harness,
        # which imports this module (module-level import would be a cycle)
        from repro.sim.events import HashRng

        self._rng = HashRng(seed, "pooler")

    def behind(self) -> bool:
        return self.checkpoint.floor() < self.feed.last_seq

    def poll_once(self, crash_after: Optional[int] = None) -> Dict[str, Any]:
        """One poll attempt at the current sim time. Returns a small status
        dict (logged by the harness). ``crash_after=k`` is the chaos hook:
        hand k events, publish the (k+1)-th WITHOUT marking it seen, then
        crash — the torn point the checkpoint contract must absorb."""
        now = self.clock.now()
        if self.breaker_open_until is not None:
            if now < self.breaker_open_until:
                self.stats.breaker_skips += 1
                return {"skipped": "breaker", "until": self.breaker_open_until}
            # half-open: one trial poll decides reset-or-reopen
            self.breaker_open_until = None
        if now < self.next_poll_at:
            self.stats.backoff_skips += 1
            return {"skipped": "backoff", "until": self.next_poll_at}
        self.stats.polls += 1
        # skipped polls (backoff/breaker, every idle tick) stay span-free;
        # only real poll attempts — including outages — leave a trace
        with self.tracer.span("ingest.poll") as _poll_span:
            return self._poll_traced(now, crash_after, _poll_span)

    def _poll_traced(
        self, now: float, crash_after: Optional[int], span
    ) -> Dict[str, Any]:
        try:
            batch = self.feed.poll(self.checkpoint.floor(), self.batch)
        except FeedOutage:
            self.failures += 1
            self.stats.outages += 1
            backoff = min(
                self.max_backoff, self.base_backoff * 2 ** (self.failures - 1)
            )
            # seeded jitter decorrelates retry herds without breaking replay
            backoff *= 1.0 + self.jitter * self._rng.u("jitter", self.failures)
            self.next_poll_at = now + backoff
            if self.failures >= self.breaker_threshold:
                self.breaker_open_until = now + self.breaker_cooldown
                self.stats.breaker_opens += 1
            span.set(kind="outage", error="FeedOutage")
            return {"outage": True, "failures": self.failures, "backoff": backoff}
        self.failures = 0
        handed = 0
        dups = 0
        events = sorted(batch, key=lambda e: e.seq)
        crash_at: Optional[int] = None
        if crash_after is not None:
            n_unseen = len({e.seq for e in events} - self.checkpoint.seen)
            if n_unseen:
                # clamp so an injected crash always fires mid-batch even when
                # the batch holds fewer unseen events than the requested offset
                crash_at = min(crash_after, n_unseen - 1)
        for event in events:
            if event.seq in self.checkpoint.seen:
                dups += 1
                self.stats.duplicates += 1
                continue
            # at-least-once handoff: publish BEFORE mark_seen; the applier's
            # (accession, etag) dedup makes the redelivery effect-idempotent
            self.broker.publish(
                key=f"feed/{event.accession}@{event.etag[:12]}#{event.seq}",
                payload={
                    "seq": event.seq,
                    "kind": event.kind,
                    "accession": event.accession,
                    "etag": event.etag,
                },
                nbytes=0,
            )
            if crash_at is not None and handed >= crash_at:
                raise PoolerCrash(
                    f"pooler crashed mid-batch after seq {event.seq} "
                    f"(published, not yet checkpointed)"
                )
            self.checkpoint.mark_seen(event.seq)
            handed += 1
            self.stats.handed += 1
        span.set(handed=handed, duplicates=dups, floor=self.checkpoint.floor())
        return {"handed": handed, "duplicates": dups, "floor": self.checkpoint.floor()}


class ApplierStats(StatsShim):
    """Applier counters as real metrics (``repro_applier_*``)."""

    _SUBSYSTEM = "applier"
    _FIELDS = (
        "applied",
        "deletes",
        "effect_deduped",  # same (accession, etag) already applied
        "stale_skipped",   # older than the newest applied event for the acc
        "redelivered",     # broker redeliveries of an already-outcome'd seq
    )


@dataclass
class AppliedOp:
    """What one apply actually did — the harness's bookkeeping handle."""

    seq: int
    op: str                  # "put" | "delete"
    accession: str
    etag: str                # PACS-side etag applied ("" for deletes)
    study: Any = None
    rows: int = 0


class IngestApplier:
    """Broker consumer that lands feed events in the lake, exactly once by
    effect. Shares the pooler's checkpoint — they are one ingest process."""

    def __init__(
        self,
        broker: Broker,
        feed: PacsFeed,
        store: StudyStore,
        checkpoint: Checkpoint,
        worker_id: str = "ingest-applier",
        tracer=None,
        registry=None,
        ledger=None,
    ) -> None:
        self.broker = broker
        self.feed = feed
        self.store = store
        self.checkpoint = checkpoint
        self.worker_id = worker_id
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        self.stats = ApplierStats(registry)

    def _outcome(
        self, seq: int, acc: str, etag: str, op: str, outcome: str, rows: int = 0
    ) -> None:
        """Checkpoint the terminal outcome AND audit it: every source
        mutation that reached a decision (applied / dup / stale) is a
        PHI-relevant change to what later deliveries will disclose."""
        self.checkpoint.mark_outcome(seq, acc, etag, op, outcome, rows=rows)
        self.ledger.append(
            INGEST_APPLY, feed_seq=seq, accession=acc, etag=etag, op=op,
            outcome=outcome, rows=rows,
        )

    def _apply_one(self, payload: Dict[str, Any]) -> Optional[AppliedOp]:
        ckpt = self.checkpoint
        seq = int(payload["seq"])
        acc = payload["accession"]
        etag = payload["etag"]
        kind = payload["kind"]
        if ckpt.has_outcome(seq):
            # redelivery (pooler crash between publish and mark_seen, or a
            # broker lease expiry): terminal outcome already recorded
            self.stats.redelivered += 1
            return None
        if seq < ckpt.applied_seq.get(acc, 0):
            # out-of-order: a newer event for this accession already landed —
            # applying the older one would regress the lake (freshness fence)
            self._outcome(seq, acc, etag, kind, "stale")
            self.stats.stale_skipped += 1
            return None
        if kind == "delete":
            self.store.delete_study(acc)
            self._outcome(seq, acc, "", "delete", "applied")
            self.stats.applied += 1
            self.stats.deletes += 1
            return AppliedOp(seq, "delete", acc, "")
        fetched = self.feed.fetch(acc)
        if fetched is None:
            # created/updated then deleted before we applied: the delete
            # event is (or will be) in the sequence — skip, don't resurrect
            self._outcome(seq, acc, etag, kind, "stale")
            self.stats.stale_skipped += 1
            return None
        study, current_etag = fetched
        if ckpt.applied_etag.get(acc) == current_etag:
            # effect-idempotent redelivery: these exact bytes already landed
            self._outcome(seq, acc, current_etag, kind, "dup")
            self.stats.effect_deduped += 1
            return None
        rows = len(study.datasets)
        # apply current bytes (not the event's snapshot): a burst of updates
        # collapses to one put + dups, and the lake never lags the last ack
        self.store.put_study(acc, study)
        self._outcome(seq, acc, current_etag, kind, "applied", rows=rows)
        self.stats.applied += 1
        return AppliedOp(seq, "put", acc, current_etag, study=study, rows=rows)

    def drain(self, max_messages: int = 256) -> List[AppliedOp]:
        """Pull-and-apply until the ingest queue is empty (bounded). Returns
        the ops that actually mutated the lake, in apply order."""
        out: List[AppliedOp] = []
        for _ in range(max_messages):
            msgs = self.broker.pull(self.worker_id, max_messages=1)
            if not msgs:
                break
            msg = msgs[0]
            with self.tracer.span(
                "ingest.apply",
                trace_id=None,
                key=msg.key,
                seq=int(msg.payload["seq"]),
                kind=msg.payload["kind"],
            ) as sp:
                applied = self._apply_one(msg.payload)
                if applied is not None:
                    out.append(applied)
                    sp.set(ok=True, rows=applied.rows)
                else:
                    sp.set(ok=False)
                self.broker.ack(msg.msg_id)
        return out
