# Pallas TPU kernels for the de-identification compute hot-spots.
#   scrub      — batched PHI rectangle blanking (the paper's scrub stage)
#   phi_detect — burned-in-text detector (paper Future Work: OCR/ML, TPU-adapted)
#   jls        — JPEG-Lossless predictor residuals (TPU half of the codec)
#   fused      — single-pass scrub+JLS (DESIGN.md §4)
#   bitmap     — packed-bitmap predicate combine + popcount (catalog queries)
#   textdetect — tile-wise text-band profiles for the burned-in-PHI
#                detector's registry fallback (DESIGN.md §9; numpy ref.py
#                is bit-identical, not just allclose)
# Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with CPU interpret fallback) and ref.py (pure-jnp oracle).
