# Pallas TPU kernels for the de-identification compute hot-spots.
#   scrub      — batched PHI rectangle blanking (the paper's scrub stage)
#   phi_detect — burned-in-text detector (paper Future Work: OCR/ML, TPU-adapted)
#   jls        — JPEG-Lossless predictor residuals (TPU half of the codec)
#   fused      — single-pass scrub+JLS (DESIGN.md §4)
#   bitmap     — packed-bitmap predicate combine + popcount (catalog queries)
# Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with CPU interpret fallback) and ref.py (pure-jnp oracle).
