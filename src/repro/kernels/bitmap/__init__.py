"""Packed-bitmap predicate combine + popcount kernel (catalog query engine).

The catalog's vectorized query path evaluates leaf predicates into packed
uint32 bitmaps (one bit per row) and hands the boolean combine to this
kernel, which evaluates the compiled stack program and popcounts the result
in one VMEM pass. ``ref.py`` is the numpy oracle the Pallas path is
parity-tested against.
"""
from repro.kernels.bitmap.ops import combine_bitmaps, pack_mask, unpack_mask
from repro.kernels.bitmap.ref import combine_bitmaps_ref, pack_mask_np, unpack_mask_np

__all__ = [
    "combine_bitmaps",
    "combine_bitmaps_ref",
    "pack_mask",
    "pack_mask_np",
    "unpack_mask",
    "unpack_mask_np",
]
