"""Pallas TPU kernel: packed-bitmap predicate combine + popcount.

The query engine's boolean algebra is bandwidth-trivial but latency-critical:
a cohort query touches every candidate row once. Packing rows 32-to-a-word
shrinks the combine's memory traffic 32x vs boolean arrays, and the whole
predicate tree evaluates as straight-line bitwise VPU ops:

* grid = (W / bw,); each program owns a (K, bw) VMEM tile of all K leaf
  bitmaps for one word-range and emits the combined (1, bw) bitmap tile plus
  a (1, 1) popcount partial.
* the compiled stack program is *static* (a jit constant), so the evaluation
  unrolls with no control flow in the kernel — same trick as the scrub
  kernel's static rect unroll.
* popcount uses the VPU's native ``lax.population_count``; per-tile partials
  are summed by the wrapper.

Padding contract: the wrapper zero-pads leaves to the lane-aligned width and
the compiler terminates every program by ANDing a validity leaf, so NOT can
never leak padding bits into the result or the counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.bitmap.ref import Program, run_program


def _combine_kernel(leaves_ref, bitmap_ref, count_ref, *, program: Program):
    tile = leaves_ref[...]  # (K, bw) uint32
    result = run_program(tile[:, None, :], program)  # rows as (1, bw) operands
    bitmap_ref[...] = result
    count_ref[0, 0] = jnp.sum(lax.population_count(result).astype(jnp.int32))


def combine_pallas(
    leaves: jnp.ndarray,
    program: Program,
    *,
    block: int = 1024,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """leaves: (K, W) uint32 with W % block == 0 and block % 128 == 0.
    Returns ((1, W) combined bitmap, (W/block, 1) int32 popcount partials)."""
    K, W = leaves.shape
    assert W % block == 0 and block % 128 == 0, (leaves.shape, block)
    grid = (W // block,)
    kernel = functools.partial(_combine_kernel, program=program)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((K, block), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((1, block), lambda j: (0, j)),
            pl.BlockSpec((1, 1), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, W), jnp.uint32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(leaves)
