"""Jit'd public wrapper for the bitmap combine kernel.

Pads leaf bitmaps to lane-aligned widths, dispatches to the Pallas kernel
(interpret mode on CPU, compiled on TPU), and exposes jnp packing helpers
that are bit-identical to the numpy reference in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmap.bitmap import combine_pallas
from repro.kernels.bitmap.ref import Program


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pack_mask(mask: jnp.ndarray) -> jnp.ndarray:
    """(n,) bool -> (ceil(n/32),) uint32, same little-endian layout as
    ``ref.pack_mask_np``."""
    mask = jnp.asarray(mask, bool)
    n = mask.shape[0]
    words = max((n + 31) // 32, 1)
    padded = jnp.zeros(words * 32, jnp.uint32).at[:n].set(mask.astype(jnp.uint32))
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(padded.reshape(-1, 32) * weights, axis=1, dtype=jnp.uint32)


def unpack_mask(bitmap: jnp.ndarray, n: int) -> np.ndarray:
    """(W,) uint32 -> host (n,) bool."""
    from repro.kernels.bitmap.ref import unpack_mask_np

    return unpack_mask_np(np.asarray(bitmap), n)


@functools.partial(jax.jit, static_argnames=("program", "block", "interpret"))
def _combine_padded(leaves, program, block, interpret):
    return combine_pallas(leaves, program, block=block, interpret=interpret)


def combine_bitmaps(
    leaves: jnp.ndarray,
    program: Program,
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, int]:
    """Evaluate a compiled predicate program over K leaf bitmaps.

    leaves: (K, W) uint32; program: static tuple of stack ops (see ref.py).
    Returns ((W,) combined uint32 bitmap, total popcount). Zero padding added
    here is cleared by the program's terminal validity-AND, so counts never
    include padding even under NOT.
    """
    if interpret is None:
        interpret = _on_cpu()
    leaves = jnp.asarray(leaves, jnp.uint32)
    K, W = leaves.shape
    block = min(1024, -(-W // 128) * 128)
    Wp = -(-W // block) * block
    if Wp != W:
        leaves = jnp.pad(leaves, ((0, 0), (0, Wp - W)))
    bitmap, partials = _combine_padded(leaves, program, block, interpret)
    return bitmap[0, :W], int(jnp.sum(partials))
