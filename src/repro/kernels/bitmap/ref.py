"""Numpy oracle for the bitmap combine kernel.

Same bit layout and the same stack program as the Pallas kernel: bit ``b`` of
word ``w`` is row ``w*32 + b`` (little-endian within the word). The kernel is
parity-tested bit-for-bit against this module.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# stack program opcodes: ("leaf", i) pushes leaf row i; ("and",)/("or",) pop
# two and push the combination; ("not",) inverts the top of the stack.
Program = Tuple[tuple, ...]

_BIT_WEIGHTS = (np.uint32(1) << np.arange(32, dtype=np.uint32))


def pack_mask_np(mask: np.ndarray) -> np.ndarray:
    """(n,) bool -> (ceil(n/32),) uint32, little-endian bit order. Padding
    bits are zero."""
    mask = np.asarray(mask, bool)
    n = mask.shape[0]
    words = (n + 31) // 32
    padded = np.zeros(max(words, 1) * 32, np.uint32)
    padded[:n] = mask.astype(np.uint32)
    return (padded.reshape(-1, 32) * _BIT_WEIGHTS).sum(axis=1, dtype=np.uint32)


def unpack_mask_np(bitmap: np.ndarray, n: int) -> np.ndarray:
    """(W,) uint32 -> (n,) bool, inverse of :func:`pack_mask_np`."""
    bitmap = np.asarray(bitmap, np.uint32)
    bits = (bitmap[:, None] >> np.arange(32, dtype=np.uint32)) & np.uint32(1)
    return bits.reshape(-1)[:n].astype(bool)


def run_program(leaves: np.ndarray, program: Program, xp=np) -> np.ndarray:
    """Evaluate the stack program over leaf bitmaps (K, W). Works for numpy
    and (inside the kernel) jax arrays alike — the program is static, so the
    evaluation unrolls into straight-line bitwise ops."""
    stack = []
    for op in program:
        if op[0] == "leaf":
            stack.append(leaves[op[1]])
        elif op[0] == "and":
            b, a = stack.pop(), stack.pop()
            stack.append(a & b)
        elif op[0] == "or":
            b, a = stack.pop(), stack.pop()
            stack.append(a | b)
        elif op[0] == "not":
            stack.append(~stack.pop())
        else:  # pragma: no cover - compile_query never emits anything else
            raise ValueError(f"unknown opcode {op!r}")
    if len(stack) != 1:
        raise ValueError(f"unbalanced program: {len(stack)} values left on stack")
    return stack.pop()


def combine_bitmaps_ref(leaves: np.ndarray, program: Program) -> Tuple[np.ndarray, int]:
    """Oracle: (bitmap (W,) uint32, popcount). The caller is responsible for
    masking padding bits (the query compiler always ANDs a validity leaf as
    the final program step, which clears anything a NOT resurrected)."""
    leaves = np.asarray(leaves, np.uint32)
    out = run_program(leaves, program)
    count = int(unpack_mask_np(out, out.shape[0] * 32).sum())
    return out, count
