from repro.kernels.fused.ops import fused_encode_batch, fused_scrub_residuals

__all__ = ["fused_scrub_residuals", "fused_encode_batch"]
