"""Pallas TPU kernel: fused PHI-rectangle scrub + JPEG-Lossless residuals.

Single-pass fusion of the two bandwidth-bound halves of the de-id hot path
(DESIGN.md §4). The staged pipeline streams every pixel through HBM twice:

    scrub:  read dtype, write dtype          (kernels/scrub)
    jls:    read dtype, write int32          (kernels/jls)

Both are pure HBM-streaming workloads, so running them back-to-back pays
2 reads + 1 same-dtype write + 1 int32 write per pixel. This kernel blanks
and predicts in one VMEM residency — 1 read + 1 int32 write — cutting HBM
traffic to 6/10 of the staged pair for uint16 (5/9 for uint8).

Correctness hinge: a blanked pixel's *neighbors* must also observe the
blanked value, exactly as if the scrubbed image had been materialized. The
rectangle mask is therefore folded into the predictor inputs in-register:

* ``x``  is masked with the tile's own row coordinates;
* ``rb`` (above) is masked with ``rows - 1`` — the mask of the row it came
  from, not the row it feeds;
* ``ra``/``rc`` are left-shifts of the already-masked ``x``/``rb``, so they
  inherit the mask for free (col-0 zero fill matches the codec's border
  convention, which never reads ra/rc there anyway).

Blocking mirrors ``kernels/jls``: full-width row stripes (1, bh, W) with the
above-neighbor of a stripe's first row delivered via a second, one-row-shifted
input read with the same BlockSpec. The rect list (R, 4) rides in VMEM per
image, unrolled statically (R is tiny — devices stamp a handful of banners).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(
    rects_ref, img_ref, above_ref, out_ref, *, sv: int, bits: int, bh: int, W: int, n_rects: int
):
    i = pl.program_id(1)
    x = img_ref[0].astype(jnp.int32)      # (bh, W)
    rb = above_ref[0].astype(jnp.int32)   # image shifted down one row

    rows = jax.lax.broadcasted_iota(jnp.int32, (bh, W), 0) + i * bh
    cols = jax.lax.broadcasted_iota(jnp.int32, (bh, W), 1)

    # rectangle coverage for this tile's rows and for the rows feeding rb
    mask_x = jnp.zeros((bh, W), jnp.bool_)
    mask_b = jnp.zeros((bh, W), jnp.bool_)
    for r in range(n_rects):  # static unroll: R is tiny (<=4 per device)
        rx = rects_ref[0, r, 0]
        ry = rects_ref[0, r, 1]
        rw = rects_ref[0, r, 2]
        rh = rects_ref[0, r, 3]
        valid = (rw > 0) & (rh > 0)
        in_cols = (cols >= rx) & (cols < rx + rw)
        mask_x |= in_cols & (rows >= ry) & (rows < ry + rh) & valid
        mask_b |= in_cols & (rows - 1 >= ry) & (rows - 1 < ry + rh) & valid

    zero = jnp.zeros((), jnp.int32)
    x = jnp.where(mask_x, zero, x)
    rb = jnp.where(mask_b, zero, rb)

    zeros_col = jnp.zeros((bh, 1), jnp.int32)
    ra = jnp.concatenate([zeros_col, x[:, :-1]], axis=1)
    rc = jnp.concatenate([zeros_col, rb[:, :-1]], axis=1)

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(sv)

    pred = jnp.where((rows == 0) & (cols > 0), ra, pred)
    pred = jnp.where((rows > 0) & (cols == 0), rb, pred)
    pred = jnp.where((rows == 0) & (cols == 0), 1 << (bits - 1), pred)

    mask = (1 << bits) - 1
    r = (x - pred) & mask
    r = jnp.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    out_ref[0] = r


def fused_scrub_jls_pallas(
    images: jnp.ndarray,
    above: jnp.ndarray,
    rects: jnp.ndarray,
    *,
    sv: int,
    bits: int,
    bh: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """images, above: (N, H, W) with H % bh == 0; rects: (N, R, 4) int32.

    Returns int32 residuals of the *scrubbed* image — bit-identical to
    ``codec.residuals(numpy_blank(img, rects), sv)`` (property-tested).
    """
    N, H, W = images.shape
    assert H % bh == 0, (images.shape, bh)
    n_rects = rects.shape[1]
    grid = (N, H // bh)
    kernel = functools.partial(_fused_kernel, sv=sv, bits=bits, bh=bh, W=W, n_rects=n_rects)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # whole rect list for image n, broadcast over the stripe grid
            pl.BlockSpec((1, n_rects, 4), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, H, W), jnp.int32),
        interpret=interpret,
    )(rects, images, above)
