"""Jit'd public wrapper for the fused scrub+JLS kernel.

Pads H to a stripe multiple, builds the one-row-shifted ``above`` input,
dispatches (interpret mode on CPU, compiled on TPU), and crops back. The
bottom padding rows never influence real rows — prediction only looks up and
left — so the crop is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused.fused import fused_scrub_jls_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("sv", "bits", "bh", "interpret"))
def _fused(images, rects, sv, bits, bh, interpret):
    above = jnp.pad(images, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return fused_scrub_jls_pallas(
        images, above, rects, sv=sv, bits=bits, bh=bh, interpret=interpret
    )


def fused_scrub_residuals(
    images: jnp.ndarray,
    rects: jnp.ndarray,
    *,
    sv: int = 1,
    bits: int | None = None,
    bh: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Blank rectangles and compute predictor residuals in one device pass.

    images: (N, H, W); rects: (N, R, 4) int32 (x, y, w, h), padding rects have
    w<=0/h<=0. Returns int32 (N, H, W) residuals of the scrubbed image.
    """
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    rects = jnp.asarray(rects, jnp.int32)
    if bits is None:
        bits = images.dtype.itemsize * 8
    N, H, W = images.shape
    Hp = (H + bh - 1) // bh * bh
    padded = images if Hp == H else jnp.pad(images, ((0, 0), (0, Hp - H), (0, 0)))
    out = _fused(padded, rects, sv, bits, bh, interpret)
    return out[:, :H, :]


def fused_encode_batch(images: np.ndarray, rect_lists, sv: int = 1) -> list[bytes]:
    """Fused-kernel-assisted encode of a uniform batch: blank + residuals on
    device in one pass, Golomb-Rice entropy code on host. Byte-identical to
    ``codec.encode(numpy_blank(img, rects), sv)`` (tested)."""
    from repro.dicom import codec
    from repro.kernels.scrub.ops import pack_rects

    rects = pack_rects([list(r) for r in rect_lists])
    res = np.asarray(fused_scrub_residuals(images, rects, sv=sv))
    bits = images.dtype.itemsize * 8
    out = []
    for i in range(images.shape[0]):
        payload, k = codec.rice_encode(res[i])
        out.append(
            codec.pack_header(images.shape[1], images.shape[2], bits, sv, k, len(payload))
            + payload
        )
    return out
