"""Pure-jnp oracle for the fused scrub+JLS kernel: the staged two-pass
composition ``scrub_ref -> residuals_ref``. The kernel must match this (and
the host ``numpy_blank -> codec.residuals`` pair) bit-exactly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.jls.ref import residuals_ref
from repro.kernels.scrub.ref import scrub_ref


def fused_ref(images: jnp.ndarray, rects: jnp.ndarray, sv: int, bits: int) -> jnp.ndarray:
    """images: (N, H, W); rects: (N, R, 4) int32. Staged oracle."""
    return residuals_ref(scrub_ref(images, rects), sv, bits)
