"""Pallas TPU kernels: Golomb-Rice entropy pre-pass (DESIGN.md §12).

The split codec (``repro.dicom.codec``) factors entropy coding into a *plan*
phase (zigzag magnitudes, Rice parameter k, per-symbol code lengths) and a
*pack* phase (the final unary splice). The plan phase is pointwise +
reduction work — exactly what the VPU wants — so these two kernels move it
onto the device and leave the host only the splice:

* :func:`rice_prepass` — zigzag + per-row integer sums. The host folds the
  row sums into the per-instance exact zigzag sum and derives k with
  ``codec._rice_k_from_sum`` (integer math end to end, so the device-assisted
  plan lands on the same k as the host plan — bit-identity is what keeps
  batched == serial).
* :func:`rice_len_rem` — given per-instance k, per-symbol code lengths and
  the k-bit remainder words (``codec.rice_plan_from_prepass`` consumes them).

All arithmetic stays in int32: residuals of <=16-bit planes zigzag to <=17
bits and a full-width row sum of those stays under 2^31 for any plausible
detector/CR width, so the kernels agree bit-exactly with the numpy plan on
every backend (parity-tested, interpret + compiled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_QMAX = 23  # mirrors codec._QMAX; a shared constant test pins them together
_ESC_LEN = _QMAX + 2 + 64


def _zigzag_rowsum_kernel(res_ref, u_ref, rs_ref):
    r = res_ref[0]  # (bh, W) int32
    u = (r << 1) ^ (r >> 31)  # zigzag: non-negative, <= 2^17 for 16-bit planes
    u_ref[0] = u
    rs_ref[0] = jnp.sum(u, axis=1)


def _len_rem_kernel(k_ref, u_ref, len_ref, rem_ref):
    kv = k_ref[0, 0]  # per-instance Rice parameter
    u = u_ref[0]  # (bh, W) int32 zigzag magnitudes
    q = jax.lax.shift_right_logical(u, kv)
    esc = q > _QMAX
    len_ref[0] = jnp.where(esc, _ESC_LEN, q + 1 + kv)
    rem_ref[0] = u & ((1 << kv) - 1)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def _prepass(res, bh, interpret):
    N, H, W = res.shape
    Hp = (H + bh - 1) // bh * bh
    padded = res if Hp == H else jnp.pad(res, ((0, 0), (0, Hp - H), (0, 0)))
    u, rs = pl.pallas_call(
        _zigzag_rowsum_kernel,
        grid=(N, Hp // bh),
        in_specs=[pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0))],
        out_specs=[
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, bh), lambda n, i: (n, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Hp, W), jnp.int32),
            jax.ShapeDtypeStruct((N, Hp), jnp.int32),
        ],
        interpret=interpret,
    )(padded)
    return u[:, :H, :], rs[:, :H]


def rice_prepass(
    res: jnp.ndarray, *, bh: int = 64, interpret: bool | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Zigzag magnitudes + per-row sums for an (N, H, W) int32 residual batch.

    Returns device arrays (int32 ``u`` (N, H, W), int32 row sums (N, H)) —
    the call is asynchronous; callers choose when to block, which is what
    lets the batched executor overlap this with the host pack of the
    previous chunk.
    """
    if interpret is None:
        interpret = _on_cpu()
    return _prepass(jnp.asarray(res, jnp.int32), bh, interpret)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def _len_rem(u, ks, bh, interpret):
    N, H, W = u.shape
    Hp = (H + bh - 1) // bh * bh
    padded = u if Hp == H else jnp.pad(u, ((0, 0), (0, Hp - H), (0, 0)))
    lens, rem = pl.pallas_call(
        _len_rem_kernel,
        grid=(N, Hp // bh),
        in_specs=[
            pl.BlockSpec((1, 1), lambda n, i: (n, 0)),
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Hp, W), jnp.int32),
            jax.ShapeDtypeStruct((N, Hp, W), jnp.int32),
        ],
        interpret=interpret,
    )(ks, padded)
    return lens[:, :H, :], rem[:, :H, :]


def rice_len_rem(
    u: jnp.ndarray,
    ks,
    *,
    bh: int = 64,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-symbol code lengths + k-bit remainder words for a zigzag batch.

    ``ks`` is the per-instance Rice parameter, shape (N,) or (N, 1) int32.
    Returns device arrays; asynchronous like :func:`rice_prepass`.
    """
    if interpret is None:
        interpret = _on_cpu()
    ks = jnp.asarray(ks, jnp.int32).reshape(-1, 1)
    return _len_rem(jnp.asarray(u, jnp.int32), ks, bh, interpret)
