"""Pallas TPU kernel: JPEG-Lossless predictor residuals.

The TPU half of the paper's "recompress with JPEG Lossless" step
(DESIGN.md §3): prediction is pointwise over shifted planes — ideal VPU work —
while the sequential entropy coder stays on the host.

Blocking: full-width row stripes (1, bh, W). Left/above-left neighbors are
in-block shifts along W (full row present); the above-neighbor of a stripe's
first row lives in the *previous* stripe, so the wrapper passes a second input
``above`` = image shifted down one row, read with the same BlockSpec. That
costs one extra HBM read of the first row per stripe on TPU (negligible for
bh>=64) and keeps the kernel halo-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jls_kernel(img_ref, above_ref, out_ref, *, sv: int, bits: int, bh: int, W: int):
    i = pl.program_id(1)
    x = img_ref[0].astype(jnp.int32)      # (bh, W)
    rb = above_ref[0].astype(jnp.int32)   # x shifted down by one row

    zeros_col = jnp.zeros((bh, 1), jnp.int32)
    ra = jnp.concatenate([zeros_col, x[:, :-1]], axis=1)
    rc = jnp.concatenate([zeros_col, rb[:, :-1]], axis=1)

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(sv)

    rows = jax.lax.broadcasted_iota(jnp.int32, (bh, W), 0) + i * bh
    cols = jax.lax.broadcasted_iota(jnp.int32, (bh, W), 1)
    pred = jnp.where((rows == 0) & (cols > 0), ra, pred)
    pred = jnp.where((rows > 0) & (cols == 0), rb, pred)
    pred = jnp.where((rows == 0) & (cols == 0), 1 << (bits - 1), pred)

    mask = (1 << bits) - 1
    r = (x - pred) & mask
    r = jnp.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    out_ref[0] = r


def jls_residuals_pallas(
    images: jnp.ndarray,
    above: jnp.ndarray,
    *,
    sv: int,
    bits: int,
    bh: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """images, above: (N, H, W) with H % bh == 0. Returns int32 residuals."""
    N, H, W = images.shape
    assert H % bh == 0, (images.shape, bh)
    grid = (N, H // bh)
    kernel = functools.partial(_jls_kernel, sv=sv, bits=bits, bh=bh, W=W)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, W), lambda n, i: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, H, W), jnp.int32),
        interpret=interpret,
    )(images, above)
