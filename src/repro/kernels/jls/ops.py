"""Jit'd wrapper for the JPEG-Lossless predictor kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.jls.jls import jls_residuals_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("sv", "bits", "bh", "interpret"))
def _residuals(images, sv, bits, bh, interpret):
    above = jnp.pad(images, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return jls_residuals_pallas(images, above, sv=sv, bits=bits, bh=bh, interpret=interpret)


def jls_residuals(
    images: jnp.ndarray,
    *,
    sv: int = 1,
    bits: int | None = None,
    bh: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched predictor residuals (N, H, W) -> int32 (N, H, W)."""
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    if bits is None:
        bits = images.dtype.itemsize * 8
    N, H, W = images.shape
    Hp = (H + bh - 1) // bh * bh
    padded = images if Hp == H else jnp.pad(images, ((0, 0), (0, Hp - H), (0, 0)))
    out = _residuals(padded, sv, bits, bh, interpret)
    return out[:, :H, :]


def encode_batch(images: np.ndarray, sv: int = 1) -> list[bytes]:
    """TPU-assisted encode: residuals via the kernel, entropy code on host.
    Byte-identical to the pure-host ``repro.dicom.codec.encode`` (tested)."""
    from repro.dicom import codec

    res = np.asarray(jls_residuals(images, sv=sv))
    out = []
    bits = images.dtype.itemsize * 8
    for i in range(images.shape[0]):
        payload, k = codec.rice_encode(res[i])
        hdr = codec.pack_header(images.shape[1], images.shape[2], bits, sv, k, len(payload))
        out.append(hdr + payload)
    return out
