"""Pure-jnp oracle for the JPEG-Lossless predictor kernel.

Must agree bit-exactly with the host codec (`repro.dicom.codec.residuals`) —
a cross-check test asserts jnp-oracle == numpy-codec == pallas-kernel.
"""
from __future__ import annotations

import jax.numpy as jnp


def residuals_ref(images: jnp.ndarray, sv: int, bits: int) -> jnp.ndarray:
    """Batched signed modulo-2^bits predictor residuals. images: (N, H, W)."""
    x = images.astype(jnp.int32)
    N, H, W = x.shape
    zeros_col = jnp.zeros((N, H, 1), jnp.int32)
    zeros_row = jnp.zeros((N, 1, W), jnp.int32)
    ra = jnp.concatenate([zeros_col, x[:, :, :-1]], axis=2)   # left
    rb = jnp.concatenate([zeros_row, x[:, :-1, :]], axis=1)   # above
    rc = jnp.concatenate([zeros_row, ra[:, :-1, :]], axis=1)  # above-left

    if sv == 1:
        pred = ra
    elif sv == 2:
        pred = rb
    elif sv == 3:
        pred = rc
    elif sv == 4:
        pred = ra + rb - rc
    elif sv == 5:
        pred = ra + ((rb - rc) >> 1)
    elif sv == 6:
        pred = rb + ((ra - rc) >> 1)
    elif sv == 7:
        pred = (ra + rb) >> 1
    else:
        raise ValueError(f"selection value must be 1..7, got {sv}")

    rows = jnp.arange(H)[None, :, None]
    cols = jnp.arange(W)[None, None, :]
    pred = jnp.where((rows == 0) & (cols > 0), ra, pred)   # row 0: left
    pred = jnp.where((rows > 0) & (cols == 0), rb, pred)   # col 0: above
    pred = jnp.where((rows == 0) & (cols == 0), 1 << (bits - 1), pred)

    mask = (1 << bits) - 1
    r = (x - pred) & mask
    r = jnp.where(r >= (1 << (bits - 1)), r - (1 << bits), r)
    return r.astype(jnp.int32)
