"""Jit'd wrapper for the PHI text detector."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.phi_detect.phi_detect import phi_detect_pallas

# Default gradient threshold: burned-in glyph strokes are max-contrast
# (value jumps of >50% full scale every ~3 px); anatomy gradients are smooth.
DEFAULT_THRESH_FRAC = 0.25  # fraction of the sample value range
DEFAULT_TAU = 0.08          # tile flagged if >=8% of pixels are strong edges


def full_scale(dtype, max_value: float | None = None) -> float:
    """Maximum sample value for thresholding.

    Derived from the dtype (65535 for full-range uint16 ultrasound captures,
    255 for uint8, 1.0 for floats) unless ``max_value`` overrides it — pass
    the BitsStored-derived ceiling (e.g. 4095 for 12-bit CT) when the stored
    range is narrower than the dtype.
    """
    if max_value is not None:
        return float(max_value)
    dt = np.dtype(dtype)
    return float(np.iinfo(dt).max) if dt.kind in "ui" else 1.0


def stored_max_value(ds) -> float:
    """Sample ceiling for a DICOM dataset: BitsStored when declared (12-bit
    CT in uint16 words). Without a declared depth the ceiling is estimated
    from the observed sample maximum (next power-of-two range): the dtype max
    would put the threshold above every gradient a narrow-range image can
    produce and silently fail the audit *open*. This is the one place the
    ceiling is derived — audit callers must not re-implement it."""
    bits = ds.get("BitsStored")
    if bits is not None:
        return float((1 << int(bits)) - 1)
    pix = ds.pixels
    dt = np.dtype(pix.dtype)
    if dt.kind in "ui" and pix.size:
        bits_est = max(int(pix.max()).bit_length(), 1)
        return float((1 << bits_est) - 1)
    return full_scale(dt)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("thresh", "tile", "interpret"))
def _detect(images, thresh, tile, interpret):
    return phi_detect_pallas(images, thresh=thresh, tile=tile, interpret=interpret)


def edge_density(
    images: jnp.ndarray,
    *,
    thresh: float | None = None,
    max_value: float | None = None,
    tile: tuple[int, int] = (32, 128),
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-tile strong-edge density for a batch of images (N, H, W).

    The default threshold is ``DEFAULT_THRESH_FRAC`` of the dtype's full
    scale; pass ``max_value`` (BitsStored-style) when the stored range is
    narrower, e.g. 4095 for 12-bit data held in uint16.
    """
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    if thresh is None:
        thresh = full_scale(images.dtype, max_value) * DEFAULT_THRESH_FRAC
    N, H, W = images.shape
    th, tw = tile
    Hp, Wp = (H + th - 1) // th * th, (W + tw - 1) // tw * tw
    if (Hp, Wp) != (H, W):
        images = jnp.pad(images, ((0, 0), (0, Hp - H), (0, Wp - W)))
    return _detect(images, float(thresh), (th, tw), interpret)


def suspicious_tiles(images, *, tau: float = DEFAULT_TAU, **kw) -> np.ndarray:
    """Boolean heat map of tiles likely to contain burned-in text."""
    return np.asarray(edge_density(images, **kw) >= tau)


def audit_image(
    pixels: np.ndarray,
    *,
    tile=(32, 128),
    tau: float = DEFAULT_TAU,
    max_value: float | None = None,
) -> bool:
    """True if any tile of a single image looks like burned-in text.
    Used by the pipeline audit path (DESIGN.md §3) on *post-scrub* images:
    a True here means a scrub rule missed a region. ``max_value`` is the
    BitsStored-derived sample ceiling (see :func:`edge_density`)."""
    return bool(
        suspicious_tiles(
            jnp.asarray(pixels)[None], tau=tau, tile=tile, max_value=max_value
        ).any()
    )


def audit_dataset(ds, **kw) -> bool:
    """Audit a DICOM dataset's pixels at its *stored* bit depth — the safe
    entry point for pipeline/audit callers (a raw ``audit_image`` on 12-bit
    data held in uint16 would threshold at the dtype max and fail open)."""
    return audit_image(ds.pixels, max_value=stored_max_value(ds), **kw)
