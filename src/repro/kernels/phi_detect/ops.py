"""Jit'd wrapper for the PHI text detector."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.phi_detect.phi_detect import phi_detect_pallas

# Default gradient threshold: burned-in glyph strokes are max-contrast
# (value jumps of >50% full scale every ~3 px); anatomy gradients are smooth.
DEFAULT_THRESH_FRAC = 0.25  # fraction of dtype max
DEFAULT_TAU = 0.08          # tile flagged if >=8% of pixels are strong edges


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("thresh", "tile", "interpret"))
def _detect(images, thresh, tile, interpret):
    return phi_detect_pallas(images, thresh=thresh, tile=tile, interpret=interpret)


def edge_density(
    images: jnp.ndarray,
    *,
    thresh: float | None = None,
    tile: tuple[int, int] = (32, 128),
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-tile strong-edge density for a batch of images (N, H, W)."""
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    if thresh is None:
        maxv = 255.0 if images.dtype == jnp.uint8 else 4095.0
        thresh = maxv * DEFAULT_THRESH_FRAC
    N, H, W = images.shape
    th, tw = tile
    Hp, Wp = (H + th - 1) // th * th, (W + tw - 1) // tw * tw
    if (Hp, Wp) != (H, W):
        images = jnp.pad(images, ((0, 0), (0, Hp - H), (0, Wp - W)))
    return _detect(images, float(thresh), (th, tw), interpret)


def suspicious_tiles(images, *, tau: float = DEFAULT_TAU, **kw) -> np.ndarray:
    """Boolean heat map of tiles likely to contain burned-in text."""
    return np.asarray(edge_density(images, **kw) >= tau)


def audit_image(pixels: np.ndarray, *, tile=(32, 128), tau: float = DEFAULT_TAU) -> bool:
    """True if any tile of a single image looks like burned-in text.
    Used by the pipeline audit path (DESIGN.md §3) on *post-scrub* images:
    a True here means a scrub rule missed a region."""
    return bool(suspicious_tiles(jnp.asarray(pixels)[None], tau=tau, tile=tile).any())
