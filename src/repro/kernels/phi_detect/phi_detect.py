"""Pallas TPU kernel: burned-in-annotation (PHI text) detector.

TPU-native first step of the paper's Future-Work "OCR and other machine
learning approaches to improve image de-identification": a tiled
edge-density reduction producing a per-tile text-likelihood heat map. Used to
audit whitelist coverage (route images whose *unscrubbed* tiles light up to
the filter) — the machine-checkable analogue of the paper's human review.

Kernel shape: grid (N, H/th, W/tw); each program reduces one (th, tw) VMEM
tile to one scalar density. This is a pure streaming reduction — reads each
pixel exactly once, writes H/th * W/tw floats — so, like scrub, it runs at
HBM bandwidth. The gradient is tile-local (no halo), which the oracle mirrors
exactly; detection quality is insensitive to losing one boundary column per
tile (text banners are hundreds of pixels wide).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phi_kernel(img_ref, out_ref, *, thresh: float, th: int, tw: int):
    tile = img_ref[0].astype(jnp.float32)  # (th, tw)
    grad = jnp.abs(tile[:, 1:] - tile[:, :-1])
    hits = jnp.sum((grad >= thresh).astype(jnp.float32))
    out_ref[0, 0, 0] = hits / float(th * tw)


def phi_detect_pallas(
    images: jnp.ndarray,
    *,
    thresh: float,
    tile: tuple[int, int] = (32, 128),
    interpret: bool = False,
) -> jnp.ndarray:
    """images: (N, H, W), tile-aligned. Returns (N, H/th, W/tw) f32 densities."""
    N, H, W = images.shape
    th, tw = tile
    assert H % th == 0 and W % tw == 0, (images.shape, tile)
    grid = (N, H // th, W // tw)
    kernel = functools.partial(_phi_kernel, thresh=thresh, th=th, tw=tw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, th, tw), lambda n, i, j: (n, i, j))],
        out_specs=pl.BlockSpec((1, 1, 1), lambda n, i, j: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, H // th, W // tw), jnp.float32),
        interpret=interpret,
    )(images)
