"""Pure-jnp oracle for the burned-in-text detector.

Semantics (tile-local by construction, so kernel and oracle agree exactly):
the image is partitioned into (th, tw) tiles; within each tile we count
strong horizontal gradients — |x[i, j+1] - x[i, j]| >= thresh, computed only
for in-tile neighbor pairs — and return the count normalized by tile area.
Burned-in text is a dense field of vertical strokes, so its edge density is
an order of magnitude above anatomy (see tests for separation margins).
"""
from __future__ import annotations

import jax.numpy as jnp


def edge_density_ref(images: jnp.ndarray, thresh: float, tile: tuple[int, int]) -> jnp.ndarray:
    """images: (N, H, W); returns (N, H/th, W/tw) float32 densities in [0, 1]."""
    N, H, W = images.shape
    th, tw = tile
    assert H % th == 0 and W % tw == 0, (images.shape, tile)
    x = images.astype(jnp.float32)
    t = x.reshape(N, H // th, th, W // tw, tw)  # tile-local view
    grad = jnp.abs(t[..., 1:] - t[..., :-1])    # in-tile horizontal gradient
    hits = (grad >= thresh).sum(axis=(2, 4))
    return (hits / float(th * tw)).astype(jnp.float32)


def phi_flags_ref(images: jnp.ndarray, thresh: float, tile: tuple[int, int], tau: float) -> jnp.ndarray:
    return edge_density_ref(images, thresh, tile) >= tau
