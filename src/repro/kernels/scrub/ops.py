"""Jit'd public wrapper for the scrub kernel.

Pads images to tile-aligned shapes, dispatches to the Pallas kernel (interpret
mode on CPU, compiled on TPU), crops back, and offers a convenience adapter
matching the ``ScrubStage`` ``blank_fn`` protocol.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.scrub.scrub import scrub_pallas

_SUBLANE = {1: 32, 2: 16, 4: 8, 8: 8}  # dtype itemsize -> min sublane tile


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def default_block(dtype: jnp.dtype, H: int, W: int) -> tuple[int, int]:
    """Pick a VMEM-friendly tile: lane dim multiple of 128, sublane dim a
    multiple of the dtype tile, working set well under VMEM (~16 MB/core).

    Each dimension is the image extent rounded up to its alignment unit
    (128 lanes / the dtype sublane tile), capped at 512x256 — so an image
    never pads by more than one alignment unit, and never by a full tile.
    """
    sub = _SUBLANE[jnp.dtype(dtype).itemsize]
    bw = min(512, -(-W // 128) * 128)
    bh = min(256, -(-max(H, 1) // sub) * sub)
    return bh, bw


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _scrub_padded(images, rects, block, interpret):
    return scrub_pallas(images, rects, block=block, interpret=interpret)


def scrub_images(
    images: jnp.ndarray,
    rects: jnp.ndarray,
    *,
    block: tuple[int, int] | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Blank rectangles on a batch of images.

    images: (N, H, W); rects: (N, R, 4) int32 (x, y, w, h); padding rects have
    w<=0/h<=0. Returns same shape/dtype.
    """
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    rects = jnp.asarray(rects, jnp.int32)
    N, H, W = images.shape
    bh, bw = block or default_block(images.dtype, H, W)
    Hp = (H + bh - 1) // bh * bh
    Wp = (W + bw - 1) // bw * bw
    padded = images
    if (Hp, Wp) != (H, W):
        padded = jnp.pad(images, ((0, 0), (0, Hp - H), (0, Wp - W)))
    out = _scrub_padded(padded, rects, (bh, bw), interpret)
    return out[:, :H, :W]


def pack_rects(rect_lists: Sequence[Sequence[tuple[int, int, int, int]]], R: int | None = None) -> np.ndarray:
    """Pack ragged per-image rect lists into a (N, R, 4) int32 array.

    ``R`` defaults to the longest list (min 1). An explicit ``R`` smaller than
    the longest list raises — silently dropping scrub rectangles would ship
    PHI pixels through un-blanked.
    """
    longest = max((len(r) for r in rect_lists), default=0)
    if R is None:
        R = max(longest, 1)
    elif longest > R:
        raise ValueError(
            f"rect list of length {longest} does not fit R={R}; "
            "refusing to truncate scrub rectangles"
        )
    out = np.zeros((len(rect_lists), R, 4), np.int32)
    for i, rl in enumerate(rect_lists):
        for j, rect in enumerate(rl):
            out[i, j] = rect
    return out


def blank_fn(pixels: np.ndarray, rects) -> np.ndarray:
    """Adapter matching ``repro.core.scrub.ScrubStage(blank_fn=...)``:
    single-image host entry point backed by the Pallas kernel."""
    img = jnp.asarray(pixels)[None]
    packed = pack_rects([list(rects)])
    return np.asarray(scrub_images(img, packed)[0])


# Same observable contract as core.scrub.numpy_blank (zero the rectangles,
# touch nothing else) — lets the batched executor substitute the fused kernel.
blank_fn.rect_blank_semantics = True
