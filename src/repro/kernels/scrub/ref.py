"""Pure-jnp oracle for the scrub kernel.

Semantics: for each image n, every rectangle (x, y, w, h) in ``rects[n]`` is
blanked to 0. Rectangles with w<=0 or h<=0 are padding no-ops (rect lists are
ragged per device; callers pad to a fixed R).
"""
from __future__ import annotations

import jax.numpy as jnp


def scrub_ref(images: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """images: (N, H, W) any integer/float dtype; rects: (N, R, 4) int32 x,y,w,h."""
    N, H, W = images.shape
    rows = jnp.arange(H, dtype=jnp.int32)[:, None]  # (H, 1)
    cols = jnp.arange(W, dtype=jnp.int32)[None, :]  # (1, W)
    x = rects[..., 0][:, :, None, None]  # (N, R, 1, 1)
    y = rects[..., 1][:, :, None, None]
    w = rects[..., 2][:, :, None, None]
    h = rects[..., 3][:, :, None, None]
    inside = (
        (cols[None, None] >= x)
        & (cols[None, None] < x + w)
        & (rows[None, None] >= y)
        & (rows[None, None] < y + h)
        & (w > 0)
        & (h > 0)
    )  # (N, R, H, W)
    mask = jnp.any(inside, axis=1)  # (N, H, W)
    return jnp.where(mask, jnp.zeros((), images.dtype), images)
