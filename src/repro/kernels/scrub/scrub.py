"""Pallas TPU kernel: batched PHI rectangle blanking.

TPU adaptation of the paper's scrub stage (DESIGN.md §3). The stage is
bandwidth-bound (read pixel, maybe zero it, write pixel), so the kernel's job
is to stream HBM->VMEM->HBM at full rate while folding the rectangle test into
the VPU pipeline:

* grid = (N, H/bh, W/bw); each program owns one (bh, bw) VMEM tile of one
  image. bw is a multiple of 128 (VPU lane width); bh a multiple of the
  dtype's sublane tile (32 for 8-bit, 16 for 16-bit, 8 for f32).
* the per-image rectangle list (R, 4) rides in VMEM with the tile; the
  coverage mask is built with ``broadcasted_iota`` + compares, unrolled over R
  (R is small and static — devices stamp a handful of banners).
* out-of-image padding (H, W not tile-aligned) is handled by the wrapper in
  ops.py, keeping the kernel branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scrub_kernel(rects_ref, img_ref, out_ref, *, bh: int, bw: int, n_rects: int):
    i = pl.program_id(1)  # tile row index
    j = pl.program_id(2)  # tile col index
    tile = img_ref[0]  # (bh, bw)

    # global pixel coordinates of this tile
    row0 = i * bh
    col0 = j * bw
    rows = jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.int32, (bh, bw), 1) + col0

    mask = jnp.zeros((bh, bw), jnp.bool_)
    for r in range(n_rects):  # static unroll: R is tiny (<=4 per device)
        x = rects_ref[0, r, 0]
        y = rects_ref[0, r, 1]
        w = rects_ref[0, r, 2]
        h = rects_ref[0, r, 3]
        hit = (cols >= x) & (cols < x + w) & (rows >= y) & (rows < y + h)
        hit &= (w > 0) & (h > 0)
        mask |= hit

    out_ref[0] = jnp.where(mask, jnp.zeros((), tile.dtype), tile)


def scrub_pallas(
    images: jnp.ndarray,
    rects: jnp.ndarray,
    *,
    block: tuple[int, int] = (256, 256),
    interpret: bool = False,
) -> jnp.ndarray:
    """images: (N, H, W) with H % bh == 0 and W % bw == 0; rects: (N, R, 4)."""
    N, H, W = images.shape
    bh, bw = block
    assert H % bh == 0 and W % bw == 0, (images.shape, block)
    n_rects = rects.shape[1]
    grid = (N, H // bh, W // bw)

    kernel = functools.partial(_scrub_kernel, bh=bh, bw=bw, n_rects=n_rects)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # whole rect list for image n, broadcast over the tile grid
            pl.BlockSpec((1, n_rects, 4), lambda n, i, j: (n, 0, 0)),
            pl.BlockSpec((1, bh, bw), lambda n, i, j: (n, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bh, bw), lambda n, i, j: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct(images.shape, images.dtype),
        interpret=interpret,
    )(rects, images)
