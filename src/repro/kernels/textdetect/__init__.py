from repro.kernels.textdetect import ops, ref  # noqa: F401
