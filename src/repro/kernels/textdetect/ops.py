"""Jit'd public wrapper for the text-band detector kernel.

Pads inputs to tile multiples (zero padding can never binarize to a hit),
dispatches to the Pallas kernel (interpret mode on CPU, compiled on TPU),
and reduces tile profiles to the full-width per-row hit counts the band
extractor (``repro.detect.regions``) consumes. The binarization threshold
reuses ``phi_detect``'s dtype-aware ceiling logic: ``full_scale`` /
``stored_max_value`` times :data:`BINARIZE_FRAC`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.detect.policy import DEFAULT_BINARIZE_FRAC as BINARIZE_FRAC
from repro.kernels.phi_detect.ops import full_scale, stored_max_value  # noqa: F401
from repro.kernels.textdetect.textdetect import textdetect_pallas


def binarize_thresh(dtype, max_value: float | None = None) -> float:
    """Dtype-aware glyph threshold (same ceiling logic as ``phi_detect``)."""
    return full_scale(dtype, max_value) * BINARIZE_FRAC


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("thresh", "tile", "interpret"))
def _profiles(images, thresh, tile, interpret):
    return textdetect_pallas(images, thresh=thresh, tile=tile, interpret=interpret)


def tile_profiles(
    images: jnp.ndarray,
    *,
    thresh: float | None = None,
    max_value: float | None = None,
    tile: tuple[int, int] = (32, 128),
    interpret: bool | None = None,
):
    """Per-tile (rows, cols, runs) int32 profiles for a batch (N, H, W).

    Pads H and W up to tile multiples; padding tiles report zero hits. The
    default threshold is :func:`binarize_thresh` of the dtype (pass
    ``max_value`` for BitsStored-style narrow ranges held in wide words).
    """
    if interpret is None:
        interpret = _on_cpu()
    images = jnp.asarray(images)
    if thresh is None:
        thresh = binarize_thresh(images.dtype, max_value)
    N, H, W = images.shape
    th, tw = tile
    Hp, Wp = -(-H // th) * th, -(-W // tw) * tw
    if (Hp, Wp) != (H, W):
        images = jnp.pad(images, ((0, 0), (0, Hp - H), (0, Wp - W)))
    return _profiles(images, float(thresh), (th, tw), interpret)


def row_hit_profile(
    images: np.ndarray,
    *,
    thresh: float | None = None,
    max_value: float | None = None,
    tile: tuple[int, int] = (32, 128),
    interpret: bool | None = None,
) -> np.ndarray:
    """Full-width per-row hit counts, host (N, H) int32 — the kernel-path
    equivalent of ``ref.row_hits_np`` (bit-identical, parity-tested)."""
    N, H, W = np.asarray(images).shape
    rows, _, _ = tile_profiles(
        images, thresh=thresh, max_value=max_value, tile=tile, interpret=interpret
    )
    flat = jnp.sum(rows, axis=2, dtype=jnp.int32).reshape(N, -1)
    return np.asarray(flat[:, :H])
