"""Pure-numpy oracle for the text-band detector kernel.

Semantics (tile-local by construction, so kernel and oracle agree exactly,
bit for bit — everything below is integer arithmetic after one float32
compare):

* **binarize** — a pixel is a *glyph hit* when ``float32(x) >= float32(t)``.
  The threshold ``t`` is dtype-aware (``phi_detect.ops.full_scale`` /
  ``stored_max_value`` times a fraction): burned-in glyph strokes sit at the
  top of the stored sample range, anatomy tops out well below it.
* **projection profiles** — per (th, tw) tile, the row profile counts hits in
  each tile row and the column profile counts hits in each tile column.
  Full-image row profiles are exact tile-column sums, which is what makes the
  reduction embarrassingly tileable.
* **run-lengths** — per tile, the maximum horizontal run of consecutive hits
  (runs do not span tile boundaries, mirroring ``phi_detect``'s tile-local
  gradient convention). Text is a fence of short dense runs; a saturated
  anatomy patch would produce one tile-wide run, so the statistic separates
  the two and rides into the :class:`~repro.detect.report.DetectionReport`.

The numbers here are the detector's ground truth: the Pallas kernel is
parity-tested against this module with exact integer equality.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

Profiles = Tuple[np.ndarray, np.ndarray, np.ndarray]  # rows, cols, runs


def binarize_np(images: np.ndarray, thresh: float) -> np.ndarray:
    """(N, H, W) -> (N, H, W) int32 glyph-hit mask. The one float compare of
    the whole detector: both sides are cast to float32 first so numpy and the
    kernel see identical values for every integer dtype."""
    return (images.astype(np.float32) >= np.float32(thresh)).astype(np.int32)


def tile_profiles_ref(
    images: np.ndarray, thresh: float, tile: Tuple[int, int]
) -> Profiles:
    """images: (N, H, W), tile-aligned. Returns

    * rows: (N, H/th, W/tw, th) int32 — per-tile row projection profile;
    * cols: (N, H/th, W/tw, tw) int32 — per-tile column projection profile;
    * runs: (N, H/th, W/tw) int32 — per-tile max horizontal hit run.
    """
    N, H, W = images.shape
    th, tw = tile
    assert H % th == 0 and W % tw == 0, (images.shape, tile)
    b = binarize_np(images, thresh).reshape(N, H // th, th, W // tw, tw)
    rows = np.ascontiguousarray(b.sum(axis=4, dtype=np.int32).transpose(0, 1, 3, 2))
    cols = b.sum(axis=2, dtype=np.int32)
    # max-run scan, identical recurrence to the kernel's fori_loop:
    # run_j = (run_{j-1} + b_j) * b_j
    run = np.zeros((N, H // th, th, W // tw), np.int32)
    best = np.zeros_like(run)
    for j in range(tw):
        run = (run + b[..., j]) * b[..., j]
        best = np.maximum(best, run)
    runs = best.max(axis=2).astype(np.int32)
    return rows, cols, runs


def pad_to_tiles_np(images: np.ndarray, tile: Tuple[int, int]) -> np.ndarray:
    """Zero-pad (N, H, W) up to tile multiples. Padding pixels are zero and
    can never binarize to a hit, so profiles over real rows are unaffected."""
    N, H, W = images.shape
    th, tw = tile
    Hp, Wp = -(-H // th) * th, -(-W // tw) * tw
    if (Hp, Wp) == (H, W):
        return images
    return np.pad(images, ((0, 0), (0, Hp - H), (0, Wp - W)))


def row_hits_np(
    images: np.ndarray, thresh: float, tile: Tuple[int, int] = (32, 128)
) -> np.ndarray:
    """Full-width per-row hit counts, (N, H) int32 — the band extractor's
    input and the hot host path (every CPU detector scan, the sim's PHI
    audit, catalog ingest). A full-width row sum IS the sum of per-tile row
    profiles across tile columns (padding binarizes to zero), so this skips
    the tiled reduction — and the run-length scan whose output it would
    discard — while staying bit-identical to the kernel-path wrapper
    (``ops.row_hit_profile``, parity-tested)."""
    assert images.ndim == 3, images.shape
    return binarize_np(images, thresh).sum(axis=2, dtype=np.int32)


def max_run_np(
    images: np.ndarray, thresh: float, tile: Tuple[int, int] = (32, 128)
) -> np.ndarray:
    """(N,) int32 — max tile-local horizontal run per image (report metric)."""
    padded = pad_to_tiles_np(images, tile)
    _, _, runs = tile_profiles_ref(padded, thresh, tile)
    return runs.max(axis=(1, 2)).astype(np.int32)
