"""Pallas TPU kernel: tile-wise text-band statistics for burned-in PHI.

The detector's device half (DESIGN.md §9). Each program owns one (th, tw)
VMEM tile of one image and reduces it to three small statistics:

* the tile's **row projection profile** (th int32 counts),
* the tile's **column projection profile** (tw int32 counts),
* the tile's **max horizontal run** of consecutive glyph hits (1 int32).

Like ``phi_detect`` this is a pure streaming reduction — each pixel is read
exactly once and the outputs are O(H/th * W/tw * (th + tw + 1)) int32s — so
it runs at HBM bandwidth. Binarization happens in-register (one float32
compare against the dtype-aware threshold), the profiles are lane/sublane
sums, and the run-length scan is a static ``fori_loop`` over the tile width
carrying a (th,) run vector. All post-compare arithmetic is int32, which is
what makes the kernel bit-identical to the numpy oracle in ``ref.py`` rather
than merely allclose.

Band extraction (grouping hot rows into rectangles) is host logic in
``repro.detect.regions`` — it consumes these profiles, so kernel and oracle
paths produce identical rectangles by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _textdetect_kernel(img_ref, rows_ref, cols_ref, runs_ref, *, thresh: float, th: int, tw: int):
    tile = img_ref[0].astype(jnp.float32)                     # (th, tw)
    b = (tile >= jnp.float32(thresh)).astype(jnp.int32)       # glyph hits
    rows_ref[0, 0, 0] = jnp.sum(b, axis=1)
    cols_ref[0, 0, 0] = jnp.sum(b, axis=0)

    def scan(j, carry):
        run, best = carry
        col = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        run = (run + col) * col                               # resets on a gap
        return run, jnp.maximum(best, run)

    zero = jnp.zeros((th,), jnp.int32)
    _, best = jax.lax.fori_loop(0, tw, scan, (zero, zero))
    runs_ref[0, 0, 0] = jnp.max(best)


def textdetect_pallas(
    images: jnp.ndarray,
    *,
    thresh: float,
    tile: tuple[int, int] = (32, 128),
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """images: (N, H, W), tile-aligned. Returns

    (rows (N, H/th, W/tw, th), cols (N, H/th, W/tw, tw), runs (N, H/th, W/tw)),
    all int32 — bit-identical to ``ref.tile_profiles_ref``.
    """
    N, H, W = images.shape
    th, tw = tile
    assert H % th == 0 and W % tw == 0, (images.shape, tile)
    Ht, Wt = H // th, W // tw
    grid = (N, Ht, Wt)
    kernel = functools.partial(_textdetect_kernel, thresh=thresh, th=th, tw=tw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, th, tw), lambda n, i, j: (n, i, j))],
        out_specs=[
            pl.BlockSpec((1, 1, 1, th), lambda n, i, j: (n, i, j, 0)),
            pl.BlockSpec((1, 1, 1, tw), lambda n, i, j: (n, i, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda n, i, j: (n, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Ht, Wt, th), jnp.int32),
            jax.ShapeDtypeStruct((N, Ht, Wt, tw), jnp.int32),
            jax.ShapeDtypeStruct((N, Ht, Wt), jnp.int32),
        ],
        interpret=interpret,
    )(images)
