# Content-addressed de-identification result lake (DESIGN.md §6): ruleset-
# versioned cache keys, LRU-bounded result store, and the cohort planner with
# single-flight request coalescing.
#
# NOTE: planner must be imported last — it pulls in repro.core.pipeline and
# repro.queueing, whose modules import repro.lake.fingerprint/records back.
from repro.lake.fingerprint import (
    RulesetFingerprint,
    cache_key,
    geometry_digest,
    instance_digest,
    request_salt,
    study_key,
)
from repro.lake.records import (
    decode_instance_record,
    decode_study_record,
    encode_instance_record,
    encode_study_record,
)
from repro.lake.store import InMemoryBackend, LakeBackend, LakeStats, ResultLake
from repro.lake.planner import CohortPlanner, CohortTicket, PlannerStats

__all__ = [
    "RulesetFingerprint",
    "cache_key",
    "geometry_digest",
    "instance_digest",
    "request_salt",
    "study_key",
    "encode_instance_record",
    "decode_instance_record",
    "encode_study_record",
    "decode_study_record",
    "ResultLake",
    "LakeBackend",
    "InMemoryBackend",
    "LakeStats",
    "CohortPlanner",
    "CohortTicket",
    "PlannerStats",
]
