"""Deterministic cache keys for the de-identified result lake (DESIGN.md §6).

A cached de-id result is only reusable when three things are unchanged:

* the **instance content** — any pixel or metadata edit must recompute;
* the **ruleset** — filter/anonymizer/scrubber scripts *and* the device
  registry's scrub geometry (the scrub script is generated from the registry,
  but the filter's ultrasound whitelist builtin also consults the registry
  directly, so geometry is fingerprinted on its own);
* the **project pseudonym salt** — the same instance de-identified for two
  research studies yields different pseudonyms/UIDs by design, so results are
  never shared across projects.

The cache key is a digest over exactly those three, which makes invalidation
structural: editing one scrub rule changes the ruleset fingerprint and
thereby invalidates *every* entry minted under it, and nothing else.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Iterable, Optional

from repro.dicom.devices import DeviceRegistry, FIXED_DEVICES, registry

if TYPE_CHECKING:  # pragma: no cover - type-only, avoids a core<->lake cycle
    from repro.core.pipeline import DeidRequest
    from repro.dicom.dataset import DicomDataset


def _sha(*parts: str) -> str:
    return hashlib.sha256("|".join(parts).encode()).hexdigest()


def callable_identity(fn) -> str:
    """Stable, behavior-sensitive identity for a pipeline callable (e.g. the
    scrub stage's ``blank_fn``). Name alone is not enough — two same-named
    lambdas with different bodies must not share cache keys — so the bytecode
    and constants are folded in when available; ``functools.partial`` recurses
    on the wrapped function (its ``repr`` embeds a memory address, which would
    never hit across processes)."""
    import functools

    if isinstance(fn, functools.partial):
        return (
            f"partial({callable_identity(fn.func)},args={fn.args!r},"
            f"kw={sorted((fn.keywords or {}).items())!r})"
        )
    ident = f"{getattr(fn, '__module__', '?')}.{getattr(fn, '__qualname__', type(fn).__name__)}"
    code = getattr(fn, "__code__", None)
    if code is not None:
        body = hashlib.sha256(code.co_code + repr(code.co_consts).encode()).hexdigest()
        ident += f"#{body[:12]}"
    return ident


def geometry_digest(reg: Optional[DeviceRegistry] = None) -> str:
    """Digest of the device registry's scrub geometry and US whitelist.

    Any change to a device's blanking rectangles — or to whitelist
    membership, which the filter stage consults — must invalidate cached
    results computed under the old geometry.
    """
    reg = reg or registry()
    lines = []
    for key in sorted(reg.all_us_variants(), key=lambda k: k.id()):
        lines.append(f"{key.id()}:{reg.scrub_rects(key)}")
    for key in FIXED_DEVICES:
        lines.append(f"{key.id()}:{reg.scrub_rects(key)}")
    return _sha(*lines)


@dataclass(frozen=True)
class RulesetFingerprint:
    """Versioned identity of the full rule surface a result was computed under.

    ``config_sha`` digests the pipeline settings that shape delivered bytes
    beyond the scripts themselves (recompress, codec selection value, blank
    function) — two pipelines differing only in those must not share keys.
    ``detector_sha`` digests the burned-in pixel-PHI detector surface
    (detector version + :class:`repro.detect.DetectorPolicy` knobs): a
    policy edit or a new detector changes which pixels get blanked, so
    results minted under the old behavior must never be served warm. The
    empty string is the no-detector (pre-§9) identity.
    """

    filter_sha: str
    anonymizer_sha: str
    scrubber_sha: str
    geometry_sha: str
    config_sha: str = ""
    detector_sha: str = ""

    @property
    def digest(self) -> str:
        return _sha(
            "ruleset",
            self.filter_sha,
            self.anonymizer_sha,
            self.scrubber_sha,
            self.geometry_sha,
            self.config_sha,
            self.detector_sha,
        )

    @classmethod
    def of(
        cls,
        script_shas: Dict[str, str],
        reg: Optional[DeviceRegistry] = None,
        config: str = "",
        detector: str = "",
    ) -> "RulesetFingerprint":
        """Build from a pipeline's ``script_shas`` + the live device registry."""
        return cls(
            filter_sha=script_shas["filter"],
            anonymizer_sha=script_shas["anonymizer"],
            scrubber_sha=script_shas["scrubber"],
            geometry_sha=geometry_digest(reg),
            config_sha=_sha("config", config),
            detector_sha=_sha("detector", detector) if detector else "",
        )


def instance_digest(ds: "DicomDataset") -> str:
    """Content digest of one SOP instance: metadata, private tags, pixels,
    and encapsulated payload. Canonicalized (sorted keys) so element insertion
    order does not leak into the key."""
    h = hashlib.sha256()
    h.update(
        json.dumps(
            {k: str(v) for k, v in ds.elements.items()}, sort_keys=True
        ).encode()
    )
    h.update(
        json.dumps({k: str(v) for k, v in ds.private.items()}, sort_keys=True).encode()
    )
    if ds.pixels is not None:
        h.update(str((ds.pixels.dtype.name, ds.pixels.shape)).encode())
        h.update(ds.pixels.tobytes())
    if ds.encapsulated is not None:
        h.update(ds.encapsulated)
    return h.hexdigest()


def request_salt(request: "DeidRequest") -> str:
    """Project pseudonym salt: digests everything the anonymizer consumes from
    the request (anon accession/MRN, jitter, uid salt) plus the research study
    and trust mode. Deterministic per (research study, accession), different
    across research studies — cached results never cross project boundaries."""
    params = request.script_params()
    return _sha(
        "salt",
        request.research_study,
        request.mode,
        *(f"{k}={params[k]}" for k in sorted(params)),
    )


def cache_key(inst_digest: str, ruleset_digest: str, salt: str) -> str:
    """Content-addressed key for one instance's de-id result."""
    return _sha("inst", inst_digest, ruleset_digest, salt)


def study_key(accession: str, source_etag: str, ruleset_digest: str, salt: str) -> str:
    """Key for a study-level completion record. ``source_etag`` is the data
    lake's content etag for the identified study, so the planner can test
    warmth without reading (or hashing) a single pixel."""
    return _sha("study", accession, source_etag, ruleset_digest, salt)
