"""Cohort request planner: warm/in-flight/cold partitioning + single-flight
coalescing (DESIGN.md §6).

Researchers request overlapping cohorts (lists of accessions). The planner is
the admission layer in front of the broker that makes repeat traffic cheap:

* **warm** — a study-level record exists in the result lake and every
  instance record it references is still resident: the results are served
  straight from the lake. Zero broker publishes, zero kernel dispatches.
* **in-flight** — another cohort already published this accession and a
  worker is (or will be) computing it: the new request *subscribes* to the
  existing computation instead of publishing duplicate work (single-flight).
* **cold** — genuinely new work: published to the broker, registered as
  in-flight so later requesters coalesce onto it.

Single-flight composes with the journal's exactly-once dedup rather than
replacing it: the planner stops duplicate *publishes* at admission; the
journal still stops duplicate *completions* (crash redelivery, speculative
clones) behind the broker. A journal-done accession whose lake entries were
evicted is still reported warm — its outputs were already delivered — with
the manifest replayed from the journal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import DELIVERY, PROVENANCE
from repro.core.manifest import Manifest
from repro.core.pipeline import DeidRequest, build_request
from repro.core.pseudonym import PseudonymService
from repro.dicom.dataset import DicomDataset
from repro.lake.fingerprint import request_salt, study_key
from repro.lake.records import decode_instance_record, decode_study_record
from repro.lake.store import ResultLake
from repro.obs.metrics import StatsShim
from repro.obs.trace import NULL_TRACER
from repro.queueing.broker import Broker
from repro.queueing.journal import Journal
from repro.storage.object_store import StudyStore
from repro.utils.logging import get_logger

log = get_logger("lake.planner")


class PlannerStats(StatsShim):
    """Planner admission counters as real metrics (``repro_planner_*``).

    The conservation identities the sim audits:
    ``accessions == lake_hits + journal_hits + coalesced + published + rejected``
    and ``published == resolved + dead_lettered + len(inflight)``.
    """

    _SUBSYSTEM = "planner"
    _FIELDS = (
        "accessions",
        "lake_hits",        # served entirely from the result lake
        "journal_hits",     # already completed; outputs delivered previously
        "coalesced",        # subscribed to an in-flight computation
        "published",        # cold: emitted to the broker
        "rejected",
        "resolved",         # in-flight completions handed to subscribers
        "demoted",          # study record found but instance blobs evicted
        "dead_lettered",    # in-flight work that exhausted its deliveries
        "stale_refreshes",  # journal-done keys republished: source mutated
    )


@dataclass
class CohortTicket:
    """One cohort request's view of its accessions.

    ``manifests``/``outputs`` are filled immediately for warm accessions and
    at :meth:`CohortPlanner.resolve` time for coalesced/cold ones (outputs
    only while the lake still holds them; cold outputs are always also
    delivered to the researcher bucket by the worker)."""

    cohort_id: int
    study_id: str
    # digest of (catalog snapshot, query) when this cohort came from
    # DeidService.submit_query — joins the warm-replay identity: the same
    # selection digest is guaranteed to name the same cohort, so a replayed
    # query is attributable to the exact catalog state that answered it
    selection_digest: str = ""
    hits: List[str] = field(default_factory=list)
    coalesced: List[str] = field(default_factory=list)
    cold: List[str] = field(default_factory=list)
    rejected: Dict[str, str] = field(default_factory=dict)
    failed: Dict[str, str] = field(default_factory=dict)  # e.g. dead-lettered
    manifests: Dict[str, Manifest] = field(default_factory=dict)
    outputs: Dict[str, List[DicomDataset]] = field(default_factory=dict)
    pending: Set[str] = field(default_factory=set)

    def done(self) -> bool:
        return not self.pending


@dataclass
class _InFlight:
    accession: str
    request: DeidRequest
    tickets: List[CohortTicket] = field(default_factory=list)
    published_at: float = 0.0  # broker publish_time of THIS registration


class CohortPlanner:
    def __init__(
        self,
        result_lake: ResultLake,
        source: StudyStore,
        broker: Broker,
        journal: Journal,
        validate: Optional[Callable[[str], Tuple[bool, str]]] = None,
        ruleset_digest: str = "",
        tracer=None,
        registry=None,
        ledger=None,
    ) -> None:
        self.result_lake = result_lake
        self.source = source
        self.broker = broker
        self.journal = journal
        self.validate = validate
        # must match the digest of the pipeline serving the worker pool —
        # DeidService wires both sides from the same DeidPipeline
        self.ruleset_digest = ruleset_digest
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        self.stats = PlannerStats(registry)
        self._inflight: Dict[str, _InFlight] = {}
        self._cohorts = 0

    # ------------------------------------------------------------- admission
    def submit(
        self,
        pseudo: PseudonymService,
        accessions: List[str],
        mrn_lookup: Dict[str, str],
        selection_digest: str = "",
    ) -> CohortTicket:
        """Partition one cohort request and publish only the cold slice.
        Callers are expected to pass deduplicated accessions
        (``DeidService`` does); a duplicate here would coalesce the second
        occurrence onto the first rather than double-publish, but would still
        double-count admission stats."""
        # opportunistically clear finished in-flight work first, so a key
        # completed since the last resolve() is served warm rather than
        # coalesced onto a registration nobody will ever resolve
        self.resolve()
        self._cohorts += 1
        ticket = CohortTicket(
            cohort_id=self._cohorts,
            study_id=pseudo.study_id,
            selection_digest=selection_digest,
        )
        with self.tracer.span(
            "planner.partition", cohort_id=ticket.cohort_id, n=len(accessions)
        ) as _part_span:
            self._partition(pseudo, accessions, mrn_lookup, ticket)
            _part_span.set(
                warm=len(ticket.hits),
                coalesced=len(ticket.coalesced),
                cold=len(ticket.cold),
                rejected=len(ticket.rejected),
            )
        return ticket

    def _partition(
        self,
        pseudo: PseudonymService,
        accessions: List[str],
        mrn_lookup: Dict[str, str],
        ticket: CohortTicket,
    ) -> None:
        with self.ledger.batch():  # one fsync per cohort admission
            self._partition_batched(pseudo, accessions, mrn_lookup, ticket)

    def _partition_batched(
        self,
        pseudo: PseudonymService,
        accessions: List[str],
        mrn_lookup: Dict[str, str],
        ticket: CohortTicket,
    ) -> None:
        for acc in accessions:
            self.stats.accessions += 1
            if self.validate is not None:
                ok, reason = self.validate(acc)
                if not ok:
                    ticket.rejected[acc] = reason
                    self.stats.rejected += 1
                    continue
            key = f"{pseudo.study_id}/{acc}"
            entry = self._inflight.get(key)
            if entry is not None:  # single-flight: subscribe, don't republish
                entry.tickets.append(ticket)
                ticket.coalesced.append(acc)
                ticket.pending.add(acc)
                self.stats.coalesced += 1
                continue
            request = build_request(pseudo, acc, mrn_lookup[acc])
            warm = self._materialize(acc, request)
            if warm is not None:
                ticket.hits.append(acc)
                ticket.outputs[acc], ticket.manifests[acc] = warm
                self.stats.lake_hits += 1
                self._record_hit(key, acc, request, temp="warm", instances=len(warm[0]))
                continue
            done = self.journal.manifest_for(key)
            if done is not None and not self._journal_stale(key, acc):
                # completed before, lake since evicted: outputs already sit in
                # the researcher bucket; replay the manifest only
                ticket.hits.append(acc)
                ticket.manifests[acc] = done
                self.stats.journal_hits += 1
                self._record_hit(key, acc, request, temp="journal", instances=0)
                continue
            if done is not None:
                # journal-done but the source mutated since: the recorded
                # manifest describes pre-mutation bytes. Freshness fencing:
                # never replay it — republish so only the changed content is
                # re-de-identified (the worker supersedes the journal entry)
                self.stats.stale_refreshes += 1
            ticket.cold.append(acc)
            ticket.pending.add(acc)
            self._register_and_publish(key, acc, request, [ticket])

    def admit(self, pseudo: PseudonymService, accession: str, request: DeidRequest) -> bool:
        """Single-flight admission for non-cohort submits (`DeidService.submit`).
        Returns False when the key is already in flight — the caller must not
        publish a duplicate; otherwise publishes and registers it so later
        cohorts coalesce onto this work. No ticket: plain submits track
        completion through the journal, not through subscriptions."""
        key = f"{pseudo.study_id}/{accession}"
        if key in self._inflight:
            self.stats.coalesced += 1
            return False
        self._register_and_publish(key, accession, request, [])
        return True

    def _register_and_publish(
        self, key: str, accession: str, request: DeidRequest, tickets: List[CohortTicket]
    ) -> None:
        # metadata-only admission: stored size is the backlog estimate;
        # only the worker ever reads (and pays egress for) the study
        self.broker.publish(
            key=key,
            payload={"accession": accession, "request": request.__dict__},
            nbytes=self.source.study_nbytes(accession) or 0,
        )
        self._inflight[key] = _InFlight(
            accession, request, tickets, published_at=self.broker.clock.now()
        )
        self.stats.published += 1

    # ------------------------------------------------------------ completion
    def resolve(self) -> List[str]:
        """Hand completed in-flight accessions to every subscribed ticket.
        Call after (or during) a pool drain; returns the resolved keys.

        In-flight work whose message exhausted its delivery budget (DLQ) is
        *failed out*: subscribers are unblocked with an error instead of
        waiting forever, and the registration is dropped so a later cohort
        can republish once the fault clears."""
        # match DLQ entries to *this* registration via publish_time: the DLQ
        # list is cumulative, and a key dead-lettered once must not poison a
        # later republish of the same accession (redeliveries and speculative
        # clones keep the original publish_time, so they still match)
        dead = {(m.key, m.publish_time) for m in self.broker.dead_letter}
        resolved: List[str] = []
        for key, entry in list(self._inflight.items()):
            if not self.journal.is_done(key):
                # fail out only when no live copy remains: a speculative clone
                # may dead-letter while the original delivery still completes
                if (key, entry.published_at) in dead and not self.broker.has_live(key):
                    for ticket in entry.tickets:
                        ticket.pending.discard(entry.accession)
                        ticket.failed[entry.accession] = (
                            "dead-lettered after max deliveries"
                        )
                    del self._inflight[key]
                    self.stats.dead_lettered += 1
                    self.tracer.event("planner.failout", key=key)
                continue
            warm = self._materialize(entry.accession, entry.request)
            manifest = warm[1] if warm is not None else self.journal.manifest_for(key)
            for ticket in entry.tickets:
                ticket.pending.discard(entry.accession)
                if manifest is not None:
                    ticket.manifests[entry.accession] = manifest
                if warm is not None:
                    ticket.outputs[entry.accession] = warm[0]
            del self._inflight[key]
            self.stats.resolved += 1
            resolved.append(key)
        if resolved:
            # emit only when work actually resolved: resolve() runs on every
            # sim step, and an unconditional event would swamp the trace
            self.tracer.event("planner.resolve", n=len(resolved))
        return resolved

    def inflight_keys(self) -> List[str]:
        return list(self._inflight)

    def audit_wedged(self) -> List[str]:
        """Registrations whose subscribers can never be resolved: no live
        broker copy remains, the journal never saw a completion, and the DLQ
        holds no entry :meth:`resolve` could fail them out with. A non-empty
        result means tickets would wait forever — the invariant the fleet
        simulator's conformance suite checks after every run (call
        :meth:`resolve` first so resolvable work doesn't show up here)."""
        dead = {(m.key, m.publish_time) for m in self.broker.dead_letter}
        wedged = []
        for key, entry in self._inflight.items():
            if self.journal.is_done(key) or self.broker.has_live(key):
                continue
            if (key, entry.published_at) in dead:
                continue  # resolve() will fail this one out to its tickets
            wedged.append(key)
        return wedged

    # ------------------------------------------------------------- internals
    def _record_hit(
        self, key: str, accession: str, request: DeidRequest, temp: str, instances: int
    ) -> None:
        """Delivery + provenance records for a warm/journal-hit admission.
        Warm hits disclose lake bytes (each underlying read already emitted a
        ``lake_hit`` record); journal hits replay only the manifest. The etag
        recorded is the *current* source etag — the freshness check that
        admitted the hit proved it matches the completed version."""
        etag = self.source.study_etag(accession)
        skey = (
            study_key(accession, etag, self.ruleset_digest, request_salt(request))
            if temp == "warm" and etag is not None else ""
        )
        self.ledger.append(
            DELIVERY, key=key, accession=accession, etag=etag, temp=temp, worker="planner"
        )
        self.ledger.append(
            PROVENANCE,
            key=key,
            project=request.research_study,
            accession=accession,
            lake_key=skey,
            etag=etag,
            ruleset=self.ruleset_digest,
            detector_sha="",
            kernel_path="lake" if temp == "warm" else "journal",
            batched=0,
            trace_id="",
            temp=temp,
            instances=instances,
            nbytes=0,
        )

    def _journal_stale(self, key: str, accession: str) -> bool:
        """True when the journal's completion for ``key`` was computed from a
        source version that has since mutated (etag drift). Legacy records
        without an etag are treated as fresh — staleness must be proven."""
        done_etag = self.journal.etag_for(key)
        current = self.source.study_etag(accession)
        return done_etag is not None and current is not None and done_etag != current

    def _materialize(
        self, accession: str, request: DeidRequest
    ) -> Optional[Tuple[List[DicomDataset], Manifest]]:
        """Reassemble a study's outputs purely from the lake, or None when any
        piece is missing (no study record, or instance blobs evicted)."""
        etag = self.source.study_etag(accession)
        if etag is None:
            return None
        skey = study_key(accession, etag, self.ruleset_digest, request_salt(request))
        blob = self.result_lake.get(skey)
        if blob is None:
            return None
        instance_keys = decode_study_record(blob)
        if not all(self.result_lake.contains(k) for k in instance_keys):
            # partially evicted: drop the stale study record and recompute
            self.result_lake.delete(skey)
            self.stats.demoted += 1
            return None
        manifest = Manifest(
            request_id=f"{request.research_study}/{request.anon_accession}"
        )
        outputs: List[DicomDataset] = []
        for k in instance_keys:
            rec = self.result_lake.get(k)
            if rec is None:  # raced an eviction between contains() and get()
                self.stats.demoted += 1
                return None
            dataset, entry = decode_instance_record(rec)
            manifest.add(entry)
            if dataset is not None:
                outputs.append(dataset)
        return outputs, manifest
