"""Wire formats for lake entries.

Two record kinds live in the lake:

* **instance records** — one per SOP instance: the delivered (de-identified)
  dataset, or ``None`` when the instance was filtered/failed, plus its
  :class:`~repro.core.manifest.ManifestEntry`. A warm replay decodes exactly
  what the cold path produced, so outputs are byte-identical by construction.
* **study records** — one per (study, ruleset, project): the ordered list of
  instance cache keys making up a completed study. The planner uses these to
  answer "is this accession fully warm?" without touching pixel data.

Pickle is the container (matching ``storage.object_store.StudyStore``); the
lake only ever sees the resulting bytes.
"""
from __future__ import annotations

import pickle
from typing import List, Optional, Tuple

from repro.core.manifest import ManifestEntry
from repro.dicom.dataset import DicomDataset

_INSTANCE_RECORD_V = 1
_STUDY_RECORD_V = 1


def encode_instance_record(
    dataset: Optional[DicomDataset], entry: ManifestEntry
) -> bytes:
    return pickle.dumps(
        ("inst", _INSTANCE_RECORD_V, dataset, entry.to_dict()),
        protocol=pickle.HIGHEST_PROTOCOL,
    )


def decode_instance_record(blob: bytes) -> Tuple[Optional[DicomDataset], ManifestEntry]:
    kind, version, dataset, entry_dict = pickle.loads(blob)
    if kind != "inst" or version != _INSTANCE_RECORD_V:
        raise ValueError(f"not an instance record: {kind!r} v{version}")
    return dataset, ManifestEntry.from_dict(entry_dict)


def encode_study_record(instance_keys: List[str]) -> bytes:
    return pickle.dumps(
        ("study", _STUDY_RECORD_V, list(instance_keys)),
        protocol=pickle.HIGHEST_PROTOCOL,
    )


def decode_study_record(blob: bytes) -> List[str]:
    kind, version, keys = pickle.loads(blob)
    if kind != "study" or version != _STUDY_RECORD_V:
        raise ValueError(f"not a study record: {kind!r} v{version}")
    return keys
