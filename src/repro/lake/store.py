"""Content-addressed de-identified result store with LRU bounds (DESIGN.md §6).

The lake is the layer that turns "fast per study" into "fast under repeated
multi-user traffic": workers write finished per-instance results here, and the
cohort planner / cache-aware pipeline read them back instead of recomputing.

The store itself is deliberately dumb: opaque bytes in, opaque bytes out,
keyed by the content-addressed keys minted in ``repro.lake.fingerprint``. The
``LakeBackend`` interface is persistence-shaped (put/get/delete/size of raw
bytes) so a cloud bucket or disk tier can replace ``InMemoryBackend`` without
touching eviction or metrics, which live in :class:`ResultLake`.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import LAKE_EVICT, LAKE_HIT, LAKE_WRITE
from repro.obs.metrics import MetricsRegistry, StatsShim


class LakeBackend:
    """Minimal persistence interface: opaque bytes keyed by string."""

    def put_bytes(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get_bytes(self, key: str) -> Optional[bytes]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def nbytes(self, key: str) -> int:
        raise NotImplementedError


class InMemoryBackend(LakeBackend):
    def __init__(self) -> None:
        self._blobs: Dict[str, bytes] = {}

    def put_bytes(self, key: str, data: bytes) -> None:
        self._blobs[key] = data

    def get_bytes(self, key: str) -> Optional[bytes]:
        return self._blobs.get(key)

    def delete(self, key: str) -> None:
        self._blobs.pop(key, None)

    def nbytes(self, key: str) -> int:
        b = self._blobs.get(key)
        return 0 if b is None else len(b)


class LakeStats(StatsShim):
    """Lake counters; attribute surface unchanged, values are real metrics
    (``repro_lake_*``) aggregated by whichever registry owns them."""

    _SUBSYSTEM = "lake"
    _FIELDS = (
        "hits",
        "misses",
        "puts",
        "evictions",
        "bytes_in",       # bytes written into the lake
        "bytes_out",      # bytes served from the lake
        "evicted_bytes",
        "oversize_rejects",  # single blobs larger than the whole budget
    )

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ResultLake:
    """Size-bounded LRU cache over a :class:`LakeBackend`.

    ``max_bytes`` bounds the *stored payload* bytes; eviction is
    least-recently-used where both reads and writes refresh recency. The LRU
    index is kept here (not in the backend) so a persistent backend can stay a
    plain key/value store.
    """

    def __init__(
        self,
        max_bytes: int = 256 * 1024 * 1024,
        backend: Optional[LakeBackend] = None,
        registry: Optional[MetricsRegistry] = None,
        ledger=None,
    ) -> None:
        self.max_bytes = max_bytes
        self.backend = backend or InMemoryBackend()
        self.stats = LakeStats(registry)
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        self._lru: "OrderedDict[str, int]" = OrderedDict()  # key -> nbytes
        self._stored_bytes = 0

    # ----------------------------------------------------------------- reads
    def get(self, key: str) -> Optional[bytes]:
        if key not in self._lru:
            self.stats.misses += 1
            return None
        data = self.backend.get_bytes(key)
        if data is None:  # backend lost the blob (e.g. external pruning)
            self._drop(key, reason="lost")
            self.stats.misses += 1
            return None
        self._lru.move_to_end(key)
        self.stats.hits += 1
        self.stats.bytes_out += len(data)
        # every byte served out of the lake is a disclosure: account for it
        self.ledger.append(LAKE_HIT, lake_key=key, nbytes=len(data))
        return data

    def contains(self, key: str) -> bool:
        """Presence probe: no hit/miss accounting, no recency refresh."""
        return key in self._lru

    # ---------------------------------------------------------------- writes
    def put(self, key: str, data: bytes) -> bool:
        """Store a result; returns False when the blob alone exceeds the
        budget (storing it would immediately evict everything else)."""
        if len(data) > self.max_bytes:
            self.stats.oversize_rejects += 1
            return False
        if key in self._lru:
            self._stored_bytes -= self._lru[key]
        self.backend.put_bytes(key, data)
        self._lru[key] = len(data)
        self._lru.move_to_end(key)
        self._stored_bytes += len(data)
        self.stats.puts += 1
        self.stats.bytes_in += len(data)
        self.ledger.append(LAKE_WRITE, lake_key=key, nbytes=len(data))
        while self._stored_bytes > self.max_bytes:
            self._evict_one()
        return True

    def delete(self, key: str) -> None:
        self._drop(key, reason="invalidate")

    # -------------------------------------------------------------- internals
    def _drop(self, key: str, reason: str = "invalidate") -> None:
        if key in self._lru:
            nbytes = self._lru.pop(key)
            self._stored_bytes -= nbytes
            self.backend.delete(key)
            self.ledger.append(LAKE_EVICT, lake_key=key, nbytes=nbytes, reason=reason)

    def _evict_one(self) -> None:
        key, nbytes = self._lru.popitem(last=False)
        self._stored_bytes -= nbytes
        self.backend.delete(key)
        self.stats.evictions += 1
        self.stats.evicted_bytes += nbytes
        self.ledger.append(LAKE_EVICT, lake_key=key, nbytes=nbytes, reason="lru")

    # ------------------------------------------------------------------ misc
    def stored_bytes(self) -> int:
        return self._stored_bytes

    def keys(self) -> List[str]:
        return list(self._lru)

    def __len__(self) -> int:
        return len(self._lru)
