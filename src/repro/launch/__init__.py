# Launch layer: production meshes, sharding rules, the multi-pod dry-run,
# and the train/serve/deid-service entry points.
