"""Activation-sharding constraints threaded into model code.

Model code calls ``constrain(x, "residual")`` at block boundaries; outside a
mesh context this is a no-op, under the launch/dry-run it pins the residual
stream to the Megatron-SP layout (sequence sharded over 'model' between
blocks) — the difference between 86 GB and 5 GB of saved scan carries on the
80-layer train cells (DESIGN.md §5, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax

_RULES: contextvars.ContextVar[Optional[Dict[str, object]]] = contextvars.ContextVar(
    "act_sharding_rules", default=None
)


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, object]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x: jax.Array, name: str) -> jax.Array:
    rules = _RULES.get()
    if not rules or name not in rules or rules[name] is None:
        return x
    sharding = rules[name]
    spec = getattr(sharding, "spec", None)
    if spec is not None and len(spec) != getattr(x, "ndim", len(spec)):
        # rank mismatch (e.g. decode-path rank-2 activations vs the rank-3
        # train/prefill rule): constraints are layout hints, skip quietly
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
