"""De-identification service launcher: the paper's operational loop as a CLI.

    PYTHONPATH=src python -m repro.launch.deid_service --studies 30 --window-min 30

Stands up the full control plane (lake -> server -> broker -> autoscaled pool
-> researcher bucket) against the synthetic archive and drains one request,
printing the Table-1-style report. The heavy lifting is shared with
examples/deid_at_scale.py; this entry point exists so operators get the same
``python -m`` surface as train/serve/dryrun.
"""
from __future__ import annotations

import argparse

from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.kernels.scrub import ops as scrub_ops
from repro.obs import (
    HealthController,
    SloEngine,
    SloSpec,
    Tracer,
    default_burn_rules,
    derive_serve_observations,
)
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, FailureInjector, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.bytesize import human_bytes
from repro.utils.timing import SimClock


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", type=int, default=30)
    ap.add_argument("--images-per-study", type=int, default=3)
    ap.add_argument("--window-min", type=float, default=30.0)
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--journal", default="/tmp/deid-service-journal.jsonl")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    gen = StudyGenerator(args.seed)
    lake = StudyStore("lake", key=b"at-rest-key")
    mrns = {}
    for i in range(args.studies):
        s = gen.gen_study(f"SRV{i:05d}", n_images=args.images_per_study)
        lake.put_study(s.accession, s)
        mrns[s.accession] = s.mrn

    clock = SimClock()
    tracer = Tracer(clock)
    broker = Broker(clock, visibility_timeout=120, tracer=tracer)
    journal = Journal(args.journal)
    service = DeidService(broker, lake, journal, tracer=tracer)
    service.register_study("IRB-SRV", TrustMode.POST_IRB)
    service.submit("IRB-SRV", list(mrns), mrns)

    dest = StudyStore("researcher")
    pipeline = DeidPipeline(blank_fn=scrub_ops.blank_fn)
    injector = FailureInjector(crash_rate=0.05, straggler_rate=0.05) if args.chaos else None
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(delivery_window=args.window_min * 60), clock),
        lambda wid: DeidWorker(wid, pipeline, lake, dest, journal, tracer=tracer),
        injector,
    )
    report = pool.drain()
    manifest = journal.merged_manifest("IRB-SRV")
    total = lake.store.total_bytes()

    # SLO/health surface (DESIGN.md §13): the launcher has no per-delivery
    # hook, so cold-serve latencies are re-derived from the span stream —
    # the same reconstruction the fleet sim's SloConformance cross-checks —
    # then evaluated once at drain time.
    engine = SloEngine([SloSpec(
        "cold_serve", objective=0.9, threshold=args.window_min * 60,
        kind="latency", rules=default_burn_rules(1.0 / 60.0),
    )])
    for t, _key, latency in derive_serve_observations(tracer.spans()):
        engine.observe("cold_serve", t=t, value=latency)
    engine.evaluate(clock.now())
    service.attach_health(HealthController(engine))
    health = service.health_report()

    out = {
        "studies": report.processed,
        "instances": manifest.counts(),
        "bytes": total,
        "minutes": clock.now() / 60,
        "throughput": total / max(clock.now(), 1e-9),
        "cost_usd": report.cost_usd,
        "crashes": report.crashes,
        "health": health.to_dict(),
    }
    print(
        f"{report.processed} studies | {human_bytes(total)} | {out['minutes']:.1f} min "
        f"| {human_bytes(out['throughput'])}/s | ${out['cost_usd']:.2f} | counts {out['instances']}"
    )
    print(f"health: {health.summary()}")
    return out


if __name__ == "__main__":
    main()
