import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (jax locks device count on first init).
#   This override lives ONLY here: tests/benches see the 1 real device.

_DOC = """Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build abstract inputs
(ShapeDtypeStruct, no allocation), jit with explicit shardings,
``.lower().compile()``, and record memory_analysis / cost_analysis /
collective-bytes (parsed from the partitioned HLO) into a JSON the roofline
harness (benchmarks/roofline.py) consumes.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, both meshes
"""


import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config.model import SHAPES, ShapeConfig, cell_runnable
from repro.config.registry import get_arch, list_archs
from repro.launch import hw
from repro.launch.act_sharding import activation_sharding
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch.shardings import (
    activation_rules,
    cache_shardings,
    input_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.models.model import build_model
from repro.models.spec import param_count, tree_abstract
from repro.training.optimizer import AdamWState
from repro.training.train_step import TrainState, make_train_step
from repro.training import cosine_schedule

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"




def _abstract_train_state(model) -> TrainState:
    params = tree_abstract(model.param_specs())
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        master=jax.tree.map(f32, params),
    )
    return TrainState(params=params, opt=opt, comp=None)


def _compile_variant(cfg, shape, multi_pod: bool, microbatches: int = 1):
    """Lower + compile one (cfg, shape, mesh) variant. Returns (compiled, timings)."""
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    in_specs = model.input_specs(shape)
    t0 = time.time()
    with mesh:
        with activation_sharding(activation_rules(mesh, shape, cfg)):
            if shape.kind == "train":
                state_abs = _abstract_train_state(model)
                state_sh = opt_state_shardings(model, mesh, state_abs)
                batch_sh = input_shardings(model, mesh, shape, in_specs)
                step_fn = make_train_step(model, cosine_schedule(3e-4, 100, 10000), microbatches=microbatches)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                )
                lowered = jitted.lower(state_abs, in_specs)
            elif shape.kind == "prefill":
                p_sh = param_shardings(model, mesh)
                batch_sh = input_shardings(model, mesh, shape, in_specs)
                if cfg.family == "encoder":
                    fn = lambda p, b: model.prefill(p, b)[0]
                    out_sh = None
                else:
                    fn = model.prefill
                    out_sh = (None, cache_shardings(model, mesh, shape))
                jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh), out_shardings=out_sh)
                lowered = jitted.lower(tree_abstract(model.param_specs()), in_specs)
            else:  # decode
                p_sh = param_shardings(model, mesh)
                sh = input_shardings(model, mesh, shape, in_specs)
                c_sh = sh["cache"]
                jitted = jax.jit(
                    model.decode_step,
                    in_shardings=(p_sh, sh["tokens"], c_sh, sh["pos"]),
                    out_shardings=(None, c_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(
                    tree_abstract(model.param_specs()),
                    in_specs["tokens"],
                    in_specs["cache"],
                    in_specs["pos"],
                )
            lower_s = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            compile_s = round(time.time() - t1, 1)
    return compiled, {"lower_s": lower_s, "compile_s": compile_s}




def lower_cell(arch: str, shape_name: str, multi_pod: bool, *, overrides: dict | None = None):
    """Lower + compile one cell; returns the result record (no allocation).

    One compile per cell: memory_analysis is exact on the full-depth program
    (scan carries, caches and params are materialized buffers), and the
    while-aware static analyzer (launch/hlo_analysis.py) reconstructs
    flops / HBM bytes / collective bytes with scan trip counts applied —
    XLA's own cost_analysis counts scan bodies once (kept as raw_cost)."""
    cfg = get_arch(arch)
    microbatches = 1
    if overrides:
        overrides = dict(overrides)
        microbatches = int(overrides.pop("microbatches", 1))
        cfg = type(cfg)(**{**cfg.__dict__, **overrides})
    shape = SHAPES[shape_name]
    ok, reason = cell_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh_name = "multi" if multi_pod else "single"
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": 512 if multi_pod else 256,
        "params": cfg.param_count() and param_count(build_model(cfg).param_specs()),
        "active_params": cfg.active_param_count(),
        "overrides": overrides or {},
    }

    record["microbatches"] = microbatches
    # --- one full-depth compile: memory truth + static while-aware cost
    compiled, timings = _compile_variant(cfg, shape, multi_pod, microbatches)
    record.update(timings)
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                record[attr] = int(v)
        record["peak_bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0) + getattr(mem, "temp_size_in_bytes", 0)
        )
    hlo = compiled.as_text()
    record["hlo_lines"] = hlo.count("\n")
    cost = compiled.cost_analysis() or {}
    record["raw_cost"] = {  # xla's scan-body-once numbers, kept for reference
        "flops": float(cost.get("flops", 0)),
        "bytes": float(cost.get("bytes accessed", 0)),
    }
    from repro.launch.hlo_analysis import analyze_hlo

    static = analyze_hlo(hlo)
    record["collectives"] = {k: float(v) for k, v in static["coll"].items()}
    record["hlo_flops"] = static["flops"]
    record["hlo_bytes"] = static["bytes"]

    flops, bts = static["flops"], static["bytes"]
    intra, cross = static["coll_intra"], static["coll_cross"]
    record["roofline"] = {
        "compute_s": flops / hw.PEAK_FLOPS_BF16 if flops > 0 else None,
        "memory_s": bts / hw.HBM_BW if bts > 0 else None,
        "collective_s": intra / hw.ICI_BW + cross / hw.DCI_BW,
        "collective_bytes_intra": intra,
        "collective_bytes_cross_pod": cross,
    }
    record["status"] = "ok"
    return record


def run_cell_subprocess(arch: str, shape: str, mesh: str, out_dir: Path, timeout: int = 3000) -> dict:
    """Isolation wrapper: one cell per process (fresh XLA, bounded blast radius)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{arch}__{shape}__{mesh}.json"
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", str(out_file),
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        if proc.returncode == 0 and out_file.exists():
            return json.loads(out_file.read_text())
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "failed",
               "error": proc.stderr[-2000:]}
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "timeout"}
    out_file.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true", help="run every cell x both meshes via subprocesses")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", action="append", default=[], help="cfg override k=v (perf iterations)")
    args = ap.parse_args()

    if args.all:
        results = []
        for arch in list_archs():
            for shape in SHAPES:
                for mesh in ("single", "multi"):
                    out_file = OUT_DIR / f"{arch}__{shape}__{mesh}.json"
                    if out_file.exists():
                        rec = json.loads(out_file.read_text())
                        if rec.get("status") in ("ok", "skipped"):
                            results.append(rec)
                            continue
                    rec = run_cell_subprocess(arch, shape, mesh, OUT_DIR)
                    results.append(rec)
                    print(f"{arch:18s} {shape:12s} {mesh:6s} -> {rec['status']}", flush=True)
        bad = [r for r in results if r["status"] not in ("ok", "skipped")]
        print(f"\n{len(results)} cells: {sum(r['status']=='ok' for r in results)} ok, "
              f"{sum(r['status']=='skipped' for r in results)} skipped, {len(bad)} failed")
        sys.exit(1 if bad else 0)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    try:
        rec = lower_cell(args.arch, args.shape, args.mesh == "multi", overrides=overrides or None)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "failed", "error": traceback.format_exc()[-4000:]}
    text = json.dumps(rec, indent=1)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(text)
    print(text)
    if rec["status"] == "ok":
        print(f"\n# memory_analysis: peak/device = {rec.get('peak_bytes_per_device', 0)/1e9:.2f} GB "
              f"(args {rec.get('argument_size_in_bytes', 0)/1e9:.2f} + temps {rec.get('temp_size_in_bytes', 0)/1e9:.2f})")
        print(f"# cost_analysis: flops/device = {rec.get('hlo_flops', 0):.3e}, bytes = {rec.get('hlo_bytes', 0):.3e}")
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
