"""Static, while-loop-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts a ``lax.scan``/``while`` body ONCE
regardless of trip count, which silently drops O(layers x attention-chunks)
of the real cost on scan-over-layers programs. This analyzer parses the
partitioned HLO text into computations, builds per-computation symbol tables
(operand types are not inline in modern HLO), extracts every while loop's
trip count from its condition constants, and aggregates bottom-up:

  * flops       — 2 x |out| x |contraction| for dot/convolution ops;
  * hbm bytes   — output + operand tensor bytes of compute ops (fusions count
                  their boundary tensors — the fused-kernel traffic model;
                  control flow, tuples and parameters are skipped);
  * collectives — operand bytes per kind + cross-pod attribution;

each multiplied by the product of enclosing while trip counts. Validated
against 6ND model FLOPs in tests/test_dryrun.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^\s*([a-z][\w\-]*)\(")


def _opname(rhs: str) -> Optional[str]:
    """Op name after the result type. The type is either 'dtype[dims]{layout}'
    or a (possibly /*indexed*/-commented, nested) tuple '(...)'."""
    s = rhs.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    s = s[i + 1 :]
                    break
        else:
            return None
    else:
        m = re.match(r"^[a-z0-9]+\[[0-9,]*\](\{[^}]*\})?", s)
        if m:
            s = s[m.end() :]
    m = _OPNAME_RE.match(s)
    return m.group(1) if m else None
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_BYTE_SKIP = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "while",
    "conditional", "after-all", "partition-id", "replica-id", "iota", "call",
    "broadcast", "reshape", "transpose",  # layout ops usually fuse away
    # dtype converts: native on the TPU target (bf16 MXU inputs) / fused into
    # neighbors — the CPU backend materializes them, which is backend noise
    "convert",
}


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",")] if s else []


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    symbols: Dict[str, Tuple[str, str]] = field(default_factory=dict)  # name -> (dtype, dims)


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{$", s)
            if m:
                cur = Computation(m.group(1))
            continue
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(s)
        dm = _DEF_RE.match(s)
        if dm:
            first_shape = _SHAPE_RE.search(dm.group(2))
            if first_shape and not dm.group(2).lstrip().startswith("("):
                cur.symbols[dm.group(1)] = (first_shape.group(1), first_shape.group(2))
    return comps


def _operand_names(rhs: str, opname: str) -> List[str]:
    try:
        inner = rhs.split(f"{opname}(", 1)[1]
    except IndexError:
        return []
    depth, out, cur = 1, [], []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    return re.findall(r"%([\w\.\-]+)", args)


def _group_spans_pods(line: str) -> bool:
    gm = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if gm:
        ids = [int(x) for x in gm.group(1).split(",")]
        return min(ids) < 256 <= max(ids)
    gi = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", line)
    if gi:
        G, N = int(gi.group(1)), int(gi.group(2))
        dims = [int(x) for x in gi.group(3).split(",")]
        total = int(np.prod(dims))
        if total <= 256:
            return False
        arr = np.arange(total).reshape(dims)
        if gi.group(4):
            arr = arr.transpose([int(x) for x in gi.group(4).split(",")])
        groups = arr.reshape(G, N)
        return bool(((groups.min(1) < 256) & (groups.max(1) >= 256)).any())
    return False


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_cross: float = 0.0
    whiles: List[Tuple[str, str]] = field(default_factory=list)   # (body, cond)
    fusion_calls: List[str] = field(default_factory=list)
    plain_calls: List[str] = field(default_factory=list)


def _fusion_root_op(callee: Optional["Computation"]) -> Optional[str]:
    if callee is None:
        return None
    for line in reversed(callee.lines):
        if line.startswith("ROOT "):
            dm = _DEF_RE.match(line)
            if dm:
                return _opname(dm.group(2))
    return None


def analyze_computation(comp: Computation, all_comps: Optional[Dict[str, "Computation"]] = None) -> CompCost:
    cost = CompCost()
    sym = comp.symbols
    for line in comp.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rhs = dm.group(2)
        opname = _opname(rhs)
        if opname is None:
            continue

        if opname == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            if bm and cm:
                cost.whiles.append((bm.group(1), cm.group(1)))
            continue
        if opname in ("call", "conditional"):
            for m in re.finditer(r"(?:to_apply|branch_computations=\{|calls=\{?)%?([\w\.\-]+)", line):
                cost.plain_calls.append(m.group(1))
            continue

        kind = next((k for k in _COLL_KINDS if opname.startswith(k)), None)
        if kind:
            b = 0
            for op in _operand_names(rhs, opname):
                if op in sym:
                    b += _nbytes(*sym[op])
            if b == 0:
                fs = _SHAPE_RE.search(rhs)
                b = _nbytes(fs.group(1), fs.group(2)) if fs else 0
            cost.coll[kind] = cost.coll.get(kind, 0) + b
            if _group_spans_pods(line):
                cost.coll_cross += b
            continue

        if opname in ("dot", "convolution"):
            out_m = _SHAPE_RE.search(rhs)
            out_elems = 1
            for d in _dims(out_m.group(2)) if out_m else []:
                out_elems *= d
            ops = _operand_names(rhs, opname)
            contract = 1
            if opname == "dot":
                cm2 = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                if cm2 and ops and ops[0] in sym:
                    lhs_dims = _dims(sym[ops[0]][1])
                    for idx in _dims(cm2.group(1)):
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
            else:  # convolution: contraction ~ kernel elems / out features
                if len(ops) > 1 and ops[1] in sym:
                    kd = _dims(sym[ops[1]][1])
                    contract = int(np.prod(kd[:-1])) if kd else 1
            cost.flops += 2.0 * out_elems * contract

        fusion_root = None
        if opname == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", line)
            if fm:
                cost.fusion_calls.append(fm.group(1))
                if all_comps is not None:
                    fusion_root = _fusion_root_op(all_comps.get(fm.group(1)))

        if opname not in _BYTE_SKIP:
            b = 0
            out_m = _SHAPE_RE.search(rhs)
            out_b = _nbytes(out_m.group(1), out_m.group(2)) if out_m else 0
            if fusion_root == "dynamic-update-slice":
                # in-place cache writeback wrapped in a fusion: traffic is the
                # updated slice, not the whole (layers-stacked) buffer — the
                # slice is the smallest non-buffer operand
                ops = _operand_names(rhs, opname)
                sizes = sorted(
                    _nbytes(*sym[o]) for o in ops if o in sym and _nbytes(*sym[o]) < out_b
                )
                b = 2 * (sizes[0] if sizes else out_b)
                cost.bytes += b
                continue
            if opname == "dynamic-slice":
                # reads only the slice (= the output), not the whole operand
                b = 2 * out_b
            elif opname == "dynamic-update-slice":
                # in-place on the donated buffer: traffic = the update slice
                ops = _operand_names(rhs, opname)
                upd = _nbytes(*sym[ops[1]]) if len(ops) > 1 and ops[1] in sym else 0
                b = 2 * upd
            else:
                b = out_b
                for op in _operand_names(rhs, opname):
                    if op in sym:
                        b += _nbytes(*sym[op])
            cost.bytes += b
    return cost


def _trip_count(cond: Computation) -> int:
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def analyze_hlo(hlo: str, entry_hint: str = "main") -> Dict[str, object]:
    comps = split_computations(hlo)
    costs = {name: analyze_computation(c, comps) for name, c in comps.items()}

    entry = next((n for n in comps if n.startswith(entry_hint)), None)
    if entry is None and comps:
        entry = max(comps, key=lambda n: len(comps[n].lines))

    memo: Dict[str, Dict[str, object]] = {}

    def total(name: str, depth: int = 0) -> Dict[str, object]:
        if name in memo:
            return memo[name]
        zero = {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_cross": 0.0}
        if name not in costs or depth > 60:
            return zero
        c = costs[name]
        agg = {"flops": c.flops, "bytes": c.bytes, "coll": dict(c.coll), "coll_cross": c.coll_cross}

        def absorb(sub: Dict[str, object], mult: float, with_bytes: bool) -> None:
            agg["flops"] += mult * sub["flops"]
            if with_bytes:
                agg["bytes"] += mult * sub["bytes"]
            agg["coll_cross"] += mult * sub["coll_cross"]
            for k, v in sub["coll"].items():
                agg["coll"][k] = agg["coll"].get(k, 0) + mult * v

        for body, cond in c.whiles:
            trip = _trip_count(comps[cond]) if cond in comps else 1
            absorb(total(body, depth + 1), trip, with_bytes=True)
        for callee in c.plain_calls:
            absorb(total(callee, depth + 1), 1, with_bytes=True)
        for callee in c.fusion_calls:
            # fusion boundary bytes were counted at the call site; inner ops
            # contribute flops/collectives only
            absorb(total(callee, depth + 1), 1, with_bytes=False)
        memo[name] = agg
        return agg

    out = dict(total(entry)) if entry else {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_cross": 0.0}
    out["coll_total"] = float(sum(out["coll"].values()))
    out["coll_intra"] = out["coll_total"] - out["coll_cross"]
    out["entry"] = entry
    out["n_computations"] = len(comps)
    return out


def top_collectives(hlo: str, n: int = 12, entry_hint: str = "main") -> List[Tuple[float, str, str, int, int]]:
    """Largest collective contributors with trip multipliers applied:
    [(total_bytes, kind, shape, per_op_bytes, trip_multiplier), ...].
    The §Perf hypothesis loop reads this to find what to kill first."""
    comps = split_computations(hlo)
    entry = next((c for c in comps if c.startswith(entry_hint)), None)
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c].lines))

    # trip multiplier per computation = product of enclosing while trips
    mult: Dict[str, int] = {entry: 1}
    frontier = [entry]
    seen = set()
    while frontier:
        name = frontier.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        c = analyze_computation(comps[name])
        m = mult.get(name, 1)
        for body, cond in c.whiles:
            trip = _trip_count(comps[cond]) if cond in comps else 1
            mult[body] = max(mult.get(body, 0), m * trip)
            frontier.append(body)
        for callee in c.plain_calls + c.fusion_calls:
            mult[callee] = max(mult.get(callee, 0), m)
            frontier.append(callee)

    rows: Dict[Tuple[str, str], List[float]] = {}
    for name, m in mult.items():
        comp = comps.get(name)
        if comp is None:
            continue
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            op = _opname(rhs)
            kind = next((k for k in _COLL_KINDS if op and op.startswith(k)), None)
            if not kind:
                continue
            b = 0
            for o in _operand_names(rhs, op):
                if o in comp.symbols:
                    b += _nbytes(*comp.symbols[o])
            fs = _SHAPE_RE.search(rhs)
            shape = f"{fs.group(1)}[{fs.group(2)}]" if fs else "?"
            if b == 0 and fs:
                b = _nbytes(fs.group(1), fs.group(2))
            key = (kind, shape)
            cur = rows.setdefault(key, [0.0, 0, 0])
            cur[0] += b * m
            cur[1] = b
            cur[2] = max(cur[2], m)
    out = [(v[0], k[0], k[1], int(v[1]), int(v[2])) for k, v in rows.items()]
    return sorted(out, reverse=True)[:n]
