"""TPU v5e hardware constants for the roofline model (deliverable g)."""

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (intra-pod)
DCI_BW = 25e9              # bytes/s per chip across pods (data-center interconnect)
HBM_PER_CHIP = 16e9        # v5e HBM capacity
