"""Production meshes (deliverable e).

A function, not a module-level constant, so importing this module never
touches jax device state (required: tests/benches must keep seeing exactly
one real device; only dryrun.py forces 512 host devices).

Topology (TPU v5e target):
  single-pod: (data=16, model=16)       = 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16) = 512 chips; 'pod' is pure DP and
  rides the slower inter-pod DCI, so keeping it a separate axis makes XLA
  schedule cross-pod all-reduces separately and lets the roofline attribute
  their bytes (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names — lets sharding-rule code
    paths run in unit tests without the 512-device override."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "multi_pod": "pod" in mesh.axis_names,
    }
