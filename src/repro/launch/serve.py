"""Serving launcher: batched LM inference on a reduced config.

``python -m repro.launch.serve --arch qwen2-0.5b --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config.registry import get_arch, list_archs
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_batch=args.max_batch)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12))).tolist()
        engine.submit(Request(f"req-{i}", prompt, max_new_tokens=args.max_new,
                              temperature=args.temperature))

    t0 = time.time()
    results = engine.run(jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        log.info("%s: prompt %d tokens -> %s...", r.request_id, r.prompt_len, r.tokens[:8])
    log.info("%d requests, %d tokens in %.2fs (%.1f tok/s)",
             len(results), total_tokens, dt, total_tokens / max(dt, 1e-9))
    return {"requests": len(results), "tokens": total_tokens, "seconds": dt}


if __name__ == "__main__":
    main()
