"""Sharding rules: logical axis names -> mesh axes, per (mesh, shape-kind).

Layout (DESIGN.md §5):
  * params: TP over 'model' (heads / mlp / experts / vocab), layers stacked
    dim replicated. Divisibility-aware: when a dim doesn't divide the axis
    GSPMD pads (uneven sharding) — used deliberately for e.g. llava's 56
    heads on tp=16 — except tiny dims (< axis size) which replicate.
  * optimizer states: ZeRO-1 — m/v/master additionally shard their largest
    replicated dim over ('pod','data').
  * activations: batch over ('pod','data'); residual stream sequence-sharded
    over 'model' between blocks (Megatron-SP, see act_sharding).
  * decode caches: batch over ('pod','data') (long_500k: cache sequence over
    ('pod','data') instead, batch=1), kv heads over 'model'.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.model import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.models.spec import SpecTree, TensorSpec


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def logical_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Optional[object]]:
    """logical param-axis name -> mesh axis (or None)."""
    tp = mesh.shape["model"]
    rules: Dict[str, Optional[object]] = {
        "layers": None,
        "sublayers": None,
        # hubert's 504-cluster head doesn't divide tp=16 -> replicate (tiny)
        "vocab": "model" if cfg.vocab_size % tp == 0 else None,
        "embed": None,
        "heads": "model",
        # param tensors carry kv flattened as KV*hd (always tp-divisible here)
        "kv": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
    }
    if cfg.family == "moe":
        if cfg.n_experts % tp == 0:
            rules["experts"] = "model"   # expert parallelism (olmoe: 64/16)
            rules["mlp"] = None
        else:
            rules["experts"] = None      # few big experts (mixtral: 8 on 16)
            rules["mlp"] = "model"       # -> TP inside each expert
    else:
        rules["mlp"] = "model"
    return rules


def spec_to_pspec(spec: TensorSpec, rules: Dict[str, Optional[object]]) -> P:
    return P(*[rules.get(a) if a is not None else None for a in spec.axes])


_FSDP_CANDIDATES = ("embed", "mlp", "ssm_inner", "heads", "kv", "vocab")
_FSDP_MIN_ELEMS = 1 << 20  # don't bother sharding small tensors


def fsdp_pspec(spec: TensorSpec, rules: Dict[str, Optional[object]], mesh: Mesh) -> P:
    """TP pspec + FSDP: the first large still-replicated logical dim of a big
    tensor is sharded over 'data'. Weights live fully sharded (ZeRO-3-style);
    GSPMD all-gathers each scanned layer's slice on use — which overlaps with
    the previous layer's compute (MaxText's v5e recipe; see DESIGN.md §5)."""
    base = [rules.get(a) if a is not None else None for a in spec.axes]
    n_elems = 1
    for d in spec.shape:
        n_elems *= d
    if n_elems >= _FSDP_MIN_ELEMS:
        dp = mesh.shape["data"]
        for i, (a, assigned) in enumerate(zip(spec.axes, base)):
            if assigned is None and a in _FSDP_CANDIDATES and spec.shape[i] % dp == 0:
                base[i] = "data"
                break
    return P(*base)


def param_shardings(model: Model, mesh: Mesh, *, fsdp: bool = True) -> Any:
    rules = logical_rules(model.cfg, mesh)
    to_pspec = (lambda s: fsdp_pspec(s, rules, mesh)) if fsdp else (lambda s: spec_to_pspec(s, rules))
    return jax.tree.map(
        lambda s: NamedSharding(mesh, to_pspec(s)),
        model.param_specs(),
        is_leaf=lambda x: isinstance(x, TensorSpec),
    )


# ------------------------------------------------------- optimizer states
def opt_state_shardings(model: Model, mesh: Mesh, state_abstract, *, fsdp: bool = True) -> Any:
    """Shardings for a TrainState. With FSDP on, params AND all f32 optimizer
    states are fully sharded over (model, data) — ZeRO-3-equivalent storage:
    the m/v/master update is pointwise over identically-sharded trees, so the
    optimizer step needs no gathers at all."""
    from repro.training.train_step import TrainState  # local: avoid cycle

    p_shard = param_shardings(model, mesh, fsdp=fsdp)
    scalar = NamedSharding(mesh, P())
    opt = type(state_abstract.opt)(step=scalar, m=p_shard, v=p_shard, master=p_shard)
    comp = None
    if state_abstract.comp is not None:
        from repro.distributed.compression import CompressionState

        comp = jax.tree.map(
            lambda sh: CompressionState(sh), p_shard,
            is_leaf=lambda x: isinstance(x, NamedSharding),
        )
    return TrainState(params=p_shard, opt=opt, comp=comp)


# ------------------------------------------------------------- activations
def activation_rules(mesh: Mesh, shape: ShapeConfig, cfg: Optional[ModelConfig] = None) -> Dict[str, object]:
    """Interior activation layouts (Megatron-SP style):
      residual    — sequence sharded over 'model' between blocks;
      attn_q      — heads sharded, sequence gathered (TP inside attention);
      attn_kv     — kv heads replicated, sequence gathered;
      inner       — d_ff / d_inner sharded, sequence gathered (TP inside FFN/SSM);
      logits      — vocab sharded CE chunks;
      moe_in/hidden — expert-parallel or expert-internal TP per cfg.
    """
    b = _batch_axes(mesh)
    if shape.name == "long_500k":
        # batch=1: parallelism comes from sequence sharding
        rules = {"residual": NamedSharding(mesh, P(None, b, "model"))}
    else:
        rules = {"residual": NamedSharding(mesh, P(b, "model", None))}
    rules["attn_q"] = NamedSharding(mesh, P(b, None, "model", None))
    rules["attn_kv"] = NamedSharding(mesh, P(b, None, None, None))
    rules["inner"] = NamedSharding(mesh, P(b, None, "model"))
    rules["logits"] = NamedSharding(mesh, P(b, None, "model"))
    if cfg is not None and cfg.n_kv_heads:
        # decode query/output (B, KV, G, hd): mirror the KV-cache TP layout
        kv_div = cfg.n_kv_heads % mesh.shape["model"] == 0
        bd = b if shape.global_batch > 1 else None
        rules["decode_q"] = NamedSharding(
            mesh, P(bd, "model", None, None) if kv_div else P(bd, None, None, "model")
        )
    if cfg is not None and cfg.family == "moe":
        # row-local dispatch buffers are (B, E, C, d/f): batch stays on the
        # data axes, experts or expert-interior on 'model'
        if cfg.n_experts % mesh.shape["model"] == 0:
            rules["moe_in"] = NamedSharding(mesh, P(b, "model", None, None))
            rules["moe_hidden"] = NamedSharding(mesh, P(b, "model", None, None))
        else:
            rules["moe_in"] = NamedSharding(mesh, P(b, None, None, None))
            rules["moe_hidden"] = NamedSharding(mesh, P(b, None, None, "model"))
    return rules


def input_shardings(model: Model, mesh: Mesh, shape: ShapeConfig, specs: Dict[str, Any]) -> Dict[str, Any]:
    """NamedShardings matching the structure of model.input_specs(shape)."""
    cfg = model.cfg
    b = _batch_axes(mesh)
    batch_first = P(b)
    out: Dict[str, Any] = {}
    for name, v in specs.items():
        if name == "cache":
            out[name] = cache_shardings(model, mesh, shape)
        elif name == "pos":
            out[name] = NamedSharding(mesh, P())
        elif isinstance(v, jax.ShapeDtypeStruct):
            if shape.name == "long_500k" and v.ndim >= 1 and v.shape[0] == 1:
                out[name] = NamedSharding(mesh, P(*([None] * v.ndim)))
            else:
                out[name] = NamedSharding(mesh, P(*([b] + [None] * (v.ndim - 1))))
        else:
            raise TypeError(name)
    return out


def cache_shardings(model: Model, mesh: Mesh, shape: ShapeConfig) -> Any:
    cfg = model.cfg
    b = _batch_axes(mesh)
    tp = mesh.shape["model"]
    # KV cache TP dim: kv heads when divisible, else head_dim (contraction
    # dim — partial attention scores psum'd by GSPMD); both divide tp for
    # every assigned arch
    kv_divisible = cfg.n_kv_heads and cfg.n_kv_heads % tp == 0
    act_rules = {
        "layers": None,
        "sublayers": None,
        "act_batch": b if shape.global_batch > 1 else None,
        "cache_seq": b if shape.global_batch == 1 else None,  # long_500k: shard S
        "kv": "model" if kv_divisible else None,
        "hd": None if kv_divisible else "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": None,
    }
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, act_rules)),
        model.cache_specs(shape.global_batch, shape.seq_len),
        is_leaf=lambda x: isinstance(x, TensorSpec),
    )
