"""Training launcher: ``python -m repro.launch.train --arch qwen2-0.5b --steps 100``.

On this CPU container it trains the *reduced* config by default (the full
configs are exercised via the dry-run); pass --full on real hardware. Wires
together: config registry -> model -> data pipeline -> train step ->
checkpoint manager, with resume-from-latest and periodic saves — the same
loop a real multi-pod job runs under the production mesh.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config.registry import get_arch, list_archs
from repro.models import build_model
from repro.training import (
    CheckpointManager,
    SyntheticTokenPipeline,
    cosine_schedule,
    make_train_step,
    train_state_init,
)
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", action="store_true", help="int8 grad compression + error feedback")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config — real hardware only")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    state = train_state_init(model, jax.random.PRNGKey(args.seed), compression=args.compression)
    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state, start_step, _ = mgr.restore(state)
        log.info("resumed from step %d", start_step)

    pipe = SyntheticTokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    sched = cosine_schedule(args.lr, args.warmup, args.steps)
    step_fn = jax.jit(
        make_train_step(model, sched, microbatches=args.microbatches, compression=args.compression),
        donate_argnums=(0,),
    )

    t0 = time.time()
    tokens_per_step = args.batch * args.seq
    metrics = {}
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(step))
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            log.info(
                "step %4d loss %.4f gnorm %.3f lr %.2e (%.1f tok/s)",
                step, float(metrics["loss"]), float(metrics["gnorm"]),
                float(metrics["lr"]), tokens_per_step * (step - start_step + 1) / (time.time() - t0),
            )
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extra={"arch": cfg.name})
    mgr.save(args.steps, state, extra={"arch": cfg.name})
    return {"final_loss": float(metrics["loss"]), "steps": args.steps}


if __name__ == "__main__":
    main()
