"""GQA attention: KV-chunked (flash-style) train/prefill + cached decode.

TPU adaptation notes (DESIGN.md §3, §Perf):

* **Chunked online-softmax attention** in pure JAX: the O(S^2) logits tensor
  is never materialized. The query dim is unrolled over static chunks and the
  key dim is scanned, so for causal masks the loop is *triangular* — fully
  masked (q, k) tiles are never emitted, and HLO FLOPs match the ~S^2/2
  useful work (this is the property a Pallas flash kernel would give; the
  scan formulation gets it portably and lets XLA pipeline the chunk matmuls).
* **Grouped GQA einsums** (§Perf iteration 1): Q is reshaped to
  (B, S, KV, G, hd) and contracted directly against (B, S, KV, hd) K/V —
  K/V are never repeated to n_heads. The naive ``jnp.repeat`` formulation
  materialized G x the KV tensors every layer (measured 8x = 2.3 TB/step on
  qwen1.5-110b decode_32k; see EXPERIMENTS.md §Perf).
* **Score/probability precision** (§Perf iteration 2): scores and the
  softmax statistics stay f32; the post-exp probabilities are stored in
  ``p_dtype`` (bf16 by default) for the PV matmul, halving the dominant
  HBM-traffic term of long-context prefill with <1e-2 output error
  (tests/test_models.py tolerances unchanged).
* **Sliding windows** restrict the scanned k-chunk range statically per
  q-chunk (window bounds are compile-time constants).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd). Oracle/test path only — the compute
    paths below use grouped einsums and never materialize this."""
    B, S, KV, hd = k.shape
    if KV == n_heads:
        return k
    return jnp.repeat(k, n_heads // KV, axis=2)


def _chunk(x: jnp.ndarray, size: int) -> jnp.ndarray:
    """(B, S, ...) -> (S/size, B, size, ...)."""
    B, S = x.shape[:2]
    n = S // size
    return x.reshape((B, n, size) + x.shape[2:]).swapaxes(0, 1)


def chunked_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,  # (B, S, KV, hd)
    *,
    causal: bool,
    window: int = 0,     # 0 = unbounded
    chunk: int = 1024,
    unroll: bool = False,
    p_dtype=jnp.float32,  # model passes bf16 for bf16 configs (cfg.attn_p_bf16)
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    scale = 1.0 / (hd ** 0.5)

    kf = _chunk(k, chunk)  # (n, B, C, KV, hd) — grouped: no repeat to H
    vf = _chunk(v, chunk)
    qf = _chunk(q.reshape(B, S, KV, G, hd), chunk)  # (n, B, C, KV, G, hd)

    # static per-q-chunk k-chunk range
    def k_range(qi: int) -> Tuple[int, int]:
        hi = (qi + 1) if causal else nq
        lo = 0
        if window:
            lo = max(0, (qi * chunk - window) // chunk)
        return lo, hi

    rows = jnp.arange(chunk)

    out_chunks = []
    for qi in range(nq):
        lo, hi = k_range(qi)
        qb = (qf[qi] * scale).astype(q.dtype)  # (B, C, KV, G, hd)
        m = jnp.full((B, chunk, KV, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, chunk, KV, G), jnp.float32)
        acc = jnp.zeros((B, chunk, KV, G, hd), jnp.float32)

        def step(carry, inp, qi=qi):
            m, l, acc = carry
            kb, vb, ki = inp  # kb/vb: (B, Ck, KV, hd)
            s = jnp.einsum("bqkgd,bckd->bqkgc", qb, kb, preferred_element_type=jnp.float32)
            mask = _dynamic_mask(qi, ki, chunk, causal, window, rows)
            if mask is not None:
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]).astype(p_dtype)  # stored compactly
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p.astype(jnp.float32), axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vb.astype(p_dtype), preferred_element_type=jnp.float32
            )
            return (m_new, l, acc), None

        ks = kf[lo:hi]
        vs = vf[lo:hi]
        kis = jnp.arange(lo, hi)
        (m, l, acc), _ = jax.lax.scan(step, (m, l, acc), (ks, vs, kis), unroll=unroll)
        out_chunks.append((acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype))

    out = jnp.stack(out_chunks, axis=1)  # (B, nq, C, KV, G, hd)
    return out.reshape(B, S, H, hd)


def _dynamic_mask(qi, ki_scalar, chunk, causal, window, rows):
    """Mask for tile (qi static, ki dynamic in-scan). Returns None when no
    tile in this q-row needs masking (pure off-diagonal full-attention)."""
    if not causal and not window:
        return None
    qpos = qi * chunk + rows[:, None]
    kpos = ki_scalar * chunk + rows[None, :]
    keep = jnp.ones((chunk, chunk), bool)
    if causal:
        keep &= kpos <= qpos
    if window:
        keep &= kpos > qpos - window
    return keep


def decode_attention(
    q: jnp.ndarray,        # (B, H, hd) — single new token
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,  # (B, S, KV, hd)
    pos: jnp.ndarray,      # scalar int32: index of the new token
    *,
    window: int = 0,
) -> jnp.ndarray:
    from repro.launch.act_sharding import constrain

    B, S, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    # pin the query to the cache's TP layout (kv- or hd-sharded, see
    # launch/shardings.cache_shardings) BEFORE the einsums — without this
    # GSPMD resolves the KVxG head split by replicating the whole stacked
    # cache in f32 every step (§Perf iteration 1b: 84% of decode HBM bytes)
    qg = constrain(q.reshape(B, KV, G, hd), "decode_q")
    scale = 1.0 / (hd ** 0.5)
    # grouped: contract against the cache directly (no repeat materialization)
    s = jnp.einsum("bkgd,bskd->bkgs", qg * scale, k_cache, preferred_element_type=jnp.float32)
    idx = jnp.arange(S)
    keep = idx <= pos
    if window:
        keep &= idx > pos - window
    s = jnp.where(keep[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = constrain(out, "decode_q")
    return out.reshape(B, H, hd).astype(q.dtype)


def update_kv_cache(
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,    # (B, KV, hd)
    v_new: jnp.ndarray,
    pos: jnp.ndarray,      # scalar int32
) -> tuple[jnp.ndarray, jnp.ndarray]:
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new[:, None].astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new[:, None].astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache


def reference_attention(q, k, v, *, causal, window=0):
    """O(S^2) oracle for tests (repeat-based, f32 throughout)."""
    B, S, H, hd = q.shape
    kf = _repeat_kv(k, H)
    vf = _repeat_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q / (hd ** 0.5), kf, preferred_element_type=jnp.float32)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    keep = jnp.ones((S, S), bool)
    if causal:
        keep &= kpos <= qpos
    if window:
        keep &= kpos > qpos - window
    s = jnp.where(keep[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------- legacy A/B
def chunked_attention_repeat(q, k, v, *, causal, window=0, chunk=1024, unroll=False):
    """Naive repeat-based GQA baseline (pre-§Perf-iteration-1): K/V repeated
    to n_heads before the einsums, f32 probabilities. Kept for A/B
    measurement via cfg.attn_grouped=False; numerically identical to the
    grouped path at f32."""
    return _chunked_attention_repeat_impl(
        q, k, v, causal=causal, window=window, chunk=chunk, unroll=unroll
    )


def _chunked_attention_repeat_impl(q, k, v, *, causal, window, chunk, unroll):
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    nq = S // chunk
    scale = 1.0 / (hd ** 0.5)
    kf = _chunk(_repeat_kv(k, H), chunk)
    vf = _chunk(_repeat_kv(v, H), chunk)
    qf = _chunk(q, chunk)
    rows = jnp.arange(chunk)
    out_chunks = []
    for qi in range(nq):
        hi = (qi + 1) if causal else nq
        lo = max(0, (qi * chunk - window) // chunk) if window else 0
        qb = qf[qi] * scale
        m = jnp.full((B, chunk, H), NEG_INF, jnp.float32)
        l = jnp.zeros((B, chunk, H), jnp.float32)
        acc = jnp.zeros((B, chunk, H, hd), jnp.float32)

        def step(carry, inp, qi=qi):
            m, l, acc = carry
            kb, vb, ki = inp
            s = jnp.einsum("bqhd,bkhd->bqhk", qb, kb, preferred_element_type=jnp.float32)
            mask = _dynamic_mask(qi, ki, chunk, causal, window, rows)
            if mask is not None:
                s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(step, (m, l, acc), (kf[lo:hi], vf[lo:hi], jnp.arange(lo, hi)), unroll=unroll)
        out_chunks.append((acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype))
    return jnp.stack(out_chunks, axis=1).reshape(B, S, H, hd)


def decode_attention_repeat(q, k_cache, v_cache, pos, *, window=0):
    """Naive repeat-based decode baseline (pre-§Perf-iteration-1)."""
    B, S, KV, hd = k_cache.shape
    H = q.shape[1]
    kf = _repeat_kv(k_cache, H)
    vf = _repeat_kv(v_cache, H)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhd,bkhd->bhk", q * scale, kf, preferred_element_type=jnp.float32)
    idx = jnp.arange(S)
    keep = idx <= pos
    if window:
        keep &= idx > pos - window
    s = jnp.where(keep[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf.astype(jnp.float32)).astype(q.dtype)
