"""Per-family transformer blocks (params specs + apply fns).

All stacks scan over layers with stacked params so HLO size is O(1) in depth
(compile-time requirement for the 80-layer dry-runs)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.model import ModelConfig
from repro.launch.act_sharding import constrain
from repro.models.attention import (
    chunked_attention,
    chunked_attention_repeat,
    decode_attention,
    decode_attention_repeat,
    update_kv_cache,
)
from repro.models.layers import apply_rope, mlp_apply, mlp_specs, rms_norm, rope_freqs
from repro.models.moe import moe_apply, moe_specs
from repro.models.spec import TensorSpec


# ------------------------------------------------------------- attention core
def attn_specs(cfg: ModelConfig, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": TensorSpec((d, H * hd), ("embed", "heads")),
        "wk": TensorSpec((d, KV * hd), ("embed", "kv")),
        "wv": TensorSpec((d, KV * hd), ("embed", "kv")),
        "wo": TensorSpec((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = TensorSpec((H * hd,), ("heads",), init="zeros")
        s["bk"] = TensorSpec((KV * hd,), ("kv",), init="zeros")
        s["bv"] = TensorSpec((KV * hd,), ("kv",), init="zeros")
    return s


def _qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def attn_apply(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    return_kv: bool = False,
):
    """Full-sequence attention. positions: (S,) int32 absolute positions."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if cfg.rope_theta:
        cos, sin = rope_freqs(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # SP -> TP boundary: gather sequence, shard heads (Megatron-SP layout)
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")
    import jax.numpy as _jnp

    if cfg.attn_grouped:
        p_dtype = _jnp.bfloat16 if (cfg.attn_p_bf16 and cfg.dtype == "bfloat16") else _jnp.float32
        out = chunked_attention(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window,
            chunk=min(cfg.attn_chunk, S), unroll=cfg.scan_unroll, p_dtype=p_dtype,
        )
    else:  # §Perf A/B baseline
        out = chunked_attention_repeat(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window,
            chunk=min(cfg.attn_chunk, S), unroll=cfg.scan_unroll,
        )
    out = out.reshape(B, S, -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attn_decode_apply(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,          # (B, d_in) single token
    k_cache: jnp.ndarray,    # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,        # scalar
):
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _qkv(p, cfg, x[:, None])
    if cfg.rope_theta:
        cos, sin = rope_freqs(pos[None], cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    k_cache, v_cache = update_kv_cache(k_cache, v_cache, k[:, 0], v[:, 0], pos)
    dec = decode_attention if cfg.attn_grouped else decode_attention_repeat
    out = dec(q[:, 0], k_cache, v_cache, pos, window=cfg.sliding_window)
    return out.reshape(B, -1) @ p["wo"], k_cache, v_cache


# ------------------------------------------------------------- dense layers
def dense_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_specs(cfg),
        "ln2": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_specs(cfg),
    }


def dense_layer_apply(lp: dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    x = x + attn_apply(lp["attn"], cfg, rms_norm(x, lp["ln1"], cfg.norm_eps), positions)
    x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x


def dense_layer_prefill(lp, cfg, x, positions):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    att, (k, v) = attn_apply(lp["attn"], cfg, h, positions, return_kv=True)
    x = x + att
    x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x, (k, v)


def dense_layer_decode(lp, cfg, x, k_cache, v_cache, pos):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    att, k_cache, v_cache = attn_decode_apply(lp["attn"], cfg, h, k_cache, v_cache, pos)
    x = x + att
    x = x + mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x, k_cache, v_cache


# --------------------------------------------------------------- moe layers
def moe_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_specs(cfg),
        "ln2": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "moe": moe_specs(cfg),
    }


def moe_layer_apply(lp, cfg, x, positions):
    x = x + attn_apply(lp["attn"], cfg, rms_norm(x, lp["ln1"], cfg.norm_eps), positions)
    ff, aux = moe_apply(lp["moe"], cfg, rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x + ff, aux


def moe_layer_prefill(lp, cfg, x, positions):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    att, (k, v) = attn_apply(lp["attn"], cfg, h, positions, return_kv=True)
    x = x + att
    ff, _ = moe_apply(lp["moe"], cfg, rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x + ff, (k, v)


def moe_layer_decode(lp, cfg, x, k_cache, v_cache, pos):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    att, k_cache, v_cache = attn_decode_apply(lp["attn"], cfg, h, k_cache, v_cache, pos)
    x = x + att
    ff, _ = moe_apply(lp["moe"], cfg, rms_norm(x, lp["ln2"], cfg.norm_eps)[:, None])
    return x + ff[:, 0], k_cache, v_cache


# ------------------------------------------------- zamba2 shared attention
def shared_attn_specs(cfg: ModelConfig) -> dict:
    """One set of weights, applied n_shared_attn() times (zamba trick). Input
    is concat(hidden, initial_embeds) -> 2*d_model."""
    d2 = 2 * cfg.d_model
    attn = attn_specs(cfg, d_in=d2)
    # output projection returns to the residual stream width (d_model)
    attn["wo"] = TensorSpec((cfg.n_heads * cfg.hd, cfg.d_model), ("heads", "embed"))
    return {
        "ln": TensorSpec((d2,), ("embed",), init="ones"),
        "attn": attn,
        "ln2": TensorSpec((d2,), ("embed",), init="ones"),
        "mlp": {
            "gate": TensorSpec((d2, cfg.d_ff), ("embed", "mlp")),
            "up": TensorSpec((d2, cfg.d_ff), ("embed", "mlp")),
            "down": TensorSpec((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
        },
    }


def shared_attn_apply(sp, cfg, x, e0, positions):
    cat = jnp.concatenate([x, e0], axis=-1)
    x = x + attn_apply(sp["attn"], cfg, rms_norm(cat, sp["ln"], cfg.norm_eps), positions)
    cat2 = jnp.concatenate([x, e0], axis=-1)
    h = rms_norm(cat2, sp["ln2"], cfg.norm_eps)
    hh = constrain(jax.nn.silu(h @ sp["mlp"]["gate"]) * (h @ sp["mlp"]["up"]), "inner")
    x = x + hh @ sp["mlp"]["down"]
    return x


def shared_attn_prefill(sp, cfg, x, e0, positions):
    cat = jnp.concatenate([x, e0], axis=-1)
    att, (k, v) = attn_apply(sp["attn"], cfg, rms_norm(cat, sp["ln"], cfg.norm_eps), positions, return_kv=True)
    x = x + att
    cat2 = jnp.concatenate([x, e0], axis=-1)
    h = rms_norm(cat2, sp["ln2"], cfg.norm_eps)
    hh = constrain(jax.nn.silu(h @ sp["mlp"]["gate"]) * (h @ sp["mlp"]["up"]), "inner")
    x = x + hh @ sp["mlp"]["down"]
    return x, (k, v)


def shared_attn_decode(sp, cfg, x, e0, k_cache, v_cache, pos):
    cat = jnp.concatenate([x, e0], axis=-1)
    att, k_cache, v_cache = attn_decode_apply(
        sp["attn"], cfg, rms_norm(cat, sp["ln"], cfg.norm_eps), k_cache, v_cache, pos
    )
    x = x + att
    cat2 = jnp.concatenate([x, e0], axis=-1)
    h = rms_norm(cat2, sp["ln2"], cfg.norm_eps)
    x = x + (jax.nn.silu(h @ sp["mlp"]["gate"]) * (h @ sp["mlp"]["up"])) @ sp["mlp"]["down"]
    return x, k_cache, v_cache
