"""Shared layers: RMSNorm, RoPE, gated MLP, embeddings, chunked CE loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.model import ModelConfig
from repro.launch.act_sharding import constrain
from repro.models.spec import TensorSpec


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_freqs(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim/2) f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :].astype(jnp.float32)
    s = sin[None, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# -------------------------------------------------------------- gated MLP
def mlp_specs(cfg: ModelConfig, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    return {
        "gate": TensorSpec((d, cfg.d_ff), ("embed", "mlp")),
        "up": TensorSpec((d, cfg.d_ff), ("embed", "mlp")),
        "down": TensorSpec((cfg.d_ff, d), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = constrain(h, "inner")  # SP -> TP boundary: d_ff sharded, S gathered
    return h @ p["down"]


# ------------------------------------------------------------- embeddings
def embed_specs(cfg: ModelConfig) -> dict:
    # GPT-2-style 0.02 init; with tied embeddings this also keeps head logits
    # in a sane range at init (scale-1.0 embeddings blow the tied CE up)
    specs = {"tok": TensorSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        specs["head"] = TensorSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return specs


def embed_tokens(p: dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def head_matrix(p: dict, cfg: ModelConfig) -> jnp.ndarray:
    return p["tok"].T if cfg.tie_embeddings else p["head"]


# ------------------------------------------------- chunked cross-entropy
def chunked_ce_loss(
    x: jnp.ndarray,           # (B, S, d) final hidden states
    head: jnp.ndarray,        # (d, V)
    labels: jnp.ndarray,      # (B, S) int32; -1 = ignore
    chunk: int,
    unroll: bool = False,
) -> jnp.ndarray:
    """Sequence-chunked softmax CE: never materializes (B, S, V) logits.

    The (B, C, V) chunk logits stay bf16 with f32 reductions; XLA inserts the
    cross-shard max/sum collectives when V is sharded over 'model'.
    """
    B, S, d = x.shape
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    n_chunks = S // chunk
    xc = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)        # (n, B, C, d)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)      # (n, B, C)

    def body(carry, inp):
        total, count = carry
        xs, ls = inp
        logits = constrain((xs @ head).astype(jnp.float32), "logits")  # (B, C, V)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        gold = jnp.take_along_axis(logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        valid = ls >= 0
        total = total + jnp.sum(jnp.where(valid, lse - gold, 0.0))
        count = count + jnp.sum(valid)
        return (total, count), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc), unroll=unroll)
    return total / jnp.maximum(count, 1.0)
