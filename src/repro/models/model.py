"""Model assembly: specs, losses, prefill and decode steps for every family.

The public surface consumed by training/serving/launch:

    model = build_model(cfg)
    specs  = model.param_specs()          # TensorSpec tree (shapes + logical axes)
    params = model.init(key)              # real weights (smoke tests/examples)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, tokens, cache, pos)

Layer stacks scan over stacked params (HLO O(1) in depth); remat policy per
cfg.remat. Caches are TensorSpec trees too, so the dry-run can fabricate
sharded ShapeDtypeStructs for serve_step without allocating 500k-token KV.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.model import ModelConfig, ShapeConfig
from repro.launch.act_sharding import constrain
from repro.models import blocks, ssm
from repro.models.layers import chunked_ce_loss, embed_specs, embed_tokens, head_matrix, rms_norm
from repro.models.spec import SpecTree, TensorSpec, tree_abstract, tree_init

ACT_DTYPE = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _stack(specs: SpecTree, n: int, axis: str = "layers") -> SpecTree:
    def add(s: TensorSpec) -> TensorSpec:
        return TensorSpec((n,) + s.shape, (axis,) + s.axes, s.dtype, s.init, s.scale)

    return jax.tree.map(add, specs, is_leaf=lambda x: isinstance(x, TensorSpec))


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


class Model:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        self.dtype = ACT_DTYPE[cfg.dtype]

    # ================================================================ specs
    def param_specs(self) -> SpecTree:
        cfg = self.cfg
        specs: SpecTree = {"embed": embed_specs(cfg), "ln_f": TensorSpec((cfg.d_model,), ("embed",), init="ones")}
        if cfg.family in ("dense", "vlm"):
            specs["layers"] = _stack(blocks.dense_layer_specs(cfg), cfg.n_layers)
        elif cfg.family == "encoder":
            specs["layers"] = _stack(blocks.dense_layer_specs(cfg), cfg.n_layers)
            specs["mask_emb"] = TensorSpec((cfg.d_model,), ("embed",))
            specs["head"] = TensorSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
        elif cfg.family == "moe":
            specs["layers"] = _stack(blocks.moe_layer_specs(cfg), cfg.n_layers)
        elif cfg.family == "ssm":
            layer = {"ln": TensorSpec((cfg.d_model,), ("embed",), init="ones"), "mamba": ssm.mamba1_specs(cfg)}
            specs["layers"] = _stack(layer, cfg.n_layers)
        elif cfg.family == "hybrid":
            G, A = cfg.n_shared_attn(), cfg.attn_every
            layer = {"ln": TensorSpec((cfg.d_model,), ("embed",), init="ones"), "mamba": ssm.mamba2_specs(cfg)}
            specs["groups"] = _stack(_stack(layer, A, axis="sublayers"), G)
            specs["shared"] = blocks.shared_attn_specs(cfg)
        else:
            raise ValueError(cfg.family)
        if cfg.family == "encoder":
            # encoder consumes frame embeddings; token table unused -> drop it
            specs["embed"] = {}
        return specs

    def init(self, key: jax.Array):
        return tree_init(self.param_specs(), key)

    def abstract_params(self):
        return tree_abstract(self.param_specs())

    # ================================================================ loss
    def loss(self, params, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.family == "encoder":
            return self._encoder_loss(params, batch)
        if cfg.family == "vlm":
            return self._vlm_loss(params, batch)
        tokens, labels = batch["tokens"], batch["labels"]
        x = embed_tokens(params["embed"], tokens, self.dtype)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, aux = self._backbone(params, x, positions)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        head = head_matrix(params["embed"], cfg)
        ce = chunked_ce_loss(x, head, labels, cfg.loss_chunk, unroll=cfg.scan_unroll)
        return ce + aux, {"ce": ce, "aux": aux}

    def _vlm_loss(self, params, batch):
        cfg = self.cfg
        tokens, patches, labels = batch["tokens"], batch["patch_embeds"], batch["labels"]
        te = embed_tokens(params["embed"], tokens, self.dtype)
        x = jnp.concatenate([patches.astype(self.dtype), te], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x, aux = self._backbone(params, x, positions)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        # loss only over the text region (labels for patches are ignored)
        x_txt = x[:, patches.shape[1] :]
        ce = chunked_ce_loss(x_txt, head_matrix(params["embed"], cfg), labels, cfg.loss_chunk, unroll=cfg.scan_unroll)
        return ce + aux, {"ce": ce, "aux": aux}

    def _encoder_loss(self, params, batch):
        cfg = self.cfg
        frames, mask, labels = batch["frame_embeds"], batch["mask"], batch["labels"]
        x = jnp.where(mask[..., None], params["mask_emb"].astype(self.dtype), frames.astype(self.dtype))
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, aux = self._backbone(params, x, positions)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        labels_masked = jnp.where(mask, labels, -1)  # predict only masked frames
        ce = chunked_ce_loss(x, params["head"], labels_masked, cfg.loss_chunk, unroll=cfg.scan_unroll)
        return ce + aux, {"ce": ce, "aux": aux}

    # ============================================================= backbone
    def _backbone(self, params, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        aux = jnp.float32(0)
        x = constrain(x, "residual")
        if cfg.family in ("dense", "vlm", "encoder"):

            def body(h, lp):
                h = blocks.dense_layer_apply(lp, cfg, h, positions)
                return constrain(h, "residual"), None

            x, _ = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"], unroll=cfg.scan_unroll)
        elif cfg.family == "moe":

            def body(carry, lp):
                h, a = carry
                h, aux_l = blocks.moe_layer_apply(lp, cfg, h, positions)
                return (constrain(h, "residual"), a + aux_l), None

            (x, aux), _ = jax.lax.scan(_remat(body, cfg.remat), (x, aux), params["layers"], unroll=cfg.scan_unroll)
        elif cfg.family == "ssm":

            def body(h, lp):
                out, _ = ssm.mamba1_forward(lp["mamba"], cfg, rms_norm(h, lp["ln"], cfg.norm_eps))
                return constrain(h + out, "residual"), None

            x, _ = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"], unroll=cfg.scan_unroll)
        elif cfg.family == "hybrid":
            e0 = x  # concat-skip source (zamba trick)
            shared = params["shared"]

            def group_body(h, gp):
                def sub_body(hh, lp):
                    out, _ = ssm.mamba2_forward(lp["mamba"], cfg, rms_norm(hh, lp["ln"], cfg.norm_eps))
                    return constrain(hh + out, "residual"), None

                h, _ = jax.lax.scan(sub_body, h, gp, unroll=cfg.scan_unroll)
                h = blocks.shared_attn_apply(shared, cfg, h, e0, positions)
                return constrain(h, "residual"), None

            x, _ = jax.lax.scan(_remat(group_body, cfg.remat), x, params["groups"], unroll=cfg.scan_unroll)
        else:
            raise ValueError(cfg.family)
        return x, aux

    # ============================================================== prefill
    def prefill(self, params, batch) -> Tuple[jnp.ndarray, SpecTree]:
        """Process a prompt; returns (last-token logits, cache). The cache is
        sized to the prompt length (callers pad prompts to cache size)."""
        cfg = self.cfg
        if cfg.family == "encoder":
            return self._encoder_forward(params, batch), {}
        if cfg.family == "vlm":
            te = embed_tokens(params["embed"], batch["tokens"], self.dtype)
            x = jnp.concatenate([batch["patch_embeds"].astype(self.dtype), te], axis=1)
        else:
            x = embed_tokens(params["embed"], batch["tokens"], self.dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        cache: Dict[str, Any] = {}
        if cfg.family in ("dense", "vlm"):

            def body(h, lp):
                h, kv = blocks.dense_layer_prefill(lp, cfg, h, positions)
                return h, kv

            x, (ks, vs) = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"], unroll=cfg.scan_unroll)
            cache = {"k": ks, "v": vs}
        elif cfg.family == "moe":

            def body(h, lp):
                h, kv = blocks.moe_layer_prefill(lp, cfg, h, positions)
                return h, kv

            x, (ks, vs) = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"], unroll=cfg.scan_unroll)
            cache = {"k": ks, "v": vs}
        elif cfg.family == "ssm":

            def body(h, lp):
                out, h_last = ssm.mamba1_forward(lp["mamba"], cfg, rms_norm(h, lp["ln"], cfg.norm_eps))
                conv_tail = self._conv_tail(h, lp, cfg)
                return h + out, (h_last, conv_tail)

            x, (hs, convs) = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"], unroll=cfg.scan_unroll)
            cache = {"ssm": hs, "conv": convs}
        elif cfg.family == "hybrid":
            e0 = x
            shared = params["shared"]

            def group_body(h, gp):
                def sub_body(hh, lp):
                    out, h_last = ssm.mamba2_forward(lp["mamba"], cfg, rms_norm(hh, lp["ln"], cfg.norm_eps))
                    conv_tail = self._conv_tail(hh, lp, cfg, mamba2=True)
                    return hh + out, (h_last, conv_tail)

                h, (hs, convs) = jax.lax.scan(sub_body, h, gp, unroll=cfg.scan_unroll)
                h, kv = blocks.shared_attn_prefill(shared, cfg, h, e0, positions)
                return h, (hs, convs, kv)

            x, (hs, convs, (ks, vs)) = jax.lax.scan(_remat(group_body, cfg.remat), x, params["groups"], unroll=cfg.scan_unroll)
            cache = {"ssm": hs, "conv": convs, "k": ks, "v": vs}
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, -1] @ head_matrix(params["embed"], cfg)).astype(jnp.float32)
        return logits, cache

    @staticmethod
    def _conv_tail(h, lp, cfg, mamba2: bool = False):
        """Last K-1 conv inputs for the decode conv buffer."""
        K = cfg.ssm_conv
        pre = rms_norm(h, lp["ln"], cfg.norm_eps)
        proj = pre @ lp["mamba"]["in_proj"]
        if mamba2:
            di, N = cfg.d_inner, cfg.ssm_state
            xbc = proj[..., di : 2 * di + 2 * N]
            return xbc[:, -(K - 1) :]
        x_part = proj[..., : cfg.d_inner]
        return x_part[:, -(K - 1) :]

    def _encoder_forward(self, params, batch):
        cfg = self.cfg
        x = batch["frame_embeds"].astype(self.dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, _ = self._backbone(params, x, positions)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return (x @ params["head"]).astype(jnp.float32)  # (B, S, V) frame logits

    # =============================================================== decode
    def decode_step(self, params, tokens: jnp.ndarray, cache: SpecTree, pos: jnp.ndarray):
        """One autoregressive step. tokens: (B,) int32; pos: scalar int32.
        Returns (logits (B, V) f32, new cache)."""
        cfg = self.cfg
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        x = embed_tokens(params["embed"], tokens, self.dtype)  # (B, d)

        if cfg.family in ("dense", "vlm", "moe"):
            layer_fn = blocks.dense_layer_decode if cfg.family != "moe" else blocks.moe_layer_decode

            def body(h, inp):
                lp, kc, vc = inp
                h, kc, vc = layer_fn(lp, cfg, h, kc, vc, pos)
                return h, (kc, vc)

            x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll)
            new_cache = {"k": ks, "v": vs}
        elif cfg.family == "ssm":

            def body(h, inp):
                lp, hc, cc = inp
                out, hc, cc = ssm.mamba1_decode(lp["mamba"], cfg, rms_norm(h, lp["ln"], cfg.norm_eps), hc, cc)
                return h + out, (hc, cc)

            x, (hs, convs) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]), unroll=cfg.scan_unroll)
            new_cache = {"ssm": hs, "conv": convs}
        elif cfg.family == "hybrid":
            # concat-skip uses the *current* token's embedding (matches the
            # per-position e0 stream in the full forward pass)
            e0 = x
            shared = params["shared"]

            def group_body(h, inp):
                gp, hc_g, cc_g, kc, vc = inp

                def sub_body(hh, sub):
                    lp, hc, cc = sub
                    out, hc, cc = ssm.mamba2_decode(lp["mamba"], cfg, rms_norm(hh, lp["ln"], cfg.norm_eps), hc, cc)
                    return hh + out, (hc, cc)

                h, (hs, ccs) = jax.lax.scan(sub_body, h, (gp, hc_g, cc_g), unroll=cfg.scan_unroll)
                h, kc, vc = blocks.shared_attn_decode(shared, cfg, h, e0, kc, vc, pos)
                return h, (hs, ccs, kc, vc)

            x, (hs, convs, ks, vs) = jax.lax.scan(
                group_body, x, (params["groups"], cache["ssm"], cache["conv"], cache["k"], cache["v"]),
                unroll=cfg.scan_unroll,
            )
            new_cache = {"ssm": hs, "conv": convs, "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x @ head_matrix(params["embed"], cfg)).astype(jnp.float32)
        return logits, new_cache

    # ================================================================ cache
    def cache_specs(self, batch: int, cache_len: int) -> SpecTree:
        """TensorSpec tree for a decode cache of ``cache_len`` tokens."""
        cfg = self.cfg
        dt = self.dtype
        KV, hd, K = cfg.n_kv_heads, cfg.hd, cfg.ssm_conv
        if cfg.family in ("dense", "vlm", "moe"):
            kv = TensorSpec(
                (cfg.n_layers, batch, cache_len, KV, hd),
                ("layers", "act_batch", "cache_seq", "kv", "hd"),
                dt,
                init="zeros",
            )
            return {"k": kv, "v": kv}
        if cfg.family == "ssm":
            return {
                "ssm": TensorSpec(
                    (cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                    ("layers", "act_batch", "ssm_inner", None),
                    jnp.float32,
                    init="zeros",
                ),
                "conv": TensorSpec(
                    (cfg.n_layers, batch, K - 1, cfg.d_inner),
                    ("layers", "act_batch", None, "ssm_inner"),
                    dt,
                    init="zeros",
                ),
            }
        if cfg.family == "hybrid":
            G, A = cfg.n_shared_attn(), cfg.attn_every
            return {
                "ssm": TensorSpec(
                    (G, A, batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state),
                    ("layers", "sublayers", "act_batch", "ssm_heads", None, None),
                    jnp.float32,
                    init="zeros",
                ),
                "conv": TensorSpec(
                    (G, A, batch, K - 1, cfg.d_inner + 2 * cfg.ssm_state),
                    ("layers", "sublayers", "act_batch", None, "ssm_inner"),
                    dt,
                    init="zeros",
                ),
                "k": TensorSpec(
                    (G, batch, cache_len, KV, hd),
                    ("layers", "act_batch", "cache_seq", "kv", "hd"),
                    dt,
                    init="zeros",
                ),
                "v": TensorSpec(
                    (G, batch, cache_len, KV, hd),
                    ("layers", "act_batch", "cache_seq", "kv", "hd"),
                    dt,
                    init="zeros",
                ),
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, cache_len: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_specs(batch, cache_len),
            is_leaf=lambda x: isinstance(x, TensorSpec),
        )

    # ============================================================ input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell
        (weak-type-correct, shardable, no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            if cfg.family == "encoder":
                return {
                    "frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), self.dtype),
                    "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                }
            if cfg.family == "vlm":
                si = S // 2
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S - si), i32),
                    "patch_embeds": jax.ShapeDtypeStruct((B, si, cfg.d_model), self.dtype),
                    "labels": jax.ShapeDtypeStruct((B, S - si), i32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if shape.kind == "prefill":
            if cfg.family == "encoder":
                return {"frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), self.dtype)}
            if cfg.family == "vlm":
                si = S // 2
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S - si), i32),
                    "patch_embeds": jax.ShapeDtypeStruct((B, si, cfg.d_model), self.dtype),
                }
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        # decode: one new token against a cache of S
        return {
            "tokens": jax.ShapeDtypeStruct((B,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "cache": tree_abstract(self.cache_specs(B, S)),
        }


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
