"""Mixture-of-Experts FFN: top-k router + capacity-bounded scatter dispatch.

Formulation (Mesh-TF/MaxText-style, TPU-friendly):
  1. router logits -> softmax -> top-k experts per token, weights renormalized;
  2. position-in-expert via cumsum over the flattened (token, choice) lattice;
     tokens beyond ``capacity = cf * S * k / E`` are dropped (standard
     capacity-factor semantics, cf=1.25 default);
  3. scatter tokens into a dense (E, C, d) buffer, grouped-matmul the expert
     FFNs — einsums land on the MXU and shard cleanly: experts over 'model'
     when E % tp == 0 (olmoe), otherwise expert-internal d_ff over 'model'
     (mixtral 8 experts on tp=16) — see launch/shardings.py;
  4. gather back with combine weights; aux load-balance loss (Switch-style).

HLO FLOPs therefore track 6*N_active*D (plus router/dispatch overhead),
which §Roofline cross-checks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.model import ModelConfig
from repro.launch.act_sharding import constrain
from repro.models.spec import TensorSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": TensorSpec((d, E), ("embed", None), dtype=jnp.float32),
        "gate": TensorSpec((E, d, f), ("experts", "embed", "mlp")),
        "up": TensorSpec((E, d, f), ("experts", "embed", "mlp")),
        "down": TensorSpec((E, f, d), ("experts", "mlp", "embed")),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.experts_per_token)


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is **row-local** (§Perf iteration 3): position-in-expert and the
    scatter/gather stay within each sequence, with per-row capacity
    ``S*k*cf/E``. A global (token-dim) cumsum + scatter forces GSPMD to
    replicate the whole dispatch buffer and all-reduce it every layer when
    the batch is data-sharded — measured 128 GB f32 per layer on
    mixtral-8x22b train_4k (EXPERIMENTS.md §Perf). Row-local routing keeps
    all dispatch traffic on-device; capacity semantics become per-sequence
    (standard practice, e.g. grouped/expert-choice routers)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity(cfg, S)  # per-row capacity

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                      # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))                                         # (E,)
    ce = (
        jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
        / (B * S * k)
    )
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # position-in-expert within each row's (S*k) dispatch lattice
    flat_e = expert_idx.reshape(B, S * k)                                # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)                  # (B, S*k, E)
    pos = ((jnp.cumsum(onehot, axis=1) - 1) * onehot).sum(-1)            # (B, S*k)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)                      # (B, S*k)

    # row-local scatter to (B, E*C+1, d); spill row dropped
    tok_idx = jnp.repeat(jnp.arange(S), k)                               # (S*k,)
    vals = jnp.take(x, tok_idx, axis=1)                                  # (B, S*k, d)
    rows = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, slot].set(vals)
    ex_in = constrain(buf[:, : E * C].reshape(B, E, C, d), "moe_in")

    # grouped expert FFN (batched over rows; weights broadcast)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", ex_in, p["gate"])) * jnp.einsum(
        "becd,edf->becf", ex_in, p["up"]
    )
    h = constrain(h, "moe_hidden")
    ex_out = jnp.einsum("becf,efd->becd", h, p["down"]).reshape(B, E * C, d)
    ex_out = jnp.concatenate([ex_out, jnp.zeros((B, 1, d), x.dtype)], axis=1)

    # row-local gather + combine
    gathered = jnp.take_along_axis(ex_out, slot[..., None], axis=1)      # (B, S*k, d)
    w = (gate_vals.reshape(B, S * k) * keep).astype(jnp.float32)[..., None]
    contrib = (gathered.astype(jnp.float32) * w).reshape(B, S, k, d).sum(axis=2)
    return contrib.astype(x.dtype), aux


def moe_apply_dense_eval(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle: run every expert on every token, combine with router weights
    (no capacity drops). Used by tests to validate the dispatch path."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    full = jnp.zeros((xt.shape[0], cfg.n_experts), jnp.float32)
    w = full.at[jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["gate"])) * jnp.einsum(
        "td,edf->tef", xt, p["up"]
    )
    y = jnp.einsum("tef,efd->ted", h, p["down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype)
