"""TensorSpec trees: shapes + logical sharding axes for every parameter.

MaxText-style logical axis naming decouples model code from mesh layout:
model code labels each tensor dim ("vocab", "embed", "heads", "experts", ...);
`repro.launch.shardings` maps labels -> mesh axes per mesh/shape. The same
spec tree drives (a) real initialization for smoke tests/examples,
(b) ShapeDtypeStruct stand-ins for the dry-run, and (c) NamedShardings.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TensorSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str | None, ...]  # logical axis name per dim (None = replicated)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


SpecTree = Dict[str, Any]  # nested dicts of TensorSpec


def tree_abstract(specs: SpecTree):
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=lambda x: isinstance(x, TensorSpec))


def _init_one(spec: TensorSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "ssm_a":
        # mamba A_log init: A = -exp(A_log) stable negatives, log(1..N) pattern
        n = spec.shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, spec.shape).astype(spec.dtype)
    if spec.init == "ssm_dt":
        # dt bias init so softplus(dt) spans ~[1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(spec.dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(spec.dtype)


def tree_init(specs: SpecTree, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, TensorSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_logical_axes(specs: SpecTree):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, TensorSpec))


def param_count(specs: SpecTree) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, TensorSpec))
    )
