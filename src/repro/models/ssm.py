"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

TPU adaptation (DESIGN.md §3): the CUDA "hardware-aware" fused scan becomes a
**chunked scan** — `lax.scan` over sequence chunks carrying the recurrent
state, with the intra-chunk recurrence evaluated by `associative_scan`
(mamba1) or the SSD quadratic-form einsums (mamba2). Chunking bounds the
materialized (B, Q, d_inner, N) tensors to one chunk (the VMEM-sized working
set a Pallas kernel would use), and the einsums land on the MXU.

Both blocks have sequential-scan oracles in tests/test_models.py; chunked ==
sequential to f32 tolerance.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.model import ModelConfig
from repro.launch.act_sharding import constrain
from repro.models.spec import TensorSpec


# =============================================================== mamba-1
def mamba1_specs(cfg: ModelConfig) -> dict:
    d, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    return {
        "in_proj": TensorSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": TensorSpec((K, di), (None, "ssm_inner")),
        "conv_b": TensorSpec((di,), ("ssm_inner",), init="zeros"),
        "x_proj": TensorSpec((di, R + 2 * N), ("ssm_inner", None)),
        "dt_w": TensorSpec((R, di), (None, "ssm_inner")),
        "dt_b": TensorSpec((di,), ("ssm_inner",), init="ssm_dt", dtype=jnp.float32),
        "A_log": TensorSpec((di, N), ("ssm_inner", None), init="ssm_a", dtype=jnp.float32),
        "D": TensorSpec((di,), ("ssm_inner",), init="ones", dtype=jnp.float32),
        "out_proj": TensorSpec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),  # (K, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _mamba1_core(p: dict, cfg: ModelConfig, x: jnp.ndarray, h0: jnp.ndarray):
    """Chunked selective scan. x: (B, S, di) post-conv post-silu activations.
    h0: (B, di, N) carried state. Returns (y, h_last)."""
    B, S, di = x.shape
    N, R, Q = cfg.ssm_state, cfg.dt_rank, min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)

    proj = (x @ p["x_proj"]).astype(jnp.float32)  # (B, S, R+2N)
    dt_r, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(dt_r @ p["dt_w"].astype(jnp.float32) + p["dt_b"])  # (B,S,di)
    A = -jnp.exp(p["A_log"])  # (di, N)

    xf = x.astype(jnp.float32)
    nc = S // Q

    def chunk_step(h, inp):
        dt_c, B_c, C_c, x_c = inp  # (B,Q,di) (B,Q,N) (B,Q,N) (B,Q,di)
        dA = jnp.exp(dt_c[..., None] * A)               # (B,Q,di,N)
        dBx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]  # (B,Q,di,N)
        # intra-chunk linear recurrence h_t = dA_t h_{t-1} + dBx_t
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        a_sc, b_sc = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        h_all = a_sc * h[:, None] + b_sc                 # (B,Q,di,N)
        y_c = jnp.einsum("bqn,bqdn->bqd", C_c, h_all)
        return h_all[:, -1], y_c

    def reshape_c(t):
        return t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    h_last, ys = jax.lax.scan(
        chunk_step, h0, (reshape_c(dt), reshape_c(Bm), reshape_c(Cm), reshape_c(xf)),
        unroll=cfg.scan_unroll,
    )
    y = ys.swapaxes(0, 1).reshape(B, S, di) + xf * p["D"]
    return y, h_last


def mamba1_forward(p: dict, cfg: ModelConfig, u: jnp.ndarray, h0=None, conv0=None):
    """Full block. u: (B, S, d_model) -> (B, S, d_model)."""
    B, S, _ = u.shape
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = constrain(u @ p["in_proj"], "inner")  # SP -> TP: d_inner sharded
    x, z = jnp.split(xz, 2, axis=-1)
    x = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)
    y, h_last = _mamba1_core(p, cfg, x, h0)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["out_proj"], h_last


def mamba1_decode(p: dict, cfg: ModelConfig, u: jnp.ndarray, h: jnp.ndarray, conv_buf: jnp.ndarray):
    """Single-token step. u: (B, d); h: (B, di, N); conv_buf: (B, K-1, di).
    Returns (y (B, d), h_new, conv_buf_new)."""
    N, R, K = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    window = jnp.concatenate([conv_buf, x[:, None]], axis=1)  # (B, K, di)
    conv_buf_new = window[:, 1:]
    xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    x = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32)).astype(u.dtype)

    proj = (x @ p["x_proj"]).astype(jnp.float32)
    dt_r, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(dt_r @ p["dt_w"].astype(jnp.float32) + p["dt_b"])  # (B, di)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                     # (B, di, N)
    dBx = (dt * x.astype(jnp.float32))[..., None] * Bm[:, None, :]
    h_new = dA * h + dBx
    y = jnp.einsum("bn,bdn->bd", Cm, h_new) + x.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["out_proj"], h_new, conv_buf_new


# =============================================================== mamba-2
def mamba2_specs(cfg: ModelConfig) -> dict:
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    H = cfg.ssm_nheads
    return {
        "in_proj": TensorSpec((d, 2 * di + 2 * N + H), ("embed", "ssm_inner")),
        "conv_w": TensorSpec((K, di + 2 * N), (None, "ssm_inner")),
        "conv_b": TensorSpec((di + 2 * N,), ("ssm_inner",), init="zeros"),
        "A_log": TensorSpec((H,), ("ssm_heads",), init="ssm_a", dtype=jnp.float32),
        "dt_b": TensorSpec((H,), ("ssm_heads",), init="ssm_dt", dtype=jnp.float32),
        "D": TensorSpec((H,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "norm": TensorSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": TensorSpec((di, d), ("ssm_inner", "embed")),
    }


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) decay logs -> (..., Q, Q) lower-triangular pairwise sums:
    out[i, j] = sum_{j < t <= i} a_t  (i >= j), -inf above diagonal."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum_(j,i] when i>=j
    i = jnp.arange(Q)
    keep = i[:, None] >= i[None, :]
    return jnp.where(keep, diff, -jnp.inf)


def _mamba2_core(cfg, dt, A, Bm, Cm, X, h0):
    """Chunked SSD. dt: (B,S,H); Bm/Cm: (B,S,N); X: (B,S,H,P); h0: (B,H,P,N)."""
    B, S, H = dt.shape
    P, N = X.shape[-1], Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0
    nc = S // Q

    def r(t):  # (B, S, ...) -> (nc, B, Q, ...)
        return t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    dtc, Bc, Cc, Xc = r(dt), r(Bm), r(Cm), r(X)

    def chunk_step(h, inp):
        dt_c, B_c, C_c, x_c = inp
        a = dt_c * A  # (B,Q,H) decay logs
        a = a.swapaxes(1, 2)  # (B,H,Q)
        L = jnp.exp(_segsum(a))                                  # (B,H,Q,Q)
        xdt = x_c * dt_c[..., None]                              # (B,Q,H,P)
        # intra-chunk (diagonal blocks)
        y_diag = jnp.einsum("bqn,bkn,bhqk,bkhp->bqhp", C_c, B_c, L, xdt)
        # inter-chunk: contribution of carried state
        cum = jnp.cumsum(a, axis=-1)                             # (B,H,Q)
        y_inter = jnp.einsum("bqn,bhq,bhpn->bqhp", C_c, jnp.exp(cum), h)
        # state update
        decay_to_end = jnp.exp(cum[..., -1:] - cum)              # (B,H,Q)
        new_contrib = jnp.einsum("bkn,bhk,bkhp->bhpn", B_c, decay_to_end, xdt)
        h_new = jnp.exp(cum[..., -1])[..., None, None] * h + new_contrib
        return h_new, y_diag + y_inter

    h_last, ys = jax.lax.scan(chunk_step, h0, (dtc, Bc, Cc, Xc), unroll=cfg.scan_unroll)
    return ys.swapaxes(0, 1).reshape(B, S, H, P), h_last


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps) * scale.astype(jnp.float32))


def mamba2_forward(p: dict, cfg: ModelConfig, u: jnp.ndarray, h0=None):
    """Full SSD block. u: (B, S, d) -> (B, S, d)."""
    B, S, _ = u.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    zxbcdt = constrain(u @ p["in_proj"], "inner")  # SP -> TP: d_inner sharded
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    X = x.reshape(B, S, H, P).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    Y, h_last = _mamba2_core(cfg, dtf, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32), X, h0)
    Y = Y + X * p["D"][None, None, :, None]
    y = Y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32))
    y = _rms(y, p["norm"], cfg.norm_eps).astype(u.dtype)
    return y @ p["out_proj"], h_last


def mamba2_decode(p: dict, cfg: ModelConfig, u: jnp.ndarray, h: jnp.ndarray, conv_buf: jnp.ndarray):
    """Single-token SSD step. u: (B, d); h: (B, H, P, N); conv_buf: (B, K-1, di+2N)."""
    di, N, H, P, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_conv
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    window = jnp.concatenate([conv_buf, xbc[:, None]], axis=1)
    conv_buf_new = window[:, 1:]
    xc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32))
    x, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    X = x.reshape(-1, H, P)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"])    # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtf * A)                                        # (B,H)
    h_new = dA[..., None, None] * h + jnp.einsum("bn,bh,bhp->bhpn", Bm, dtf, X)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new) + X * p["D"][None, :, None]
    y = y.reshape(-1, di) * jax.nn.silu(z.astype(jnp.float32))
    y = _rms(y, p["norm"], cfg.norm_eps).astype(u.dtype)
    return y @ p["out_proj"], h_new, conv_buf_new
