"""Observability plane: deterministic tracing, typed metrics, PHI-safe export.

Three layers, all clock-injected and fully deterministic under a SimClock:

- :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram with label sets,
  a :class:`MetricsRegistry` that aggregates across instances on snapshot,
  and :class:`StatsShim`, which lets the existing ``*.stats.field`` attribute
  surfaces keep working while the values live in real metrics.
- :mod:`repro.obs.trace` — :class:`Tracer`/:class:`Span` with explicit
  context propagation (trace ids derived from ticket key + attempt),
  deterministic span ids, and a canonical SHA-256 trace digest so a seeded
  fleet run replays bit-identically. ``NULL_TRACER`` is a zero-overhead
  no-op used wherever tracing is disabled.
- :mod:`repro.obs.export` — allowlist :class:`Redactor` plus JSONL and
  Chrome-trace exporters; *every* attribute and label crosses the redactor
  before leaving the process, making exported telemetry provably PHI-free.

On top of those sit the consumers (DESIGN.md §13):

- :mod:`repro.obs.slo` — declarative :class:`SloSpec` objectives evaluated
  incrementally with multi-window burn-rate alerting; the full alert
  sequence replays from the engine's own observation log.
- :mod:`repro.obs.profile` — :class:`CriticalPathProfiler`, folding finished
  spans into a deterministic per-(temperature, modality, stage) self-time
  profile with PHI-safe folded/Chrome exports.
- :mod:`repro.obs.health` — :class:`HealthController`, turning SLO state
  into operator :class:`HealthReport` snapshots and a burn-rate pressure
  signal the autoscaler consumes.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry, StatsShim
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer, trace_id_for
from repro.obs.export import (
    Redactor,
    export_metrics_jsonl,
    export_spans_jsonl,
    to_chrome_trace,
)
from repro.obs.slo import (
    AlertEvent,
    BurnRule,
    SloEngine,
    SloSpec,
    default_burn_rules,
    derive_serve_observations,
)
from repro.obs.profile import CriticalPathProfiler
from repro.obs.health import HealthController, HealthReport

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsShim",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "trace_id_for",
    "Redactor",
    "export_metrics_jsonl",
    "export_spans_jsonl",
    "to_chrome_trace",
    "AlertEvent",
    "BurnRule",
    "SloEngine",
    "SloSpec",
    "default_burn_rules",
    "derive_serve_observations",
    "CriticalPathProfiler",
    "HealthController",
    "HealthReport",
]
