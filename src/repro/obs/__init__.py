"""Observability plane: deterministic tracing, typed metrics, PHI-safe export.

Three layers, all clock-injected and fully deterministic under a SimClock:

- :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram with label sets,
  a :class:`MetricsRegistry` that aggregates across instances on snapshot,
  and :class:`StatsShim`, which lets the existing ``*.stats.field`` attribute
  surfaces keep working while the values live in real metrics.
- :mod:`repro.obs.trace` — :class:`Tracer`/:class:`Span` with explicit
  context propagation (trace ids derived from ticket key + attempt),
  deterministic span ids, and a canonical SHA-256 trace digest so a seeded
  fleet run replays bit-identically. ``NULL_TRACER`` is a zero-overhead
  no-op used wherever tracing is disabled.
- :mod:`repro.obs.export` — allowlist :class:`Redactor` plus JSONL and
  Chrome-trace exporters; *every* attribute and label crosses the redactor
  before leaving the process, making exported telemetry provably PHI-free.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry, StatsShim
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer, trace_id_for
from repro.obs.export import (
    Redactor,
    export_metrics_jsonl,
    export_spans_jsonl,
    to_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsShim",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "trace_id_for",
    "Redactor",
    "export_metrics_jsonl",
    "export_spans_jsonl",
    "to_chrome_trace",
]
