"""PHI-safe telemetry export: allowlist redaction, JSONL, Chrome trace.

The redaction contract (DESIGN.md §11): telemetry leaves the process only
through these exporters, and every span attribute and metric label crosses
:class:`Redactor` first. The redactor is *allowlist-only* on two axes:

- **Keys**: only keys in ``ALLOWED_ATTR_KEYS`` survive; everything else is
  dropped outright (key and value). All allowed keys are code-controlled
  literals — no call site derives an attribute key from data.
- **Values**: numbers/bools/None pass. Strings pass only when they match the
  identifier charset ``[A-Za-z0-9_./:#@\\-]`` at ≤64 chars. The charset
  deliberately excludes ``^`` and whitespace, so DICOM person names
  (``DOE^JOHN``) and any free text are blocked even if they reach an
  allowlisted key. Blocked values become ``"[redacted]"``.

Span/metric *names* and ids are code-controlled and pass as-is. Everything
here is pure-function over the inputs — exporting never mutates the tracer
or registry, so exporting cannot perturb a deterministic run.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Optional

from repro.obs.trace import Span, _canonical

# Every attribute key any instrumentation site is allowed to emit. Adding a
# key is a reviewed change to this file, which is the point.
ALLOWED_ATTR_KEYS = frozenset({
    # identity / linkage
    "key", "accession", "cohort_id", "trace_link", "seq", "attempt",
    "deliveries", "msg_id", "worker", "kind", "stage", "error",
    # sizes and counts
    "n", "nbytes", "bytes_in", "bytes_out", "instances", "datasets",
    "rects", "bands", "dispatches", "batch", "rows", "matched",
    "blocks_scanned", "blocks_pruned", "handed", "applied", "deletes",
    "duplicates", "polls", "floor", "backlog",
    # planner partition
    "cold", "warm", "in_flight", "coalesced", "rejected", "lake_hits",
    "journal_hits", "stale_refreshes", "published",
    # kernel dispatch facts
    "shape", "dtype", "bucket", "path", "interpret", "padded",
    # timing facts
    "busy_s", "t_lease", "visibility",
    # device/host pipeline boundary timing (DESIGN.md §12)
    "queue_s", "wait_s",
    # outcome flags
    "ok", "deduped", "fenced", "crashed", "mode",
    # SLO / critical-path profile plane (DESIGN.md §13)
    "modality", "slo", "rule", "action", "severity", "burn_long", "burn_short",
    # audit / provenance plane (DESIGN.md §14). These mirror the ledger's
    # payload fields: lineage handles are digests/ids (hex, charset-safe),
    # never free text, but they still cross the value rule like everything.
    "project", "etag", "lake_key", "ruleset", "detector_sha", "kernel_path",
    "batched", "trace_id", "temp", "reason", "device", "registry_hit",
    "detected", "op", "outcome", "channel", "records", "accessions", "journal",
    "feed_seq",
    "rulesets", "first_t", "last_t", "deid_executions", "lake_writes",
    "lake_evictions", "lake_bytes_in", "lake_bytes_out", "dead_lettered",
    "ledger_records", "ledger_digest",
})

_SAFE_VALUE_RE = re.compile(r"^[A-Za-z0-9_./:#@\-]{1,64}$")

REDACTED = "[redacted]"


class Redactor:
    """Allowlist attribute filter. ``enabled=False`` passes everything
    through — that mode exists solely so the ``TelemetryPhiBoundary``
    negative control can prove the checker is live."""

    def __init__(self, enabled: bool = True, allowed_keys: Optional[frozenset] = None) -> None:
        self.enabled = enabled
        self.allowed_keys = ALLOWED_ATTR_KEYS if allowed_keys is None else allowed_keys

    def safe_value(self, value) -> object:
        if value is None or isinstance(value, (bool, int, float)):
            return value
        if isinstance(value, str):
            return value if _SAFE_VALUE_RE.match(value) else REDACTED
        if isinstance(value, (list, tuple)):
            return [self.safe_value(v) for v in value]
        return REDACTED

    def attrs(self, attrs: Dict[str, object]) -> Dict[str, object]:
        if not self.enabled:
            return dict(attrs)
        return {k: self.safe_value(v) for k, v in attrs.items() if k in self.allowed_keys}


def _audit_export(ledger, channel: str, records: int) -> None:
    """Telemetry leaving the system boundary is itself a PHI-relevant action:
    record it in the audit ledger when the caller passes one (DESIGN.md §14).
    ``ledger=None`` keeps exporters pure functions, as before."""
    if ledger is not None and getattr(ledger, "enabled", False):
        ledger.append("telemetry_export", channel=channel, records=records)


def export_spans_jsonl(spans: Iterable[Span], redactor: Redactor, ledger=None) -> str:
    """One canonical JSON object per line, attrs redacted. '' if no spans."""
    lines: List[str] = []
    for s in spans:
        d = s.to_dict()
        d["attrs"] = redactor.attrs(d["attrs"])
        lines.append(json.dumps(_canonical(d), sort_keys=True, separators=(",", ":")))
    _audit_export(ledger, "spans_jsonl", len(lines))
    return "\n".join(lines) + ("\n" if lines else "")


def export_metrics_jsonl(snapshot: Dict[str, float], redactor: Redactor, ledger=None) -> str:
    """Flat registry snapshot as JSONL; label *values* are redacted too.

    Series keys look like ``repro_lake_hits{modality="CT"}``; the name part
    is code-controlled, but label values may echo data, so each one crosses
    the redactor's value rule.
    """
    lines: List[str] = []
    for key in sorted(snapshot):
        name, labels = _split_series_key(key)
        safe_labels = {k: (redactor.safe_value(v) if redactor.enabled else v)
                       for k, v in labels.items()}
        lines.append(json.dumps(
            _canonical({"metric": name, "labels": safe_labels, "value": snapshot[key]}),
            sort_keys=True, separators=(",", ":")))
    _audit_export(ledger, "metrics_jsonl", len(lines))
    return "\n".join(lines) + ("\n" if lines else "")


_SERIES_RE = re.compile(r'([^,=]+)="([^"]*)"')


def _split_series_key(key: str) -> tuple:
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    labels = {m.group(1): m.group(2) for m in _SERIES_RE.finditer(rest[:-1])}
    return name, labels


def to_chrome_trace(spans: Iterable[Span], redactor: Redactor, ledger=None) -> Dict[str, object]:
    """Chrome trace-event JSON (``chrome://tracing`` / Perfetto loadable).

    Each trace id becomes a ``tid`` so one work item's spans stack on one
    track; timestamps convert to microseconds; redacted attrs ride in
    ``args``.
    """
    tids: Dict[str, int] = {}
    events: List[Dict[str, object]] = []
    for s in spans:
        tid = tids.setdefault(s.trace_id, len(tids) + 1)
        t1 = s.t1 if s.t1 is not None else s.t0
        events.append({
            "name": s.name,
            "cat": s.trace_id,
            "ph": "X",
            "pid": 1,
            "tid": tid,
            "ts": round(s.t0 * 1e6, 3),
            "dur": round((t1 - s.t0) * 1e6, 3),
            "args": redactor.attrs(s.attrs),
        })
    thread_names = [
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
         "args": {"name": f"trace {trace_id}"}}
        for trace_id, tid in tids.items()
    ]
    _audit_export(ledger, "chrome_trace", len(events))
    return {"traceEvents": thread_names + events, "displayTimeUnit": "ms"}
