"""Health controller loop: SLO state → operator snapshot + autoscaler signal.

Closes the observability loop (DESIGN.md §13). Two outputs:

* :meth:`HealthController.snapshot` — a :class:`HealthReport` of SLO states,
  burn rates, remaining error budgets, active alerts, and the top regressing
  pipeline stages from the critical-path profiler. Surfaced to operators via
  ``DeidService.health_report()``.
* :meth:`HealthController.pressure` — a deterministic scale-up multiplier
  (≥ 1.0) derived from *active latency-SLO alerts only*: each burning
  (slo, rule) pair whose spec kind is "latency" adds ``boost_per_alert``,
  capped at ``max_pressure``. The autoscaler multiplies its backlog-derived
  target by this, so a burning latency SLO buys instances the backlog math
  alone would not — recovery from a straggler storm provably shortens
  (the sim's burn→autoscaler scenario asserts it, with an off-switch
  negative control).

The controller holds no clock and no mutable state of its own: pressure and
snapshots are pure functions of the engine/profiler at call time, so the
closed loop stays bit-replayable from one seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs.profile import CriticalPathProfiler
from repro.obs.slo import SloEngine


@dataclass
class HealthReport:
    """One point-in-time health snapshot; ``to_dict()`` is print-ready."""

    t: float
    states: Dict[str, str] = field(default_factory=dict)
    burn: Dict[str, float] = field(default_factory=dict)
    budget_remaining: Dict[str, float] = field(default_factory=dict)
    active_alerts: List[str] = field(default_factory=list)
    top_stages: List[Tuple[str, float]] = field(default_factory=list)

    @property
    def burning(self) -> List[str]:
        return [name for name, st in self.states.items() if st == "burning"]

    def to_dict(self) -> Dict[str, object]:
        return {
            "t": round(self.t, 9),
            "states": dict(self.states),
            "burn": {k: round(v, 6) for k, v in self.burn.items()},
            "budget_remaining": {
                k: round(v, 6) for k, v in self.budget_remaining.items()
            },
            "active_alerts": list(self.active_alerts),
            "top_stages": [[s, round(v, 6)] for s, v in self.top_stages],
        }

    def summary(self) -> str:
        burning = self.burning
        head = (
            f"{len(burning)}/{len(self.states)} SLOs burning"
            if self.states else "no SLOs registered"
        )
        if burning:
            head += f" ({', '.join(sorted(burning))})"
        if self.top_stages:
            stage, secs = self.top_stages[0]
            head += f"; top stage {stage} ({secs:.1f}s)"
        return head


class HealthController:
    """Pure-function bridge from SLO engine (+ profiler) to consumers."""

    def __init__(
        self,
        engine: SloEngine,
        profiler: Optional[CriticalPathProfiler] = None,
        boost_per_alert: float = 1.0,
        max_pressure: float = 4.0,
    ) -> None:
        self.engine = engine
        self.profiler = profiler
        self.boost_per_alert = boost_per_alert
        self.max_pressure = max_pressure

    def pressure(self) -> float:
        """Scale-up multiplier from active latency-SLO alerts; 1.0 when
        nothing latency-shaped is burning."""
        n = sum(
            1
            for slo, _rule in self.engine.active_alerts()
            if self.engine.specs[slo].kind == "latency"
        )
        return min(self.max_pressure, 1.0 + self.boost_per_alert * n)

    def snapshot(self, t: float) -> HealthReport:
        eng = self.engine
        burn = {}
        for name, spec in eng.specs.items():
            # report the fastest rule's long-window burn — the paging signal
            rule = spec.rules[0]
            burn[name] = eng.burn_rate(name, rule.long_window, t)
        return HealthReport(
            t=t,
            states=eng.states(),
            burn=burn,
            budget_remaining={
                name: eng.budget_remaining(name, t) for name in eng.specs
            },
            active_alerts=[f"{slo}#{rule}" for slo, rule in eng.active_alerts()],
            top_stages=self.profiler.top_stages(3) if self.profiler else [],
        )
