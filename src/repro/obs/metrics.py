"""Typed metrics: Counter/Gauge/Histogram, a registry, and the stats shim.

Naming convention (validated): ``repro_<subsystem>_<name>``, lowercase
``[a-z0-9_]``. Labels are plain string→string dicts; a metric family keys its
series by the canonical sorted label rendering, so iteration order of the
caller's kwargs never matters.

The registry is a *collection point*, not a uniqueness authority: several
components may each own an instance of the same family (e.g. every
``DeidPipeline`` has its own ``DetectStats``), and ``snapshot()`` aggregates
them by summing per-series — the same model as Prometheus multiprocess mode.
That keeps per-component attribute reads (``pipeline.scrub.detect_stats.detected``)
exact while fleet-level reads (``registry.value(...)``) see the total.

:class:`StatsShim` preserves the pre-obs attribute surfaces: subclasses
declare ``_SUBSYSTEM`` and ``_FIELDS`` and both ``stats.field`` reads and
``stats.field += 1`` writes route to label-free counters registered under
``repro_<subsystem>_<field>``.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, Optional, Tuple

_NAME_RE = re.compile(r"^repro_[a-z0-9]+_[a-z0-9_]+$")
_LABEL_RE = re.compile(r"^[a-z_][a-z0-9_]*$")

DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0, float("inf"),
)


def _series_key(labels: Dict[str, str]) -> str:
    """Canonical label rendering: ``{a="1",b="x"}`` with sorted keys."""
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{labels[k]}"' for k in sorted(labels)) + "}"


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"metric name {name!r} must match repro_<subsystem>_<name>")
    return name


class _Metric:
    """Common family plumbing: name/help/registry + per-series storage."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", registry: Optional["MetricsRegistry"] = None):
        self.name = _check_name(name)
        self.help = help
        self._series: Dict[str, object] = {}
        if registry is not None:
            registry.register(self)

    def _key(self, labels: Dict[str, str]) -> str:
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"bad label name {k!r} on {self.name}")
        return _series_key({k: str(v) for k, v in labels.items()})


class Counter(_Metric):
    """Monotone (by convention) additive counter with optional labels."""

    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0) + n

    def set_total(self, value: float, **labels) -> None:
        """Shim escape hatch: ``stats.field += 1`` desugars to a read + set."""
        self._series[self._key(labels)] = value

    @property
    def value(self):
        """Label-free series value (0 when never incremented)."""
        return self._series.get("", 0)

    def series(self) -> Dict[str, float]:
        return dict(self._series)


class Gauge(_Metric):
    """Point-in-time value; ``set``/``inc``/``dec`` with optional labels."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = value

    def inc(self, n: float = 1, **labels) -> None:
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0) + n

    def dec(self, n: float = 1, **labels) -> None:
        self.inc(-n, **labels)

    @property
    def value(self):
        return self._series.get("", 0)

    def series(self) -> Dict[str, float]:
        return dict(self._series)


class Histogram(_Metric):
    """Fixed-bucket histogram; per-series cumulative bucket counts + sum."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        registry: Optional["MetricsRegistry"] = None,
        buckets: Iterable[float] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help, registry)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds or bounds[-1] != float("inf"):
            bounds = bounds + (float("inf"),)
        self.buckets = bounds

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        state = self._series.get(key)
        if state is None:
            state = {"counts": [0] * len(self.buckets), "sum": 0.0, "count": 0,
                     "min": value, "max": value}
            self._series[key] = state
        for i, bound in enumerate(self.buckets):
            if value <= bound:
                state["counts"][i] += 1
                break
        state["sum"] += value
        state["count"] += 1
        state["min"] = min(state["min"], value)
        state["max"] = max(state["max"], value)

    def series(self) -> Dict[str, dict]:
        # min/max are quantile-estimation internals; the exported series
        # surface (and therefore registry snapshots/digests) stays exactly
        # counts/sum/count.
        return {k: {"counts": list(v["counts"]), "sum": v["sum"], "count": v["count"]}
                for k, v in self._series.items()}

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Estimate the q-quantile (q in [0, 1]) for one series.

        Rank-based with linear interpolation inside the containing bucket,
        clamped to the observed min/max — so the error is at most the width
        of that bucket, the open top bucket degrades to the observed max
        rather than infinity, and a series whose observations all share one
        value returns that value exactly. None when the series is empty.
        """
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"quantile q must be in [0, 1], got {q}")
        state = self._series.get(self._key(labels))
        if state is None or state["count"] == 0:
            return None
        return self._quantile_of(state, q)

    def _quantile_of(self, state: dict, q: float) -> float:
        rank = q * state["count"]
        cum = 0
        prev = float("-inf")
        for bound, n in zip(self.buckets, state["counts"]):
            if n and cum + n >= rank:
                lo = max(prev, state["min"])
                hi = min(bound, state["max"])
                frac = min(1.0, max(0.0, (rank - cum) / n))
                return lo + (hi - lo) * frac
            cum += n
            prev = bound
        return state["max"]

    def snapshot(self) -> Dict[str, dict]:
        """Per-series summary with estimated quantiles:
        ``{count, sum, min, max, p50, p95, p99}`` (quantiles carry the
        ±bucket-width error documented on :meth:`quantile`)."""
        out: Dict[str, dict] = {}
        for key, st in self._series.items():
            out[key] = {
                "count": st["count"],
                "sum": st["sum"],
                "min": st["min"],
                "max": st["max"],
                "p50": self._quantile_of(st, 0.50),
                "p95": self._quantile_of(st, 0.95),
                "p99": self._quantile_of(st, 0.99),
            }
        return out


class MetricsRegistry:
    """Aggregation point for metric families owned by many components."""

    def __init__(self) -> None:
        self._metrics: list[_Metric] = []

    def register(self, metric: _Metric) -> _Metric:
        self._metrics.append(metric)
        return metric

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name+labels: value}`` map, summed across family instances.

        Histograms expand to ``<name>_count``, ``<name>_sum`` and cumulative
        ``<name>_bucket{le="..."}`` series. Deterministic: sorted keys, and
        summation order is registration order (ints stay ints).
        """
        out: Dict[str, float] = {}
        for m in self._metrics:
            if m.kind == "histogram":
                for key, st in m.series().items():
                    base = m.name + key
                    out[f"{base}_count"] = out.get(f"{base}_count", 0) + st["count"]
                    out[f"{base}_sum"] = out.get(f"{base}_sum", 0) + st["sum"]
                    cum = 0
                    for bound, n in zip(m.buckets, st["counts"]):
                        cum += n
                        le = "+Inf" if bound == float("inf") else repr(bound)
                        lk = f'{m.name}_bucket{{le="{le}"}}{key}'
                        out[lk] = out.get(lk, 0) + cum
            else:
                for key, v in m.series().items():
                    full = m.name + key
                    out[full] = out.get(full, 0) + v
        return {k: out[k] for k in sorted(out)}

    def value(self, name: str, **labels):
        """Sum of one series (by exact name + labels) across instances."""
        key = name + _series_key({k: str(v) for k, v in labels.items()})
        total = 0
        for m in self._metrics:
            if m.name == name and m.kind != "histogram":
                total += m.series().get(key[len(name):] or "", 0)
        return total

    def families(self) -> Dict[str, str]:
        """``{name: kind}`` for every registered family (deduped)."""
        return {m.name: m.kind for m in self._metrics}


class StatsShim:
    """Attribute-compatible stats object backed by real counters.

    Subclasses set ``_SUBSYSTEM`` and ``_FIELDS``; each field becomes a
    label-free :class:`Counter` named ``repro_<subsystem>_<field>``. Reads
    return plain numbers (ints stay ints), writes — including augmented
    assignment — route to the counter, so call sites and tests written
    against the old dataclasses keep working unchanged. Constructing one
    without a registry gives it a private registry (standalone use in unit
    tests); fleet wiring passes the shared registry so every component's
    numbers land in one snapshot.
    """

    _SUBSYSTEM = "misc"
    _FIELDS: Tuple[str, ...] = ()

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        object.__setattr__(self, "registry", registry if registry is not None else MetricsRegistry())
        counters: Dict[str, Counter] = {}
        object.__setattr__(self, "_counters", counters)
        for f in self._FIELDS:
            counters[f] = Counter(f"repro_{self._SUBSYSTEM}_{f}", registry=self.registry)

    def __getattr__(self, name: str):
        # Only reached when normal attribute lookup fails.
        counters = object.__getattribute__(self, "_counters")
        if name in counters:
            return counters[name].value
        raise AttributeError(f"{type(self).__name__} has no field {name!r}")

    def __setattr__(self, name: str, value) -> None:
        counters = self.__dict__.get("_counters")
        if counters is not None and name in counters:
            counters[name].set_total(value)
        else:
            object.__setattr__(self, name, value)

    def counter(self, name: str) -> Counter:
        return self._counters[name]

    def as_dict(self) -> Dict[str, float]:
        return {f: self._counters[f].value for f in self._FIELDS}

    def __repr__(self) -> str:  # keeps debug output close to the old dataclasses
        body = ", ".join(f"{f}={self._counters[f].value}" for f in self._FIELDS)
        return f"{type(self).__name__}({body})"

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsShim):
            return self.as_dict() == other.as_dict()
        return NotImplemented
