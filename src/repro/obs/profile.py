"""Continuous critical-path profiler over the deterministic span stream.

Answers "where did this cohort's time go" without sampling: every finished
serve trace is folded into a per-stage critical path using the parent/attempt
chains the tracer already stamps (DESIGN.md §11), then aggregated into a
deterministic self-time profile keyed by (serve temperature, modality,
stage).

Stage attribution for a cold serve of one ticket, all derived from the
broker/worker span chain (the same reconstruction the ``--trace`` epilogue
of ``examples/deid_at_scale.py`` prints):

* ``retry``        — first publish → this attempt's entry (publish/redeliver)
* ``queue``        — entry → broker lease
* ``fetch``        — ``worker.fetch`` span (source read + decode)
* ``deid``         — ``worker.deid`` span; under SimClock the child span is
                     zero-width, so the modeled ``busy_s`` attribute wins
* ``entropy_code`` — ``kernel.entropy_code`` spans within the trace
* ``deliver``      — ``worker.deliver`` span
* ``writeback``    — ``worker.writeback`` span
* ``other``        — end-to-end remainder not attributed above

Warm serves have no worker chain; their admission cost is attributed to the
``admit`` stage from the ``service.submit_cohort`` span. Folding is
idempotent per span sequence number — feeding the same tracer again is a
no-op — so the profiler can run continuously at whatever cadence the fleet
reports. The profile, its folded flame export, and the Chrome-trace export
all pass through the PHI-safe :class:`~repro.obs.export.Redactor`, and
:meth:`digest` is bit-stable for a given trace (the sim's ``SloConformance``
checker relies on that).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.export import Redactor
from repro.obs.trace import Span, _canonical, trace_id_for

STAGES = (
    "retry",
    "queue",
    "fetch",
    "deid",
    "entropy_code",
    "deliver",
    "writeback",
    "admit",
    "other",
)

_CHILD_STAGES = (
    ("worker.fetch", "fetch"),
    ("worker.deid", "deid"),
    ("worker.deliver", "deliver"),
    ("worker.writeback", "writeback"),
)


class CriticalPathProfiler:
    """Folds finished spans into a (temperature, modality, stage) profile."""

    def __init__(self) -> None:
        # (temperature, modality, stage) -> [total_s, count]
        self._cells: Dict[Tuple[str, str, str], List[float]] = {}
        self._folded: set = set()  # span seqs already attributed
        self.traces_folded = 0
        self.spans_seen = 0

    # ------------------------------------------------------------------ fold
    def fold(self, spans: Iterable[Span]) -> int:
        """Attribute every not-yet-folded completed serve; returns how many
        new traces were folded this call."""
        spans = sorted(spans, key=lambda s: s.seq)
        self.spans_seen = max(self.spans_seen, len(spans))
        # a superseded key is re-published under the same (key, attempt)
        # trace ids, so every per-trace index is a seq-ordered LIST and each
        # ack reads only the window belonging to its own generation — the
        # one opened by the latest attempt-1 publish preceding the ack
        publishes: Dict[str, List[Span]] = {}
        entries: Dict[str, List[Span]] = {}  # publish-or-redeliver per attempt
        leases: Dict[str, List[Span]] = {}
        procs: Dict[str, List[Span]] = {}
        children: Dict[str, List[Span]] = {}
        entropy: Dict[str, List[Span]] = {}
        for s in spans:
            if s.name == "broker.publish":
                publishes.setdefault(s.trace_id, []).append(s)
                entries.setdefault(s.trace_id, []).append(s)
            elif s.name == "broker.redeliver":
                entries.setdefault(s.trace_id, []).append(s)
            elif s.name == "broker.lease":
                leases.setdefault(s.trace_id, []).append(s)
            elif s.name == "worker.process":
                procs.setdefault(s.trace_id, []).append(s)
            elif s.name == "kernel.entropy_code":
                entropy.setdefault(s.trace_id, []).append(s)
            if s.parent_id is not None:
                children.setdefault(s.parent_id, []).append(s)

        new_traces = 0
        for s in spans:
            if s.name == "broker.ack" or s.name == "service.submit_cohort":
                if s.seq in self._folded:
                    continue
                self._folded.add(s.seq)
                if s.name == "broker.ack":
                    if self._fold_cold(s, publishes, entries, leases, procs,
                                       children, entropy):
                        new_traces += 1
                else:
                    self._add("warm", "NA", "admit", s.duration)
                    new_traces += 1
        self.traces_folded += new_traces
        return new_traces

    @staticmethod
    def _in_window(group, lo: int, hi: int, last: bool = False):
        """First (or last) span in a seq-ordered group with lo <= seq <= hi."""
        picked = None
        for s in group or ():
            if s.seq > hi:
                break
            if s.seq >= lo:
                if not last:
                    return s
                picked = s
        return picked

    def _fold_cold(self, ack, publishes, entries, leases, procs, children,
                   entropy) -> bool:
        # this serve's generation: the latest attempt-1 publish before the ack
        first = self._in_window(
            publishes.get(trace_id_for(ack.attrs["key"], 1)),
            0, ack.seq, last=True,
        )
        if first is None:
            return False
        proc = self._in_window(procs.get(ack.trace_id), first.seq, ack.seq,
                               last=True)
        if proc is None or not proc.attrs.get("ok"):
            return False  # dedup ack / fence — no serve completed here
        entry = self._in_window(entries.get(ack.trace_id), first.seq, ack.seq)
        lease = self._in_window(leases.get(ack.trace_id), first.seq, ack.seq)
        if entry is None or lease is None:
            return False
        modality = "NA"
        stage_s: Dict[str, float] = {}
        stage_s["retry"] = max(0.0, entry.t0 - first.t0)
        stage_s["queue"] = max(0.0, lease.t0 - entry.t0)
        for child in children.get(proc.span_id, ()):
            for name, stage in _CHILD_STAGES:
                if child.name == name:
                    # under SimClock child spans are zero-width and the
                    # modeled busy time lives in attrs; take the larger
                    busy = child.attrs.get("busy_s", 0.0) or 0.0
                    stage_s[stage] = stage_s.get(stage, 0.0) + max(
                        child.duration, float(busy)
                    )
                    if child.name == "worker.fetch":
                        modality = str(child.attrs.get("modality") or "NA")
        for ks in entropy.get(ack.trace_id, ()):
            if first.seq <= ks.seq <= ack.seq:
                stage_s["entropy_code"] = (
                    stage_s.get("entropy_code", 0.0) + ks.duration
                )
        e2e = ack.t1 - first.t0
        stage_s["other"] = max(0.0, e2e - sum(stage_s.values()))
        for stage, secs in stage_s.items():
            self._add("cold", modality, stage, secs)
        return True

    def _add(self, temperature: str, modality: str, stage: str, secs: float) -> None:
        cell = self._cells.setdefault((temperature, modality, stage), [0.0, 0])
        cell[0] += secs
        cell[1] += 1

    # ------------------------------------------------------------- reporting
    def profile(self) -> Dict[str, Dict[str, Dict[str, Dict[str, float]]]]:
        """temperature -> modality -> stage -> {total_s, count, frac}.

        ``frac`` is the stage's share of that (temperature, modality)'s total
        attributed time — the flame-graph width."""
        out: Dict[str, Dict[str, Dict[str, Dict[str, float]]]] = {}
        totals: Dict[Tuple[str, str], float] = {}
        for (temp, modality, _stage), (secs, _n) in self._cells.items():
            totals[(temp, modality)] = totals.get((temp, modality), 0.0) + secs
        for (temp, modality, stage), (secs, n) in sorted(self._cells.items()):
            denom = totals[(temp, modality)]
            out.setdefault(temp, {}).setdefault(modality, {})[stage] = {
                "total_s": round(secs, 9),
                "count": n,
                "frac": round(secs / denom, 9) if denom > 0 else 0.0,
            }
        return out

    def top_stages(self, n: int = 3) -> List[Tuple[str, float]]:
        """Stages by total attributed self-time, descending — the "top
        regressing stages" line of a HealthReport."""
        agg: Dict[str, float] = {}
        for (_t, _m, stage), (secs, _n) in self._cells.items():
            agg[stage] = agg.get(stage, 0.0) + secs
        ranked = sorted(agg.items(), key=lambda kv: (-kv[1], kv[0]))
        return [(stage, round(secs, 9)) for stage, secs in ranked[:n]]

    def digest(self) -> str:
        """SHA-256 of the canonical profile — bit-stable for a given trace."""
        payload = {"traces": self.traces_folded, "profile": self.profile()}
        line = json.dumps(_canonical(payload), sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(line.encode()).hexdigest()

    # --------------------------------------------------------------- exports
    def export_folded(self, redactor: Optional[Redactor] = None) -> str:
        """Flame-graph "folded" format: ``temp;modality;stage <microseconds>``
        per line. All frame names cross the redactor's value policy."""
        red = redactor if redactor is not None else Redactor()
        lines = []
        for (temp, modality, stage), (secs, _n) in sorted(self._cells.items()):
            frames = ";".join(
                str(red.safe_value(part)) for part in (temp, modality, stage)
            )
            lines.append(f"{frames} {int(round(secs * 1e6))}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_chrome_trace(self, redactor: Optional[Redactor] = None) -> Dict[str, object]:
        """Aggregate profile as a Chrome trace: one track per (temperature,
        modality), stages laid end-to-end by attributed time. Reuses the
        PHI-safe span exporter rather than emitting attrs directly."""
        from repro.obs.export import to_chrome_trace

        red = redactor if redactor is not None else Redactor()
        synth: List[Span] = []
        seq = 0
        for (temp, modality), group in self._by_track().items():
            # the track label flows into the trace's ``cat`` field, which the
            # span exporter does not re-validate — sanitize it here
            track = red.safe_value(modality)
            cursor = 0.0
            for stage, secs, n in group:
                seq += 1
                synth.append(Span(
                    trace_id=f"profile-{temp}-{track}",
                    span_id=f"p{seq:08d}",
                    parent_id=None,
                    name=f"profile.{stage}",
                    t0=cursor,
                    t1=cursor + secs,
                    seq=seq,
                    attrs={"stage": stage, "modality": modality,
                           "mode": temp, "n": n},
                ))
                cursor += secs
        return to_chrome_trace(synth, red)

    def _by_track(self) -> Dict[Tuple[str, str], List[Tuple[str, float, int]]]:
        out: Dict[Tuple[str, str], List[Tuple[str, float, int]]] = {}
        for (temp, modality, stage), (secs, n) in sorted(self._cells.items()):
            out.setdefault((temp, modality), []).append((stage, secs, n))
        return out
