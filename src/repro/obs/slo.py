"""Streaming SLO engine with multi-window burn-rate alerting (DESIGN.md §13).

The observability plane's first *consumer*: PR 7 records everything, this
module decides whether the fleet is keeping its promises. Declarative
:class:`SloSpec` objectives (cold-serve latency per modality, warm-hit
latency, cohort end-to-end, ingest freshness, DLQ rate) are evaluated
incrementally from the same event stream the trace/metric layers see, using
the standard SRE multi-window multi-burn-rate scheme:

* a **burn rate** is the bad-event fraction over a window divided by the
  budgeted bad fraction ``1 - objective`` — burn 1.0 consumes the error
  budget exactly at the sustainable rate, burn N consumes it N× too fast;
* an alert **fires** only when BOTH the long and the short window of a
  :class:`BurnRule` exceed the rule's threshold (the long window gives
  confidence, the short window makes the alert resolve quickly once the
  regression stops), and **resolves** when the short window recovers;
* the canonical production windows are the fast 5m/1h pair and the slow
  6h/3d pair (:func:`default_burn_rules`); simulated fleets pass a
  ``scale`` so the same shape fits a ~600 s horizon.

Determinism contract (same as the tracer): the engine owns no clock — every
``observe``/``evaluate`` call carries its timestamp — so the full alert
sequence is a pure function of (specs, observation log, evaluation times).
:meth:`SloEngine.replay` rebuilds a fresh engine from those inputs and must
reproduce the alert list bit-for-bit; the sim's ``SloConformance`` checker
enforces exactly that, plus a cross-check of cold-serve observations against
latencies re-derived from the span stream (:func:`derive_serve_observations`)
— every alert is recomputable from the trace.
"""
from __future__ import annotations

import hashlib
import json
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import Span, _canonical, trace_id_for


@dataclass(frozen=True)
class BurnRule:
    """One multi-window burn-rate alerting rule.

    Fires when the burn rate over BOTH ``long_window`` and ``short_window``
    is >= ``threshold``; resolves when the short window drops back under.
    """

    long_window: float
    short_window: float
    threshold: float
    severity: str = "page"

    def __post_init__(self) -> None:
        if self.short_window > self.long_window:
            raise ValueError(
                f"short window {self.short_window} > long window {self.long_window}"
            )
        if self.threshold <= 0:
            raise ValueError(f"burn threshold must be > 0, got {self.threshold}")


def default_burn_rules(scale: float = 1.0) -> Tuple[BurnRule, ...]:
    """The SRE fast (5m/1h, page) + slow (6h/3d, ticket) window pairs.

    ``scale`` shrinks every window by the same factor so a simulated fleet
    with a ~600 s horizon alerts with the same *shape* a production fleet
    would over days (the sim default is 1/60: 1 h becomes 60 s).
    """
    return (
        BurnRule(3600.0 * scale, 300.0 * scale, 6.0, "page"),
        BurnRule(259200.0 * scale, 21600.0 * scale, 2.0, "ticket"),
    )


@dataclass(frozen=True)
class SloSpec:
    """A declarative service-level objective.

    ``objective`` is the required good-event fraction. ``threshold`` turns a
    value observation into good/bad (``value <= threshold`` is good); counts
    observed via :meth:`SloEngine.observe_counts` skip it. ``kind`` routes
    health-controller policy ("latency" SLOs feed the autoscaler's burn
    pressure signal); ``budget_window`` is the error-budget accounting
    horizon reported by :meth:`SloEngine.budget_remaining`.
    """

    name: str
    objective: float = 0.99
    threshold: Optional[float] = None
    unit: str = "s"
    kind: str = "latency"
    rules: Tuple[BurnRule, ...] = field(default_factory=default_burn_rules)
    budget_window: float = 86400.0

    def __post_init__(self) -> None:
        if not (0.0 < self.objective < 1.0):
            raise ValueError(f"objective must be in (0, 1), got {self.objective}")
        if not self.rules:
            raise ValueError(f"SLO {self.name!r} has no burn rules")


@dataclass(frozen=True)
class AlertEvent:
    """One deterministic fire/resolve transition of one (SLO, rule) pair."""

    t: float
    slo: str
    rule: int          # index into the spec's rules tuple
    action: str        # "fire" | "resolve"
    severity: str
    burn_long: float
    burn_short: float

    def to_dict(self) -> Dict[str, object]:
        return {
            "t": self.t,
            "slo": self.slo,
            "rule": self.rule,
            "action": self.action,
            "severity": self.severity,
            "burn_long": self.burn_long,
            "burn_short": self.burn_short,
        }


class _SloSeries:
    """Per-SLO observation stream with O(1)-amortized window sums: parallel
    time/prefix arrays (observation times are required non-decreasing, which
    every clock-driven caller satisfies by construction)."""

    __slots__ = ("times", "cum_bad", "cum_total")

    def __init__(self) -> None:
        self.times: List[float] = []
        self.cum_bad: List[int] = [0]
        self.cum_total: List[int] = [0]

    def add(self, t: float, bad: int, total: int) -> None:
        if self.times and t < self.times[-1]:
            raise ValueError(
                f"observation at t={t} before the previous one at {self.times[-1]}"
            )
        self.times.append(t)
        self.cum_bad.append(self.cum_bad[-1] + bad)
        self.cum_total.append(self.cum_total[-1] + total)

    def window(self, t: float, w: float) -> Tuple[int, int]:
        """(bad, total) over observations with time in (t - w, t]."""
        lo = bisect_right(self.times, t - w)
        hi = bisect_right(self.times, t)
        return (
            self.cum_bad[hi] - self.cum_bad[lo],
            self.cum_total[hi] - self.cum_total[lo],
        )


class SloEngine:
    """Incremental SLO evaluator; every output replays from its own log.

    Feed it with :meth:`observe` (one value or good/bad event) or
    :meth:`observe_counts` (batched good/bad deltas, e.g. DLQ vs ack counts
    per tick), then call :meth:`evaluate` at whatever cadence the fleet
    ticks; newly emitted :class:`AlertEvent`\\ s are returned AND retained in
    :attr:`alerts`. The engine is clockless and allocation-light — the hot
    path is two list appends and a counter increment.
    """

    def __init__(self, specs: Iterable[SloSpec] = (), registry=None) -> None:
        self.specs: Dict[str, SloSpec] = {}
        self._series: Dict[str, _SloSeries] = {}
        # replay inputs: everything alerts are a function of
        self.obs_log: List[Dict[str, object]] = []
        self.eval_log: List[float] = []
        self.alerts: List[AlertEvent] = []
        self._active: Dict[Tuple[str, int], bool] = {}
        self._metrics = None
        if registry is not None:
            from repro.obs.metrics import Counter

            self._metrics = {
                "observations": Counter("repro_slo_observations", registry=registry),
                "alerts_fired": Counter("repro_slo_alerts_fired", registry=registry),
                "alerts_resolved": Counter(
                    "repro_slo_alerts_resolved", registry=registry
                ),
            }
        for spec in specs:
            self.ensure(spec)

    # ------------------------------------------------------------------ specs
    def ensure(self, spec: SloSpec) -> SloSpec:
        """Idempotently register a spec (dynamic per-modality objectives are
        minted from a template on first observation). First registration
        wins; the insertion order is part of the deterministic contract."""
        if spec.name not in self.specs:
            self.specs[spec.name] = spec
            self._series[spec.name] = _SloSeries()
        return self.specs[spec.name]

    # ----------------------------------------------------------- observations
    def observe(
        self,
        name: str,
        t: float,
        value: Optional[float] = None,
        good: Optional[bool] = None,
    ) -> bool:
        """Record one event; returns whether it counted as good. Either pass
        ``value`` (judged against the spec's threshold) or ``good``."""
        spec = self.specs[name]
        if good is None:
            if value is None:
                raise ValueError(f"observe({name!r}) needs value= or good=")
            good = spec.threshold is None or value <= spec.threshold
        self._ingest(name, t, value, 0 if good else 1, 1)
        return bool(good)

    def observe_counts(self, name: str, t: float, good: int = 0, bad: int = 0) -> None:
        """Record a batch of pre-judged events (e.g. per-tick ack/DLQ deltas)."""
        if name not in self.specs:
            raise KeyError(f"unknown SLO {name!r}")
        if good < 0 or bad < 0:
            raise ValueError(f"negative counts good={good} bad={bad}")
        if good + bad == 0:
            return
        self._ingest(name, t, None, bad, good + bad)

    def _ingest(
        self, name: str, t: float, value: Optional[float], bad: int, total: int
    ) -> None:
        self._series[name].add(t, bad, total)
        self.obs_log.append(
            {"t": t, "slo": name, "value": value, "bad": bad, "total": total}
        )
        if self._metrics is not None:
            self._metrics["observations"].inc(total)

    # ------------------------------------------------------------- evaluation
    def burn_rate(self, name: str, window: float, t: float) -> float:
        """Bad fraction over the window divided by the budgeted bad fraction
        (``1 - objective``); 0.0 when the window holds no observations."""
        spec = self.specs[name]
        bad, total = self._series[name].window(t, window)
        if total == 0:
            return 0.0
        return (bad / total) / (1.0 - spec.objective)

    def evaluate(self, t: float) -> List[AlertEvent]:
        """Run the fire/resolve state machine for every (spec, rule) pair at
        time ``t``; returns (and records) the newly emitted transitions."""
        self.eval_log.append(t)
        new: List[AlertEvent] = []
        for name, spec in self.specs.items():
            for ri, rule in enumerate(spec.rules):
                burn_long = self.burn_rate(name, rule.long_window, t)
                burn_short = self.burn_rate(name, rule.short_window, t)
                key = (name, ri)
                active = self._active.get(key, False)
                if not active and burn_long >= rule.threshold and burn_short >= rule.threshold:
                    self._active[key] = True
                    new.append(AlertEvent(
                        t, name, ri, "fire", rule.severity, burn_long, burn_short
                    ))
                elif active and burn_short < rule.threshold:
                    self._active[key] = False
                    new.append(AlertEvent(
                        t, name, ri, "resolve", rule.severity, burn_long, burn_short
                    ))
        self.alerts.extend(new)
        if self._metrics is not None:
            for ev in new:
                which = "alerts_fired" if ev.action == "fire" else "alerts_resolved"
                self._metrics[which].inc()
        return new

    # -------------------------------------------------------------- reporting
    def active_alerts(self) -> List[Tuple[str, int]]:
        return sorted(k for k, v in self._active.items() if v)

    def state(self, name: str) -> str:
        return "burning" if any(s == name for s, _ in self.active_alerts()) else "ok"

    def states(self) -> Dict[str, str]:
        return {name: self.state(name) for name in self.specs}

    def budget_remaining(self, name: str, t: float) -> float:
        """Fraction of the error budget left over the spec's budget window:
        1.0 = untouched, 0.0 = exhausted, negative = overdrawn. A window with
        no traffic has a full budget."""
        spec = self.specs[name]
        bad, total = self._series[name].window(t, spec.budget_window)
        if total == 0:
            return 1.0
        allowed = total * (1.0 - spec.objective)
        return 1.0 - bad / allowed

    def digest(self) -> str:
        """SHA-256 over the canonical JSONL of the alert sequence (same float
        rounding contract as the tracer/EventLog digests)."""
        h = hashlib.sha256()
        for a in self.alerts:
            line = json.dumps(
                _canonical(a.to_dict()), sort_keys=True, separators=(",", ":")
            )
            h.update(line.encode())
            h.update(b"\n")
        return h.hexdigest()

    # ----------------------------------------------------------------- replay
    def replay(self) -> "SloEngine":
        """Rebuild a fresh engine from this engine's own recorded inputs.

        The returned engine's :attr:`alerts` must equal this one's — the
        SloConformance invariant. Any tampering with the alert list (or any
        hidden state the alerts secretly depended on) breaks the equality.
        """
        fresh = SloEngine(self.specs.values())
        events = (
            [("obs", rec["t"], rec) for rec in self.obs_log]
            + [("eval", t, None) for t in self.eval_log]
        )
        # interleave by time; same-time observations land before the same-time
        # evaluation, matching the live call order (observe happens first in
        # every tick handler), with the original per-stream order preserved
        events.sort(key=lambda e: (e[1], 0 if e[0] == "obs" else 1))
        for kind, t, rec in events:
            if kind == "obs":
                fresh._ingest(rec["slo"], t, rec["value"], rec["bad"], rec["total"])
            else:
                fresh.evaluate(t)
        return fresh


def derive_serve_observations(spans: Iterable[Span]) -> List[Tuple[float, str, float]]:
    """Re-derive every cold-serve latency from the span stream alone.

    For each acked delivery whose ``worker.process`` span completed with
    ``ok`` (a journaled completion, not a dedup/fence/zombie), the end-to-end
    latency is ``ack.t1 - first_publish.t0`` — the same quantity the fleet
    observes live from ``Message.publish_time`` (which survives redelivery
    and speculative cloning). Returns ``(t, key, latency)`` sorted by the
    ack's span sequence, so the list is bit-stable for a given trace.

    This is the SloConformance cross-check: the SLO engine's cold-serve
    observation stream must equal this reconstruction exactly, which makes
    every latency alert recomputable from the trace.
    """
    spans = list(spans)
    publishes: Dict[str, List[Span]] = {}
    procs: Dict[str, Span] = {}
    for s in spans:
        if s.name == "broker.publish":
            publishes.setdefault(s.trace_id, []).append(s)
        elif s.name == "worker.process":
            procs[s.trace_id] = s
    for group in publishes.values():
        group.sort(key=lambda s: s.seq)
    out: List[Tuple[int, float, str, float]] = []
    for s in spans:
        if s.name != "broker.ack":
            continue
        proc = procs.get(s.trace_id)
        if proc is None or not proc.attrs.get("ok"):
            continue  # dedup ack, fence, or zombie-raced clone
        # a superseded key is re-published under the same (key, attempt 1)
        # trace id — each serve starts at the LATEST publish preceding its
        # ack, which is exactly the Message.publish_time the fleet sees live
        group = publishes.get(trace_id_for(s.attrs["key"], 1))
        if not group:
            continue
        first = None
        for pub in group:
            if pub.seq > s.seq:
                break
            first = pub
        if first is None:
            continue
        out.append((s.seq, s.t1, s.attrs["key"], s.t1 - first.t0))
    out.sort()
    return [(t, key, latency) for _, t, key, latency in out]
