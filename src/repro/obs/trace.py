"""Deterministic tracing with explicit context propagation.

Design constraints, in order:

1. **Bit-replayability.** Timestamps come from the injected clock and ids are
   derived, never random: a span id is the tracer's start-sequence counter,
   and a work-item trace id is ``trace_id_for(key, attempt)`` — a SHA-256 of
   the ticket key + delivery attempt. A seeded FleetSim run therefore
   produces a bit-identical ``digest()``, which the sim enforces as an
   invariant.
2. **Zero overhead when disabled.** ``NULL_TRACER`` is a module singleton
   whose ``span()`` returns one shared no-op context manager — no clock
   reads, no allocation beyond the call itself, no behavior change.
3. **Single-threaded context.** The whole stack is step-driven off one event
   loop, so the active-span *stack* is the context: a span opened inside
   another parents to it automatically; roots name their trace explicitly.

Spans never carry free-text values from data; attributes cross the
:mod:`repro.obs.export` redactor before leaving the process.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


def trace_id_for(key: str, attempt: int = 1) -> str:
    """Deterministic trace id for one delivery attempt of one work item."""
    return hashlib.sha256(f"trace|{key}|{attempt}".encode()).hexdigest()[:16]


@dataclass
class Span:
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    name: str
    t0: float
    t1: Optional[float] = None
    seq: int = 0
    attrs: Dict[str, object] = field(default_factory=dict)

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def to_dict(self) -> Dict[str, object]:
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "seq": self.seq,
            "attrs": self.attrs,
        }


class _ActiveSpan:
    """Context manager handle for an open span."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self.span = span

    def set(self, **attrs) -> "_ActiveSpan":
        self.span.attrs.update(attrs)
        return self

    def __enter__(self) -> "_ActiveSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.span.attrs["error"] = exc_type.__name__
        self._tracer._finish(self.span)
        # exceptions propagate


class _NoopSpan:
    """Shared do-nothing handle used by :class:`NullTracer`."""

    __slots__ = ()
    span = None

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


def _canonical(obj):
    """Round floats (9 places) so digests survive re-serialization."""
    if isinstance(obj, float):
        return round(obj, 9)
    if isinstance(obj, dict):
        return {k: _canonical(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    return obj


class Tracer:
    """Clock-injected span recorder with a LIFO active-span stack."""

    enabled = True

    def __init__(self, clock) -> None:
        self.clock = clock
        self.finished: List[Span] = []
        self._stack: List[Span] = []
        self._seq = 0

    # -- recording ---------------------------------------------------------

    def span(self, name: str, trace_id: Optional[str] = None, **attrs) -> _ActiveSpan:
        """Open a span. Parents to the innermost open span; a root span with
        no explicit ``trace_id`` gets one minted from its own sequence number
        (deterministic)."""
        self._seq += 1
        seq = self._seq
        parent = self._stack[-1] if self._stack else None
        if trace_id is None:
            trace_id = parent.trace_id if parent is not None else f"root{seq:08d}"
        span = Span(
            trace_id=trace_id,
            span_id=f"s{seq:08d}",
            parent_id=parent.span_id if parent is not None and parent.trace_id == trace_id else None,
            name=name,
            t0=self.clock.now(),
            seq=seq,
            attrs=dict(attrs),
        )
        self._stack.append(span)
        return _ActiveSpan(self, span)

    def event(self, name: str, trace_id: Optional[str] = None, **attrs) -> Span:
        """Instant (zero-duration) span, e.g. a broker publish or an ack."""
        with self.span(name, trace_id=trace_id, **attrs) as h:
            return h.span

    def _finish(self, span: Span) -> None:
        # Tolerate out-of-order exits defensively, but the integrity checker
        # treats any still-open span at end of run as a violation.
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        elif span in self._stack:  # pragma: no cover - misuse guard
            self._stack.remove(span)
        span.t1 = self.clock.now()
        self.finished.append(span)

    # -- inspection --------------------------------------------------------

    @property
    def open_count(self) -> int:
        return len(self._stack)

    def spans(self, name: Optional[str] = None) -> List[Span]:
        if name is None:
            return list(self.finished)
        return [s for s in self.finished if s.name == name]

    def traces(self) -> Dict[str, List[Span]]:
        out: Dict[str, List[Span]] = {}
        for s in self.finished:
            out.setdefault(s.trace_id, []).append(s)
        return out

    def digest(self) -> str:
        """SHA-256 over the canonical JSONL of finished spans (finish order).

        Floats round to 9 places (same contract as the sim EventLog) so the
        digest is stable under serialization round-trips.
        """
        h = hashlib.sha256()
        for s in self.finished:
            line = json.dumps(_canonical(s.to_dict()), sort_keys=True, separators=(",", ":"))
            h.update(line.encode())
            h.update(b"\n")
        return h.hexdigest()

    def clear(self) -> None:
        self.finished.clear()
        self._stack.clear()
        self._seq = 0


class NullTracer:
    """No-op tracer: the disabled mode. Never touches the clock."""

    enabled = False
    clock = None

    def span(self, name: str, trace_id: Optional[str] = None, **attrs) -> _NoopSpan:
        return _NOOP_SPAN

    def event(self, name: str, trace_id: Optional[str] = None, **attrs) -> None:
        return None

    @property
    def finished(self) -> List[Span]:
        return []

    @property
    def open_count(self) -> int:
        return 0

    def spans(self, name: Optional[str] = None) -> List[Span]:
        return []

    def traces(self) -> Dict[str, List[Span]]:
        return {}

    def digest(self) -> str:
        return Tracer.digest(self)  # digest of zero spans

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
