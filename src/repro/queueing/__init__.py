# Publish/subscribe control plane (paper §Method b-d): message broker with
# leases + DLQ, backlog/window autoscaler, drain workers, exactly-once journal.
from repro.queueing.broker import Broker, Message, QueueStats
from repro.queueing.autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent
from repro.queueing.journal import Journal
from repro.queueing.worker import DeidWorker, WorkerPool, FailureInjector

__all__ = [
    "Broker",
    "Message",
    "QueueStats",
    "Autoscaler",
    "AutoscalerConfig",
    "ScaleEvent",
    "Journal",
    "DeidWorker",
    "WorkerPool",
    "FailureInjector",
]
