"""Auto-scaling policy (paper §Method c): "instantiates an appropriate number
of de-identification compute instances based on the size of the message queue
... and the expected delivery window", deleting instances when the queue is
empty.

``target = clamp(ceil(backlog_bytes / (per_instance_throughput × remaining
window)), min, max)`` with hysteresis (scale-down cooldown) so lease churn
doesn't thrash the pool — the cloud-VM analogue of avoiding TPU slice
reallocation storms. Scale events drive the elastic farm re-mesh in
`repro.distributed.elastic`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.queueing.broker import Broker
from repro.utils.timing import SimClock


@dataclass
class AutoscalerConfig:
    delivery_window: float = 3600.0          # seconds to drain the request (SLA)
    per_instance_throughput: float = 160e6   # bytes/s (paper: 1.25 GB/s / 8 instances)
    min_instances: int = 0
    max_instances: int = 64
    scale_down_cooldown: float = 120.0       # hysteresis
    instance_cost_per_hour: float = 0.85     # USD, calibrated to paper Table 1


@dataclass
class ScaleEvent:
    t: float
    old: int
    new: int
    backlog_bytes: int
    reason: str


class Autoscaler:
    def __init__(self, broker: Broker, config: AutoscalerConfig, clock: Optional[SimClock] = None) -> None:
        self.broker = broker
        self.config = config
        self.clock = clock or broker.clock
        self.current = 0
        # optional burn-rate pressure signal (DESIGN.md §13): a zero-arg
        # callable returning a multiplier >= 1.0 (e.g. HealthController
        # .pressure). While > 1, the backlog-derived target is multiplied up
        # so a burning latency SLO buys capacity that queue depth alone
        # would not request. None = pure backlog scaling (the default).
        self.pressure_fn = None
        self.events: List[ScaleEvent] = []
        self._window_start: Optional[float] = None
        self._last_scale_down: float = -math.inf
        self.instance_seconds = 0.0  # integral for the cost model
        self._last_tick: Optional[float] = None
        # (tick time, pool size after the tick): the piecewise-constant record
        # the conformance suite re-integrates to audit instance_seconds
        self.tick_log: List[Tuple[float, int]] = []

    def target_for(self, backlog_bytes: int) -> int:
        cfg = self.config
        if backlog_bytes <= 0:
            return cfg.min_instances
        if self._window_start is None:
            self._window_start = self.clock.now()
        elapsed = self.clock.now() - self._window_start
        remaining = max(cfg.delivery_window - elapsed, 60.0)  # never divide by ~0
        need = math.ceil(backlog_bytes / (cfg.per_instance_throughput * remaining))
        return max(cfg.min_instances, min(cfg.max_instances, need))

    def tick(self) -> int:
        """Re-evaluate the pool size. Returns the (possibly new) instance count."""
        now = self.clock.now()
        if self._last_tick is not None:
            self.instance_seconds += self.current * (now - self._last_tick)
        self._last_tick = now

        stats = self.broker.stats()
        target = self.target_for(stats.backlog_bytes)
        reason = "scale-up"
        if stats.outstanding > 0 and self.pressure_fn is not None:
            pressure = self.pressure_fn()
            if pressure > 1.0:
                boosted = min(self.config.max_instances,
                              math.ceil(max(target, 1) * pressure))
                if boosted > target:
                    target = boosted
                    reason = "burn-scale-up"
        if stats.outstanding == 0:
            target = self.config.min_instances  # paper: delete when queue empty
            self._window_start = None
        if target > self.current:
            self.events.append(ScaleEvent(now, self.current, target, stats.backlog_bytes, reason))
            self.current = target
        elif target < self.current:
            if now - self._last_scale_down >= self.config.scale_down_cooldown or target == 0:
                self.events.append(ScaleEvent(now, self.current, target, stats.backlog_bytes, "scale-down"))
                self.current = target
                self._last_scale_down = now
        self.tick_log.append((now, self.current))
        return self.current

    def cost_usd(self) -> float:
        return self.instance_seconds / 3600.0 * self.config.instance_cost_per_hour
