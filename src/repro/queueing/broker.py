"""Publish/subscribe message broker with cloud Pub/Sub semantics.

The paper's pipeline "listens for de-identification requests using a
publish/subscribe messaging model". We reproduce the semantics that matter
for correctness at scale — **at-least-once delivery** with visibility-timeout
leases, nack/redelivery, a dead-letter queue after ``max_deliveries``, and
backlog statistics the autoscaler consumes — as a deterministic in-process
simulation driven by an injectable clock (`repro.utils.timing.SimClock`).

Exactly-once *effect* is layered on top by `repro.queueing.journal` (dedup on
message key), the standard cloud pattern.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import DEAD_LETTER as AUDIT_DEAD_LETTER
from repro.obs.metrics import StatsShim
from repro.obs.trace import NULL_TRACER, trace_id_for
from repro.utils.timing import SimClock


@dataclass
class Message:
    key: str                  # stable identity (accession), dedup handle
    payload: Any
    nbytes: int = 0           # payload size estimate for backlog stats
    msg_id: int = 0
    deliveries: int = 0
    publish_time: float = 0.0
    lease_deadline: Optional[float] = None
    lease_owner: Optional[str] = None


@dataclass
class QueueStats:
    outstanding: int      # available + leased (not yet acked)
    available: int
    leased: int
    dead_lettered: int
    backlog_bytes: int    # live work only — DLQ'd payloads are excluded, so
                          # the autoscaler never scales against dead work
    oldest_publish_time: Optional[float]
    dead_letter_bytes: int = 0  # poisoned payload bytes, reported separately


class BrokerCounters(StatsShim):
    """Lifetime broker counters as real metrics (``repro_broker_*``).

    ``deliveries`` counts leases handed out by :meth:`Broker.pull` and
    ``speculative_clones`` counts :meth:`Broker.speculative_redeliver` copies
    — together they close the conservation identities the sim's
    ``MetricsConservation`` checker audits.
    """

    _SUBSYSTEM = "broker"
    _FIELDS = (
        "published",
        "acked",
        "redelivered",
        "deliveries",
        "speculative_clones",
        "dead_lettered",
    )


class Broker:
    def __init__(
        self,
        clock: Optional[SimClock] = None,
        visibility_timeout: float = 120.0,
        max_deliveries: int = 5,
        tracer=None,
        registry=None,
        ledger=None,
    ) -> None:
        self.clock = clock or SimClock()
        self.visibility_timeout = visibility_timeout
        self.max_deliveries = max_deliveries
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.ledger = ledger if ledger is not None else NULL_LEDGER
        self.counters = BrokerCounters(registry)
        self._ids = itertools.count(1)
        self._available: List[Message] = []
        self._leased: Dict[int, Message] = {}
        self._acked_keys: set[str] = set()
        self.dead_letter: List[Message] = []

    # lifetime counters kept as properties so existing `broker.total_*`
    # call sites (and += writes) keep working on top of the metrics shim
    @property
    def total_published(self) -> int:
        return self.counters.published

    @total_published.setter
    def total_published(self, v: int) -> None:
        self.counters.published = v

    @property
    def total_acked(self) -> int:
        return self.counters.acked

    @total_acked.setter
    def total_acked(self, v: int) -> None:
        self.counters.acked = v

    @property
    def total_redelivered(self) -> int:
        return self.counters.redelivered

    @total_redelivered.setter
    def total_redelivered(self, v: int) -> None:
        self.counters.redelivered = v

    # ------------------------------------------------------------ publish
    def publish(self, key: str, payload: Any, nbytes: int = 0) -> int:
        msg = Message(
            key=key,
            payload=payload,
            nbytes=nbytes,
            msg_id=next(self._ids),
            publish_time=self.clock.now(),
        )
        self._available.append(msg)
        self.total_published += 1
        # the work item's first delivery attempt owns this trace id; the
        # publish event carries it so a trace links submit -> worker
        self.tracer.event(
            "broker.publish",
            trace_id=trace_id_for(key, 1),
            key=key,
            nbytes=nbytes,
        )
        return msg.msg_id

    # -------------------------------------------------------------- lease
    def _expire_leases(self) -> None:
        now = self.clock.now()
        expired = [m for m in self._leased.values() if m.lease_deadline is not None and m.lease_deadline <= now]
        for m in expired:
            del self._leased[m.msg_id]
            m.lease_owner = None
            m.lease_deadline = None
            if m.deliveries >= self.max_deliveries:
                self.dead_letter.append(m)
                self.counters.dead_lettered += 1
                self.tracer.event(
                    "broker.dead_letter",
                    trace_id=trace_id_for(m.key, m.deliveries),
                    key=m.key,
                    deliveries=m.deliveries,
                )
                self.ledger.append(
                    AUDIT_DEAD_LETTER, key=m.key, deliveries=m.deliveries, reason="lease_expired"
                )
            else:
                # fresh id per delivery = per-delivery ack token: a stale ack
                # from the crashed owner can never ack the new lease
                m.msg_id = next(self._ids)
                self._available.append(m)
                self.total_redelivered += 1
                self.tracer.event(
                    "broker.redeliver",
                    trace_id=trace_id_for(m.key, m.deliveries + 1),
                    key=m.key,
                    deliveries=m.deliveries,
                    kind="lease_expired",
                )

    def pull(self, worker_id: str, max_messages: int = 1) -> List[Message]:
        """Lease up to ``max_messages``; invisible to others until ack/timeout.
        Returns per-delivery *receipts* (copies): msg_id acts as the ack token
        for this delivery only, like cloud Pub/Sub ack ids."""
        self._expire_leases()
        out: List[Message] = []
        while self._available and len(out) < max_messages:
            msg = self._available.pop(0)
            msg.deliveries += 1
            msg.lease_owner = worker_id
            msg.lease_deadline = self.clock.now() + self.visibility_timeout
            self._leased[msg.msg_id] = msg
            self.counters.deliveries += 1
            self.tracer.event(
                "broker.lease",
                trace_id=trace_id_for(msg.key, msg.deliveries),
                key=msg.key,
                deliveries=msg.deliveries,
                worker=worker_id,
                visibility=self.visibility_timeout,
            )
            out.append(Message(**vars(msg)))
        return out

    def extend_lease(self, msg_id: int, extra: float) -> bool:
        """Heartbeat: push this delivery's lease deadline out by ``extra``
        seconds. Returns False when the lease is gone — already acked, or
        expired (the message has been redelivered under a fresh ack token) —
        so the caller knows it is a zombie and must abort rather than ack."""
        self._expire_leases()
        msg = self._leased.get(msg_id)
        if msg is None:
            return False
        msg.lease_deadline += extra
        return True

    # ---------------------------------------------------------------- ack
    def ack(self, msg_id: int) -> bool:
        msg = self._leased.pop(msg_id, None)
        if msg is None:
            return False  # lease already expired; redelivery will be deduped
        self._acked_keys.add(msg.key)
        self.total_acked += 1
        self.tracer.event(
            "broker.ack",
            trace_id=trace_id_for(msg.key, msg.deliveries),
            key=msg.key,
            deliveries=msg.deliveries,
        )
        return True

    def nack(self, msg_id: int) -> None:
        """Immediate negative ack: back to the queue (or DLQ if exhausted)."""
        msg = self._leased.pop(msg_id, None)
        if msg is None:
            return
        msg.lease_owner = None
        msg.lease_deadline = None
        if msg.deliveries >= self.max_deliveries:
            self.dead_letter.append(msg)
            self.counters.dead_lettered += 1
            self.tracer.event(
                "broker.dead_letter",
                trace_id=trace_id_for(msg.key, msg.deliveries),
                key=msg.key,
                deliveries=msg.deliveries,
            )
            self.ledger.append(
                AUDIT_DEAD_LETTER, key=msg.key, deliveries=msg.deliveries, reason="nack"
            )
        else:
            msg.msg_id = next(self._ids)  # fresh ack token (see _expire_leases)
            self._available.append(msg)
            self.total_redelivered += 1
            self.tracer.event(
                "broker.redeliver",
                trace_id=trace_id_for(msg.key, msg.deliveries + 1),
                key=msg.key,
                deliveries=msg.deliveries,
                kind="nack",
            )

    # -------------------------------------------------------------- stats
    def stats(self) -> QueueStats:
        self._expire_leases()
        msgs = self._available + list(self._leased.values())
        return QueueStats(
            outstanding=len(msgs),
            available=len(self._available),
            leased=len(self._leased),
            dead_lettered=len(self.dead_letter),
            backlog_bytes=sum(m.nbytes for m in msgs),
            oldest_publish_time=min((m.publish_time for m in msgs), default=None),
            dead_letter_bytes=sum(m.nbytes for m in self.dead_letter),
        )

    def empty(self) -> bool:
        s = self.stats()
        return s.outstanding == 0

    def has_live(self, key: str) -> bool:
        """Any copy of ``key`` still available or leased (speculative clones
        of a dead-lettered delivery may outlive it and complete normally)."""
        self._expire_leases()
        return any(m.key == key for m in self._available) or any(
            m.key == key for m in self._leased.values()
        )

    # straggler mitigation support: leases held longer than ``age`` seconds
    def stale_leases(self, age: float) -> List[Message]:
        now = self.clock.now()
        return [
            m
            for m in self._leased.values()
            if now - (m.lease_deadline - self.visibility_timeout) >= age
        ]

    def speculative_redeliver(self, msg_id: int) -> Optional[Message]:
        """Clone a stale leased message back onto the queue (first ack wins —
        the journal dedups the second completion)."""
        msg = self._leased.get(msg_id)
        if msg is None:
            return None
        clone = Message(
            key=msg.key,
            payload=msg.payload,
            nbytes=msg.nbytes,
            msg_id=next(self._ids),
            deliveries=msg.deliveries,
            publish_time=msg.publish_time,
        )
        self._available.append(clone)
        self.counters.speculative_clones += 1
        self.tracer.event(
            "broker.redeliver",
            trace_id=trace_id_for(msg.key, msg.deliveries + 1),
            key=msg.key,
            deliveries=msg.deliveries,
            kind="speculative",
        )
        return clone
