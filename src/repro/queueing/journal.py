"""Processing journal: exactly-once effect + checkpoint/restart.

At-least-once delivery (broker) + idempotent completion record (journal) =
exactly-once output, the standard cloud pattern. The journal is an append-only
JSONL file, fsynced per batch, so a killed worker pool resumes from durable
state: completed keys are skipped on redelivery, manifests survive restarts.

This is the de-id plane's checkpoint mechanism (DESIGN.md §5); the training
plane's equivalent lives in `repro.training.checkpoint`.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Iterator, Optional

from repro.core.manifest import Manifest


class Journal:
    def __init__(self, path: str | os.PathLike) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._completed: Dict[str, dict] = {}
        if self.path.exists():
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    def _replay(self) -> None:
        with open(self.path, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    # torn tail write from a crash: ignore the partial record
                    continue
                if rec.get("kind") == "done":
                    self._completed[rec["key"]] = rec

    # ------------------------------------------------------------------ api
    def is_done(self, key: str) -> bool:
        return key in self._completed

    def record_done(self, key: str, manifest: Manifest, worker_id: str) -> bool:
        """Record completion. Returns False if key was already done (the
        duplicate worker's output is discarded — first ack wins)."""
        if key in self._completed:
            return False
        rec = {
            "kind": "done",
            "key": key,
            "worker": worker_id,
            "counts": manifest.counts(),
            "manifest": json.loads(manifest.to_json()),
        }
        self._completed[key] = rec
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())
        return True

    def completed_keys(self) -> set:
        return set(self._completed)

    def manifest_for(self, key: str) -> Optional[Manifest]:
        """The completion manifest recorded for ``key``, or None."""
        rec = self._completed.get(key)
        if rec is None:
            return None
        return Manifest.from_json(json.dumps(rec["manifest"]))

    def manifests(self) -> Iterator[Manifest]:
        for rec in self._completed.values():
            yield Manifest.from_json(json.dumps(rec["manifest"]))

    def merged_manifest(self, request_id: str) -> Manifest:
        merged = Manifest(request_id)
        for m in self.manifests():
            merged.merge(m)
        return merged

    def close(self) -> None:
        self._fh.close()
