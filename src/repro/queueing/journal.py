"""Processing journal: exactly-once effect + checkpoint/restart.

At-least-once delivery (broker) + idempotent completion record (journal) =
exactly-once output, the standard cloud pattern. The journal is an append-only
JSONL file, fsynced per batch, so a killed worker pool resumes from durable
state: completed keys are skipped on redelivery, manifests survive restarts.

This is the de-id plane's checkpoint mechanism (DESIGN.md §5); the training
plane's equivalent lives in `repro.training.checkpoint`.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Iterator, Optional

from repro.core.manifest import Manifest
from repro.utils.wal import append_jsonl, replay_jsonl


class Journal:
    def __init__(self, path: str | os.PathLike) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._completed: Dict[str, dict] = {}
        self.supersessions = 0  # done-records that replaced a stale-etag entry
        self.torn_tail = 0      # truncated final records dropped at replay
        self.corrupt_lines = 0  # malformed non-final lines skipped at replay
        if self.path.exists():
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    def _absorb(self, rec: dict) -> None:
        if rec.get("kind") != "done" or "key" not in rec:
            return
        prev = self._completed.get(rec["key"])
        if prev is not None and prev.get("source_etag") != rec.get("source_etag"):
            self.supersessions += 1
        self._completed[rec["key"]] = rec

    def _replay(self) -> None:
        # Torn-tail repair + corrupt-line tolerance live in the shared WAL
        # helper (repro.utils.wal); the journal keeps only its absorb logic.
        replay = replay_jsonl(self.path)
        self.torn_tail += replay.torn_tail
        self.corrupt_lines += replay.corrupt_lines
        for rec in replay.records:
            self._absorb(rec)

    # ------------------------------------------------------------------ api
    def is_done(self, key: str) -> bool:
        return key in self._completed

    def record_done(
        self,
        key: str,
        manifest: Manifest,
        worker_id: str,
        source_etag: Optional[str] = None,
    ) -> bool:
        """Record completion. Returns False if key was already done for the
        same source version (the duplicate worker's output is discarded —
        first ack wins). A completion carrying a *different* ``source_etag``
        supersedes the stale record: the source mutated and the key was
        legitimately re-de-identified (incremental re-deid, not a duplicate)."""
        prev = self._completed.get(key)
        if prev is not None:
            if source_etag is None or prev.get("source_etag") == source_etag:
                return False
            self.supersessions += 1
        rec = {
            "kind": "done",
            "key": key,
            "worker": worker_id,
            "source_etag": source_etag,
            "counts": manifest.counts(),
            "manifest": json.loads(manifest.to_json()),
        }
        self._completed[key] = rec
        append_jsonl(self._fh, rec)
        return True

    def etag_for(self, key: str) -> Optional[str]:
        """Source content etag the completion for ``key`` was computed from
        (None for legacy records or unknown keys) — the freshness handle the
        planner and workers compare against the live source."""
        rec = self._completed.get(key)
        return rec.get("source_etag") if rec is not None else None

    def completed_keys(self) -> set:
        return set(self._completed)

    def manifest_for(self, key: str) -> Optional[Manifest]:
        """The completion manifest recorded for ``key``, or None."""
        rec = self._completed.get(key)
        if rec is None:
            return None
        return Manifest.from_json(json.dumps(rec["manifest"]))

    def manifests(self) -> Iterator[Manifest]:
        for rec in self._completed.values():
            yield Manifest.from_json(json.dumps(rec["manifest"]))

    def merged_manifest(self, request_id: str) -> Manifest:
        merged = Manifest(request_id)
        for m in self.manifests():
            merged.merge(m)
        return merged

    def close(self) -> None:
        self._fh.close()
