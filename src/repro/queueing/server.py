"""Central workflow server (paper §Method: "A central database and server
component ... to store workflow information relevant to the lifetime of a
de-identification request").

Responsibilities reproduced:
  * registry of research studies (IRB protocols) with their trust mode and key;
  * accession validation ("first validated as eligible for research");
  * pseudonym minting (anon accession, anon MRN, per-patient date jitter);
  * publishing one message per accession to the broker;
  * request lifecycle state (pending / queued / done) backed by the journal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Set

from repro.core.pipeline import build_request
from repro.core.pseudonym import PseudonymService, TrustMode
from repro.obs.trace import NULL_TRACER
from repro.queueing.broker import Broker
from repro.queueing.journal import Journal
from repro.storage.object_store import StudyStore
from repro.utils.logging import get_logger

log = get_logger("queueing.server")


class RequestState(Enum):
    PENDING = "pending"
    QUEUED = "queued"
    DONE = "done"
    REJECTED = "rejected"


@dataclass
class WorkflowRecord:
    research_study: str
    accession: str
    state: RequestState
    anon_accession: str = ""
    reason: str = ""


class DeidService:
    def __init__(
        self,
        broker: Broker,
        lake: StudyStore,
        journal: Journal,
        result_lake=None,
        pipeline=None,
        catalog=None,
        tracer=None,
        registry=None,
        ledger=None,
    ) -> None:
        self.broker = broker
        self.lake = lake
        self.journal = journal
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # audit ledger (repro.audit): handed to the planner so warm/journal
        # admissions account their deliveries; workers get it via the pool
        self.ledger = ledger
        # optional metadata catalog (repro.catalog.StudyCatalog): enables
        # query-then-de-identify via submit_query
        self.catalog = catalog
        self._studies: Dict[str, PseudonymService] = {}
        self._ineligible: Set[str] = set()  # e.g. research-opt-out patients
        self.records: List[WorkflowRecord] = []
        # cohort planner over the de-id result lake (DESIGN.md §6). The
        # planner's ruleset digest must match the worker pipeline's, so both
        # are wired from the same DeidPipeline instance.
        self.planner = None
        # optional health controller (repro.obs.health): health_report()
        # snapshots SLO states / burn / budgets for operators
        self.health = None
        if result_lake is not None:
            if pipeline is None:
                raise ValueError(
                    "result_lake requires the worker DeidPipeline (ruleset digest)"
                )
            from repro.lake.planner import CohortPlanner

            self.planner = CohortPlanner(
                result_lake,
                lake,
                broker,
                journal,
                validate=self.validate,
                ruleset_digest=pipeline.ruleset_fingerprint().digest,
                tracer=self.tracer,
                registry=registry,
                ledger=ledger,
            )

    # --------------------------------------------------------------- health
    def attach_health(self, controller) -> None:
        """Attach a :class:`repro.obs.health.HealthController`; after this,
        :meth:`health_report` snapshots it at the broker clock's now."""
        self.health = controller

    def health_report(self):
        if self.health is None:
            raise RuntimeError("no health controller attached; call attach_health()")
        return self.health.snapshot(self.broker.clock.now())

    # -------------------------------------------------------------- studies
    def register_study(
        self, study_id: str, mode: TrustMode = TrustMode.POST_IRB, key: Optional[bytes] = None
    ) -> PseudonymService:
        if mode is TrustMode.POST_IRB and key is None:
            # per-protocol persistent key (stored in the central DB in prod)
            key = study_id.encode().ljust(32, b"\0")[:32]
        svc = PseudonymService(study_id, mode, key=key)
        self._studies[study_id] = svc
        return svc

    def mark_ineligible(self, accession: str) -> None:
        self._ineligible.add(accession)

    # -------------------------------------------------------------- requests
    def validate(self, accession: str) -> tuple[bool, str]:
        if accession in self._ineligible:
            return False, "accession opted out of research use"
        if not self.lake.has_study(accession):
            return False, "accession not present in the data lake"
        return True, ""

    @staticmethod
    def _dedupe(accessions: List[str]) -> List[str]:
        """Drop repeated accessions, keeping stable first-occurrence order —
        a duplicated accession in one request must neither double-publish
        nor double-count planner admission stats."""
        seen: Set[str] = set()
        out: List[str] = []
        for acc in accessions:
            if acc not in seen:
                seen.add(acc)
                out.append(acc)
        return out

    def submit(self, study_id: str, accessions: List[str], mrn_lookup: Dict[str, str]) -> List[WorkflowRecord]:
        """Validate + pseudonymize + enqueue one request per accession."""
        if study_id not in self._studies:
            raise KeyError(f"research study {study_id!r} not registered")
        pseudo = self._studies[study_id]
        out: List[WorkflowRecord] = []
        with self.tracer.span("service.submit", n=len(accessions)):
            out = self._submit_traced(pseudo, study_id, accessions, mrn_lookup)
        return out

    def _submit_traced(
        self, pseudo: PseudonymService, study_id: str,
        accessions: List[str], mrn_lookup: Dict[str, str],
    ) -> List[WorkflowRecord]:
        out: List[WorkflowRecord] = []
        for acc in self._dedupe(accessions):
            ok, reason = self.validate(acc)
            key = f"{study_id}/{acc}"
            done_etag = self.journal.etag_for(key)
            fresh_done = self.journal.is_done(key) and (
                done_etag is None or done_etag == self.lake.study_etag(acc)
            )
            if not ok:
                rec = WorkflowRecord(study_id, acc, RequestState.REJECTED, reason=reason)
            elif fresh_done:
                rec = WorkflowRecord(study_id, acc, RequestState.DONE)
            else:
                req = build_request(pseudo, acc, mrn_lookup[acc])
                if self.planner is not None:
                    # route through the single-flight registry: no duplicate
                    # publish when a cohort (or earlier submit) already has
                    # this accession in flight, and cohorts arriving later
                    # coalesce onto this publish
                    self.planner.admit(pseudo, acc, req)
                else:
                    # metadata-only: blob size estimates backlog without
                    # reading (decrypting) the study the worker fetches anyway
                    self.broker.publish(
                        key=f"{study_id}/{acc}",
                        payload={"accession": acc, "request": req.__dict__},
                        nbytes=self.lake.study_nbytes(acc) or 0,
                    )
                rec = WorkflowRecord(study_id, acc, RequestState.QUEUED, req.anon_accession)
            out.append(rec)
            self.records.append(rec)
        return out

    def submit_cohort(
        self,
        study_id: str,
        accessions: List[str],
        mrn_lookup: Dict[str, str],
        selection_digest: str = "",
    ):
        """Cohort admission through the planner: warm accessions are served
        from the result lake, in-flight ones coalesce onto existing work
        (single-flight), and only the cold slice is published to the broker.
        Returns the :class:`repro.lake.planner.CohortTicket`."""
        if self.planner is None:
            raise RuntimeError("no result lake configured; use submit()")
        if study_id not in self._studies:
            raise KeyError(f"research study {study_id!r} not registered")
        with self.tracer.span("service.submit_cohort", n=len(accessions)) as sp:
            ticket = self.planner.submit(
                self._studies[study_id],
                self._dedupe(accessions),
                mrn_lookup,
                selection_digest=selection_digest,
            )
            sp.set(cohort_id=ticket.cohort_id, cold=len(ticket.cold))
        for acc in ticket.hits:
            self.records.append(
                WorkflowRecord(study_id, acc, RequestState.DONE)
            )
        for acc in ticket.coalesced + ticket.cold:
            self.records.append(WorkflowRecord(study_id, acc, RequestState.QUEUED))
        for acc, reason in ticket.rejected.items():
            self.records.append(
                WorkflowRecord(study_id, acc, RequestState.REJECTED, reason=reason)
            )
        return ticket

    def submit_query(self, study_id: str, query, mrn_lookup: Dict[str, str]):
        """Query-then-de-identify (the paper's core workflow): resolve a
        metadata predicate against the catalog, then admit the matching
        cohort through the planner. The selection digest — sha256 of
        (catalog snapshot, canonical query) — rides the ticket, pinning
        exactly which catalog state answered the query.

        Returns ``(CohortSelection, CohortTicket)``. ``mrn_lookup`` must
        cover every accession the catalog can return (in production the
        central DB joins this; here callers pass the ingest-time map).
        """
        if self.catalog is None:
            raise RuntimeError("no metadata catalog attached; pass catalog= or set .catalog")
        with self.tracer.span("service.submit_query") as sp:
            selection = self.catalog.select(query)
            sp.set(matched=len(selection.accessions))
            ticket = self.submit_cohort(
                study_id,
                list(selection.accessions),
                mrn_lookup,
                selection_digest=selection.digest,
            )
        return selection, ticket

    def request_states(self, study_id: str) -> Dict[str, RequestState]:
        out: Dict[str, RequestState] = {}
        for rec in self.records:
            if rec.research_study == study_id:
                state = rec.state
                if state is RequestState.QUEUED and self.journal.is_done(f"{study_id}/{rec.accession}"):
                    state = RequestState.DONE
                out[rec.accession] = state
        return out
