"""Drain workers + pool orchestration (paper §Method d).

"Each worker retrieves messages from the queue, downloads and de-identifies
the DICOM files ..., and uploads the de-identified images to an object store
accessible to the researcher. Compute instances are deleted once the message
queue is empty, and a manifest file is created."

The pool is a deterministic single-threaded simulation: workers are
interleaved round-robin, processing time is modeled from bytes/throughput and
advanced on the shared SimClock. Fault tolerance mechanics are real, not
mocked: a crash abandons the lease mid-flight, the visibility timeout
redelivers, the journal dedups double completions from speculative
re-dispatch (straggler mitigation).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.audit.ledger import NULL_LEDGER
from repro.audit.records import DELIVERY, PROVENANCE, SOURCE_FETCH
from repro.core.manifest import Manifest
from repro.core.pipeline import DeidPipeline, DeidRequest
from repro.obs.metrics import StatsShim
from repro.obs.trace import NULL_TRACER, trace_id_for
from repro.queueing.autoscaler import Autoscaler
from repro.queueing.broker import Broker, Message
from repro.queueing.journal import Journal
from repro.storage.object_store import StudyStore
from repro.utils.logging import get_logger

log = get_logger("queueing.worker")


class WorkerCrash(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic fault model: crash and/or stall specific (worker, key)
    pairs. Hash-based so runs are reproducible regardless of scheduling."""

    crash_rate: float = 0.0       # fraction of (worker, key, delivery) crashed
    straggler_rate: float = 0.0   # fraction processed at slow_factor speed
    slow_factor: float = 10.0
    crash_once_keys: frozenset = frozenset()  # crash first delivery of these keys

    def _u(self, *parts: object) -> float:
        h = hashlib.sha256("|".join(map(str, parts)).encode()).digest()
        return int.from_bytes(h[:8], "big") / 2**64

    def should_crash(self, worker_id: str, msg: Message) -> bool:
        if msg.key in self.crash_once_keys and msg.deliveries == 1:
            return True
        return self._u("crash", worker_id, msg.key, msg.deliveries) < self.crash_rate

    def slowdown(self, worker_id: str, msg: Message) -> float:
        if self._u("slow", worker_id, msg.key) < self.straggler_rate:
            return self.slow_factor
        return 1.0


@dataclass
class DeidWorker:
    worker_id: str
    pipeline: DeidPipeline
    source: StudyStore
    dest: StudyStore
    journal: Journal
    throughput: float = 160e6  # bytes/s of de-id compute (paper-calibrated)
    fence_stale_reads: bool = True  # abort deliveries computed from mutated bytes
    heartbeat_grace: float = 30.0   # lease headroom requested before delivery
    processed: int = 0
    deduped: int = 0
    batched_instances: int = 0  # instances that went through the fused batch path
    lake_hits: int = 0          # instances short-circuited by the result lake
    lake_misses: int = 0
    unknown_devices: int = 0    # registry misses (unknown manufacturer/model)
    detector_runs: int = 0      # burned-in text detector scans this worker ran
    fenced: int = 0             # stale-byte fences: source mutated mid-compute
    zombie_aborts: int = 0      # lease lost mid-compute: aborted without ack
    evicted_stale: int = 0      # superseded study records dropped from the lake
    tracer: object = None       # repro.obs Tracer (None -> NULL_TRACER)
    ledger: object = None       # repro.audit AuditLedger (None -> NULL_LEDGER)
    # negative-control knob for the AuditCompleteness checker: suppress the
    # delivery/provenance records a completion is supposed to produce
    audit_emit_provenance: bool = True

    def process(self, broker: Broker, msg: Message, injector: Optional[FailureInjector] = None) -> float:
        """Process one message; returns simulated seconds of work.

        The whole delivery runs under a ``worker.process`` root span whose
        trace id is derived from (key, delivery attempt) — the same id the
        broker stamped on this delivery's lease event — with child spans for
        fetch, de-id compute, lake write-back, and delivery. A crash
        propagates through the span (recorded as ``error=WorkerCrash``), so
        chaos runs leave an auditable retry chain across attempts.
        """
        tracer = self.tracer if self.tracer is not None else NULL_TRACER
        with tracer.span(
            "worker.process",
            trace_id=trace_id_for(msg.key, msg.deliveries),
            key=msg.key,
            attempt=msg.deliveries,
            worker=self.worker_id,
        ) as span:
            seconds = self._process_traced(broker, msg, injector, tracer, span)
            span.set(busy_s=seconds)
            return seconds

    def _process_traced(
        self, broker: Broker, msg: Message, injector, tracer, span
    ) -> float:
        request = DeidRequest(**msg.payload["request"])
        key = msg.key
        accession = msg.payload["accession"]

        if self.journal.is_done(key):
            done_etag = self.journal.etag_for(key)
            current = self.source.study_etag(accession)
            if done_etag is None or current is None or done_etag == current:
                # duplicate delivery of completed work: ack, drop (exactly-once)
                broker.ack(msg.msg_id)
                self.deduped += 1
                span.set(deduped=True)
                return 0.0
            # completed for a *previous* source version: the source mutated
            # since — fall through and re-de-identify (incremental re-deid);
            # record_done will supersede the stale journal entry

        if injector and injector.should_crash(self.worker_id, msg):
            # crash mid-processing: lease is abandoned, no ack, no journal entry
            raise WorkerCrash(f"{self.worker_id} crashed on {key} (delivery {msg.deliveries})")

        # pin the source version alongside the read: the study record must
        # bind results to the bytes we actually de-identified, not whatever
        # the source holds after a concurrent re-ingest
        with tracer.span("worker.fetch", accession=accession) as fetch_span:
            source_etag = self.source.study_etag(accession)
            if source_etag is None:
                # deleted while queued: nack toward the DLQ so the planner fails
                # subscribers out instead of leaving them waiting on erased bytes
                broker.nack(msg.msg_id)
                self.fenced += 1
                fetch_span.set(fenced=True)
                span.set(fenced=True)
                return 0.0
            study = self.source.get_study(accession)
            fetch_span.set(nbytes=study.nbytes(), instances=len(study.datasets),
                           modality=str(getattr(study, "modality", None) or "NA"))
        # the fetch itself is a PHI access (identified bytes left the source),
        # auditable even when a later fence discards this attempt's work
        ledger = self.ledger if self.ledger is not None else NULL_LEDGER
        ledger.append(
            SOURCE_FETCH,
            key=key,
            accession=accession,
            etag=source_etag,
            worker=self.worker_id,
            attempt=msg.deliveries,
            nbytes=study.nbytes(),
        )
        slowdown = injector.slowdown(self.worker_id, msg) if injector else 1.0
        work_seconds = (study.nbytes() / self.throughput) * slowdown
        batched0 = self.pipeline.executor.stats.instances if self.pipeline.executor else 0
        dstats = self.pipeline.scrub.detect_stats
        unknown0, druns0 = dstats.unknown_lookups, dstats.detector_runs
        with tracer.span("worker.deid", bytes_in=study.nbytes(), busy_s=work_seconds):
            result = self.pipeline.run_study(study, request, self.worker_id)
        outputs, manifest = result.delivered, result.manifest
        batched_delta = 0
        if self.pipeline.executor is not None:
            batched_delta = self.pipeline.executor.stats.instances - batched0
            self.batched_instances += batched_delta
        self._batched_delta = batched_delta  # provenance: batch-bucket fact
        # unknown-device lookups are a surfaced worker metric, never a silent
        # pass-through (the shared scrub stage counts; workers take deltas)
        self.unknown_devices += dstats.unknown_lookups - unknown0
        self.detector_runs += dstats.detector_runs - druns0
        self.lake_hits += result.cache_hits
        self.lake_misses += result.cache_misses

        # heartbeat before delivering: if the lease expired mid-compute this
        # worker is a zombie — the broker already redelivered under a fresh
        # ack token, so delivering or journaling here would race the new owner
        if not broker.extend_lease(msg.msg_id, work_seconds + self.heartbeat_grace):
            self.zombie_aborts += 1
            span.set(kind="zombie_abort")
            return work_seconds

        # stale-byte fence: a source mutation that raced this computation must
        # invalidate, never deliver — drop the lease work and let redelivery
        # read the post-mutation bytes
        if self.fence_stale_reads and self.source.study_etag(accession) != source_etag:
            broker.nack(msg.msg_id)
            self.fenced += 1
            span.set(fenced=True)
            return work_seconds

        request_id = f"{request.research_study}/{request.anon_accession}"
        with tracer.span("worker.deliver", datasets=len(outputs)):
            for ds in outputs:
                self.dest.put_output(request_id, str(ds.get("SOPInstanceUID", "?")), ds)
        with tracer.span("worker.writeback", accession=accession) as wb_span:
            self._record_study(accession, source_etag, request, result)
            wb_span.set(lake_hits=result.cache_hits, cold=result.cache_misses)

        if self.journal.record_done(key, manifest, self.worker_id, source_etag=source_etag):
            self.processed += 1
            span.set(ok=True)
            if self.audit_emit_provenance:
                self._record_provenance(
                    ledger, key, accession, source_etag, request, result, msg, study
                )
        else:
            self.deduped += 1  # lost the first-ack race to a speculative clone
            span.set(deduped=True)
        broker.ack(msg.msg_id)
        return work_seconds

    def _record_provenance(
        self, ledger, key, accession, source_etag, request, result, msg, study
    ) -> None:
        """One delivery + one provenance record per journal-accepted
        completion: the lineage chain ``lake key → source etag → ruleset
        fingerprint → detector sha → kernel path → trace id`` that makes a
        delivered instance reconstructible from the ledger alone."""
        from repro.lake.fingerprint import request_salt, study_key

        digest = self.pipeline.ruleset_fingerprint().digest
        policy = self.pipeline.scrub.policy
        skey = (
            study_key(accession, source_etag, digest, request_salt(request))
            if source_etag is not None else ""
        )
        with ledger.batch():  # the pair group-commits on one fsync
            ledger.append(
                DELIVERY,
                key=key,
                accession=accession,
                etag=source_etag,
                temp="cold",
                worker=self.worker_id,
            )
            ledger.append(
                PROVENANCE,
                key=key,
                project=request.research_study,
                accession=accession,
                lake_key=skey,
                etag=source_etag,
                ruleset=digest,
                detector_sha=getattr(policy, "fingerprint_identity", "") if policy else "",
                kernel_path="batched" if self.pipeline.executor is not None else "serial",
                batched=getattr(self, "_batched_delta", 0),
                trace_id=trace_id_for(msg.key, msg.deliveries),
                temp="cold",
                instances=len(study.datasets),
                nbytes=study.nbytes(),
            )

    def _record_study(self, accession: str, etag, request, result) -> None:
        """Write the study-level completion record to the result lake so the
        cohort planner can serve this accession warm next time. When this
        completion supersedes a previous source version, the stale study
        record (old etag's key) is evicted — pre-mutation output must never
        be materializable again."""
        lake = self.pipeline.lake
        if lake is None or etag is None:
            return
        # lazy import: repro.lake pulls core.pipeline back in (see lake/__init__)
        from repro.lake.fingerprint import request_salt, study_key
        from repro.lake.records import encode_study_record

        digest = self.pipeline.ruleset_fingerprint().digest
        salt = request_salt(request)
        prev_etag = self.journal.etag_for(f"{request.research_study}/{accession}")
        if prev_etag is not None and prev_etag != etag:
            old_key = study_key(accession, prev_etag, digest, salt)
            if lake.contains(old_key):
                lake.delete(old_key)
                self.evicted_stale += 1
        if not result.instance_keys:
            return
        if not all(lake.contains(k) for k in result.instance_keys):
            # some instance record never landed (oversize reject) or was
            # already evicted: a study record pointing at missing blobs would
            # only feed the planner's demote/recompute churn
            return
        skey = study_key(accession, etag, digest, salt)
        lake.put(skey, encode_study_record(result.instance_keys))


@dataclass
class PoolReport:
    processed: int
    deduped: int
    crashes: int
    redeliveries: int
    speculative: int
    wall_seconds: float
    bytes_in: int
    cost_usd: float
    scale_events: int
    unknown_devices: int = 0
    detector_runs: int = 0
    fenced: int = 0          # stale-byte fences (source mutated mid-compute)
    zombie_aborts: int = 0   # lease-expired heartbeats aborted without ack
    evicted_stale: int = 0   # superseded study records evicted from the lake


class PoolCounters(StatsShim):
    """Pool-level counters as real metrics (``repro_pool_*``)."""

    _SUBSYSTEM = "pool"
    _FIELDS = ("crashes", "speculative")


class WorkerPool:
    """Autoscaled drain loop with straggler re-dispatch."""

    def __init__(
        self,
        broker: Broker,
        autoscaler: Autoscaler,
        make_worker: Callable[[str], DeidWorker],
        injector: Optional[FailureInjector] = None,
        straggler_age: float = 300.0,
        tick_seconds: float = 5.0,
        max_ticks: int = 100_000,
        registry=None,
    ) -> None:
        self.broker = broker
        self.autoscaler = autoscaler
        self.make_worker = make_worker
        self.injector = injector
        self.straggler_age = straggler_age
        self.tick_seconds = tick_seconds
        self.max_ticks = max_ticks
        self.workers: List[DeidWorker] = []
        self._all_workers: List[DeidWorker] = []  # retains counters across scale-down
        self.counters = PoolCounters(registry)

    # `pool.crashes` / `pool.speculative` keep their attribute surface on
    # top of the metrics shim (tests and the fleet report read them)
    @property
    def crashes(self) -> int:
        return self.counters.crashes

    @crashes.setter
    def crashes(self, v: int) -> None:
        self.counters.crashes = v

    @property
    def speculative(self) -> int:
        return self.counters.speculative

    @speculative.setter
    def speculative(self, v: int) -> None:
        self.counters.speculative = v

    def _resize(self, n: int) -> None:
        while len(self.workers) < n:
            w = self.make_worker(f"w{len(self._all_workers)}")
            self.workers.append(w)
            self._all_workers.append(w)
        # scale-down deletes from the tail (paper: instances deleted when idle)
        del self.workers[n:]

    def step(self) -> float:
        """One scheduling round at the *current* sim time: autoscale, offer
        each live worker at most one message, then run straggler mitigation.

        Returns the busy-time (simulated seconds) of the slowest worker this
        round, 0.0 when every worker idled. The clock is NOT advanced — the
        caller owns time, which is what lets the fleet simulator interleave
        arrivals, chaos events, and pool rounds at exact sim-times.
        :meth:`drain` is the self-clocking wrapper.
        """
        n = self.autoscaler.tick()
        self._resize(max(n, 1) if not self.broker.empty() else n)

        busy = 0.0
        for worker in list(self.workers):
            msgs = self.broker.pull(worker.worker_id, max_messages=1)
            if not msgs:
                continue
            try:
                busy = max(busy, worker.process(self.broker, msgs[0], self.injector))
            except WorkerCrash:
                self.crashes += 1
                # no ack: the lease expires and the broker redelivers

        # straggler mitigation: clone stale leases back onto the queue
        stats = self.broker.stats()
        if stats.available == 0 and stats.leased > 0:
            for stale in self.broker.stale_leases(self.straggler_age):
                if self.broker.speculative_redeliver(stale.msg_id) is not None:
                    self.speculative += 1
        return busy

    def finish(self) -> None:
        """Final accounting tick + pool deletion (paper: instances deleted
        once the queue is empty). Step-driven callers invoke this once the
        broker is drained; :meth:`drain` does it automatically."""
        self.autoscaler.tick()
        self._resize(self.autoscaler.current)

    def report(self, t0: float = 0.0, bytes_in: int = 0) -> PoolReport:
        """Aggregate counters into a :class:`PoolReport` (step-driven callers
        pass the drain-start time and initial backlog they observed)."""
        return PoolReport(
            processed=sum(w.processed for w in self._all_workers),
            deduped=sum(w.deduped for w in self._all_workers),
            crashes=self.crashes,
            redeliveries=self.broker.total_redelivered,
            speculative=self.speculative,
            wall_seconds=self.broker.clock.now() - t0,
            bytes_in=bytes_in,
            cost_usd=self.autoscaler.cost_usd(),
            scale_events=len(self.autoscaler.events),
            unknown_devices=sum(w.unknown_devices for w in self._all_workers),
            detector_runs=sum(w.detector_runs for w in self._all_workers),
            fenced=sum(w.fenced for w in self._all_workers),
            zombie_aborts=sum(w.zombie_aborts for w in self._all_workers),
            evicted_stale=sum(w.evicted_stale for w in self._all_workers),
        )

    def drain(self) -> PoolReport:
        clock = self.broker.clock
        t0 = clock.now()
        bytes_in = self.broker.stats().backlog_bytes
        ticks = 0
        while not self.broker.empty() and ticks < self.max_ticks:
            ticks += 1
            busy = self.step()
            clock.advance(max(busy, self.tick_seconds))
        self.finish()
        return self.report(t0, bytes_in)
