from repro.serving.engine import ServeEngine, Request, BatchResult

__all__ = ["ServeEngine", "Request", "BatchResult"]
