"""Batched serving engine: synchronized prefill + decode over request batches.

Serving model: requests queue up, the engine packs up to ``max_batch`` of
them, left-pads prompts to a common length, prefills once, then decodes
synchronously (one token per step for the whole batch) with greedy or
temperature sampling. Per-sequence stop tokens mask finished rows.

Scope note (DESIGN.md §5): positions are batch-synchronized (scalar pos), as
in the dry-run serve_step contract. Continuous batching with per-row
positions is an engine-level extension, orthogonal to the sharding story.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class Request:
    request_id: str
    prompt_tokens: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy


@dataclass
class BatchResult:
    request_id: str
    tokens: List[int]
    prompt_len: int


class ServeEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8, stop_token: int = -1) -> None:
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.stop_token = stop_token
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._pending: List[Request] = []
        self.steps_executed = 0

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    # ------------------------------------------------------------- serving
    def step(self, key: Optional[jax.Array] = None) -> List[BatchResult]:
        """Process at most one pending batch and return its results (empty
        when the queue is idle). This is the event-loop entry point: a
        step-driven caller (e.g. the fleet simulator) interleaves serve steps
        with queue ticks instead of blocking in :meth:`run`."""
        if not self._pending:
            return []
        batch = self._pending[: self.max_batch]
        self._pending = self._pending[self.max_batch :]
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._run_batch(batch, key)

    def run(self, key: Optional[jax.Array] = None) -> List[BatchResult]:
        """Drain pending requests in batches; returns completed results."""
        key = key if key is not None else jax.random.PRNGKey(0)
        results: List[BatchResult] = []
        while self._pending:
            results.extend(self.step(key))
            key = jax.random.fold_in(key, len(results))
        return results

    def _run_batch(self, reqs: List[Request], key: jax.Array) -> List[BatchResult]:
        cfg = self.model.cfg
        B = len(reqs)
        P = max(len(r.prompt_tokens) for r in reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        total = P + max_new

        # right-align prompts into a (B, P) buffer (pad id 0; positions match
        # the synchronized-pos contract because all rows share the pad length)
        toks = np.zeros((B, P), np.int32)
        for i, r in enumerate(reqs):
            toks[i, P - len(r.prompt_tokens) :] = r.prompt_tokens

        # prefill on prompt, then grow the cache to the full horizon
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        cache = self._grow_cache(cache, B, P, total)

        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        cur = self._sample(logits, reqs, key)
        for i in range(B):
            out[i].append(int(cur[i]))
        for step in range(1, max_new):
            logits, cache = self._decode(
                self.params, jnp.asarray(cur, jnp.int32), cache, jnp.int32(P + step - 1)
            )
            cur = self._sample(logits, reqs, jax.random.fold_in(key, step))
            self.steps_executed += 1
            for i in range(B):
                if not done[i]:
                    tok = int(cur[i])
                    out[i].append(tok)
                    if tok == self.stop_token or len(out[i]) >= reqs[i].max_new_tokens:
                        done[i] = True
            if done.all():
                break
        return [
            BatchResult(r.request_id, out[i][: r.max_new_tokens], len(r.prompt_tokens))
            for i, r in enumerate(reqs)
        ]

    def _grow_cache(self, cache, B, P, total):
        """Pad seq-dim caches from prompt length to the decode horizon."""

        def grow(x):
            if x.ndim >= 3 and x.shape[-3] == P:  # (..., S, KV, hd)
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, total - P)
                return jnp.pad(x, pad)
            return x

        return jax.tree.map(grow, cache)

    def _sample(self, logits: jnp.ndarray, reqs: List[Request], key: jax.Array) -> np.ndarray:
        temps = np.array([r.temperature for r in reqs], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        if (temps == 0).all():
            return greedy
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-6)
        sampled = np.asarray(jax.random.categorical(key, scaled, axis=-1))
        return np.where(temps == 0, greedy, sampled)
