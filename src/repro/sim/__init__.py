"""Deterministic fleet simulator + invariant conformance suite (DESIGN.md §7).

``FleetSim`` drives the real DeidService -> Broker -> WorkerPool -> Autoscaler
-> ResultLake -> StudyStore stack under seeded traffic and chaos schedules;
``repro.sim.invariants`` checks the run end to end. Single-seed replayability
is the contract: same seed, byte-identical event log and metrics.
"""
from repro.sim.chaos import ChaosEvent, ChaosSchedule
from repro.sim.events import Event, EventLog, EventQueue, HashRng
from repro.sim.harness import FleetConfig, FleetReport, FleetSim
from repro.sim.invariants import (
    DEFAULT_CHECKERS,
    AuditCompleteness,
    AutoscalerAccounting,
    CheckpointMonotonicity,
    ExactlyOnceDelivery,
    Freshness,
    InvariantChecker,
    JournalDurability,
    LakeConsistency,
    MetricsConservation,
    NoFullReingest,
    NoWedgedSubscribers,
    PhiBoundary,
    QueryConsistency,
    SloConformance,
    TelemetryPhiBoundary,
    TraceIntegrity,
    Violation,
    WarmReplayIdentity,
)
from repro.sim.traffic import (
    BurstyTraffic,
    CohortArrival,
    DiurnalTraffic,
    QueryArrival,
    QueryMix,
    ReplayStorm,
)

__all__ = [
    "AuditCompleteness",
    "AutoscalerAccounting",
    "BurstyTraffic",
    "ChaosEvent",
    "ChaosSchedule",
    "CheckpointMonotonicity",
    "CohortArrival",
    "DEFAULT_CHECKERS",
    "DiurnalTraffic",
    "Event",
    "EventLog",
    "EventQueue",
    "ExactlyOnceDelivery",
    "FleetConfig",
    "FleetReport",
    "FleetSim",
    "Freshness",
    "HashRng",
    "InvariantChecker",
    "JournalDurability",
    "LakeConsistency",
    "MetricsConservation",
    "NoFullReingest",
    "NoWedgedSubscribers",
    "PhiBoundary",
    "QueryArrival",
    "QueryConsistency",
    "QueryMix",
    "ReplayStorm",
    "SloConformance",
    "TelemetryPhiBoundary",
    "TraceIntegrity",
    "Violation",
    "WarmReplayIdentity",
]
