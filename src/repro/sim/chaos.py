"""Chaos schedules: seeded fault timelines for the fleet simulator.

A chaos schedule is a time-sorted list of :class:`ChaosEvent`\\ s, fixed
before the run (same determinism contract as ``repro.sim.traffic``). Kinds
the harness understands:

* ``set_crash_rate``   — retune `FailureInjector.crash_rate` mid-run
* ``crash_keys``       — crash the FIRST delivery of specific accessions
                         (`FailureInjector.crash_once_keys` semantics: a
                         no-op for keys already past delivery 1 — schedule
                         these before the targeted cohort arrives)
* ``set_straggler``    — retune straggler rate / slow factor
* ``lease_storm``      — temporarily shrink the broker visibility timeout,
                         forcing lease-expiry races against live workers
* ``reingest``         — overwrite a source study with re-acquired bytes
                         (new content ⇒ new etag) while work may be in flight
* ``ruleset_edit``     — swap the worker pipeline + planner onto an edited
                         ruleset (new fingerprint) mid-cohort
* ``pooler_crash``     — crash the change pooler mid-batch on its next poll
                         (``after`` events handed; recovery replays the
                         durable checkpoint)
* ``feed_outage``      — the PACS change feed raises outages for
                         ``duration`` seconds (backoff + breaker path)
* ``feed_faults``      — turn on duplicate/out-of-order delivery on the feed

Every mutation is applied *at* an event boundary by the harness, never inside
a worker round, so the interleaving is exact and replayable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence

from repro.sim.events import HashRng

CHAOS_KINDS = (
    "set_crash_rate",
    "crash_keys",
    "set_straggler",
    "lease_storm",
    "reingest",
    "ruleset_edit",
    "pooler_crash",
    "feed_outage",
    "feed_faults",
)


@dataclass(frozen=True)
class ChaosEvent:
    t: float
    kind: str
    payload: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; one of {CHAOS_KINDS}")


@dataclass
class ChaosSchedule:
    events: List[ChaosEvent] = field(default_factory=list)

    def sorted(self) -> List[ChaosEvent]:
        return sorted(self.events, key=lambda e: (e.t, e.kind))

    @classmethod
    def quiet(cls) -> "ChaosSchedule":
        return cls([])

    @classmethod
    def seeded(
        cls,
        seed: int,
        horizon: float,
        corpus: Sequence[str],
        *,
        crash_events: int = 2,
        straggler_events: int = 1,
        reingests: int = 1,
        lease_storms: int = 1,
        ruleset_edits: int = 0,
        pooler_crashes: int = 0,
        feed_outages: int = 0,
        feed_faults: int = 0,
    ) -> "ChaosSchedule":
        """Hash-seeded schedule: event times and victims are pure functions of
        the seed, so a chaos run replays bit-identically."""
        rng = HashRng(seed, "chaos")
        corpus = list(corpus)
        ev: List[ChaosEvent] = []
        for i in range(crash_events):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("crash_t", i),
                    kind="set_crash_rate",
                    payload={"rate": 0.1 + 0.3 * rng.u("crash_r", i)},
                )
            )
        for i in range(straggler_events):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("slow_t", i),
                    kind="set_straggler",
                    payload={
                        "rate": 0.1 + 0.2 * rng.u("slow_r", i),
                        "slow_factor": float(rng.randint(5, 40, "slow_f", i)),
                    },
                )
            )
        for i in range(reingests):
            if corpus:
                ev.append(
                    ChaosEvent(
                        t=horizon * rng.u("reingest_t", i),
                        kind="reingest",
                        payload={"accession": rng.choice(corpus, "reingest_a", i)},
                    )
                )
        for i in range(lease_storms):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("storm_t", i),
                    kind="lease_storm",
                    payload={
                        "visibility_timeout": float(rng.randint(5, 20, "storm_v", i)),
                        "duration": horizon * 0.1,
                    },
                )
            )
        for i in range(ruleset_edits):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("edit_t", i),
                    kind="ruleset_edit",
                    payload={"edit_id": i + 1},
                )
            )
        for i in range(pooler_crashes):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("pcrash_t", i),
                    kind="pooler_crash",
                    payload={"after": rng.randint(0, 3, "pcrash_k", i)},
                )
            )
        for i in range(feed_outages):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("outage_t", i),
                    kind="feed_outage",
                    payload={"duration": horizon * (0.05 + 0.1 * rng.u("outage_d", i))},
                )
            )
        for i in range(feed_faults):
            ev.append(
                ChaosEvent(
                    t=horizon * rng.u("fault_t", i),
                    kind="feed_faults",
                    payload={
                        "dup_rate": 0.2 + 0.3 * rng.u("fault_r", i),
                        "shuffle": True,
                    },
                )
            )
        return cls(sorted(ev, key=lambda e: (e.t, e.kind)))
