"""Discrete-event machinery for the fleet simulator (DESIGN.md §7).

Two pieces, both deliberately tiny and fully deterministic:

* :class:`EventQueue` — a (time, seq)-ordered heap of :class:`Event`\\ s.
  ``seq`` is a monotone tiebreaker so two events scheduled for the same
  sim-time always pop in scheduling order, which is what makes a whole run
  replayable from one seed: the heap never consults identity or hash order.
* :class:`EventLog` — the append-only record of everything the simulator
  did. Two runs of the same scenario are *defined* equal when their logs are
  byte-identical (:meth:`EventLog.digest`), which is the bit-replayability
  contract the conformance suite enforces.

There is intentionally no wall-clock anywhere in this module; sim-time comes
from the shared :class:`repro.utils.timing.SimClock` the whole stack already
runs on.
"""
from __future__ import annotations

import hashlib
import heapq
import itertools
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True, order=True)
class Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: Dict[str, Any] = field(compare=False, default_factory=dict)


class EventQueue:
    """Min-heap of events keyed by (sim-time, scheduling order)."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, **payload: Any) -> Event:
        ev = Event(t=float(t), seq=next(self._seq), kind=kind, payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek_time(self) -> Optional[float]:
        return self._heap[0].t if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


def _canonical(v: Any) -> Any:
    """Make a payload JSON-stable: tuples -> lists, floats rounded so the log
    digest never depends on platform float-repr noise."""
    if isinstance(v, float):
        return round(v, 9)
    if isinstance(v, (list, tuple)):
        return [_canonical(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _canonical(x) for k, x in sorted(v.items())}
    return v


class EventLog:
    """Append-only structured log; the replayability unit of account."""

    def __init__(self) -> None:
        self.records: List[Dict[str, Any]] = []

    def append(self, t: float, kind: str, **detail: Any) -> None:
        rec = {"t": round(float(t), 9), "kind": kind}
        rec.update({k: _canonical(v) for k, v in detail.items()})
        self.records.append(rec)

    def to_jsonl(self, exclude_kinds: Tuple[str, ...] = ()) -> str:
        recs = self.records
        if exclude_kinds:
            recs = [r for r in recs if r["kind"] not in exclude_kinds]
        return "\n".join(json.dumps(r, sort_keys=True) for r in recs)

    def digest(self, exclude_kinds: Tuple[str, ...] = ()) -> str:
        """SHA-256 over the canonical JSONL serialization. Two runs with the
        same seed must produce the same digest — the conformance suite's
        bit-replayability check compares exactly this. ``exclude_kinds``
        filters record kinds out first, for comparisons across configs that
        only differ by a known-additive record stream (e.g. ``slo_alert``)."""
        return hashlib.sha256(self.to_jsonl(exclude_kinds).encode()).hexdigest()

    def by_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == kind]

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return iter(self.records)

    def __len__(self) -> int:
        return len(self.records)


class HashRng:
    """Stateless, order-independent randomness: every draw is a pure function
    of (seed, *parts). The same trick as `FailureInjector` — schedules built
    from it are reproducible regardless of Python hash randomization or call
    ordering, and composable (two models with different namespaces never
    correlate)."""

    def __init__(self, seed: int, namespace: str = "") -> None:
        self.seed = seed
        self.namespace = namespace

    def u(self, *parts: object) -> float:
        """Uniform in [0, 1)."""
        blob = "|".join(map(str, (self.seed, self.namespace) + parts)).encode()
        h = hashlib.sha256(blob).digest()
        return int.from_bytes(h[:8], "big") / 2**64

    def randint(self, lo: int, hi: int, *parts: object) -> int:
        """Integer in [lo, hi] inclusive."""
        return lo + int(self.u(*parts) * (hi - lo + 1))

    def choice(self, seq: List, *parts: object):
        return seq[self.randint(0, len(seq) - 1, "choice", *parts)]

    def sample(self, seq: List, k: int, *parts: object) -> List:
        """k distinct elements, order-deterministic (sort by per-element u)."""
        keyed = sorted(seq, key=lambda x: self.u("sample", x, *parts))
        return keyed[: min(k, len(seq))]

    def exp(self, mean: float, *parts: object) -> float:
        """Exponential inter-arrival draw (clamped away from u=0)."""
        import math

        u = max(self.u(*parts), 1e-12)
        return -mean * math.log(u)
