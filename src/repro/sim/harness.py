"""FleetSim: a deterministic discrete-event simulator over the REAL stack.

Drives ``DeidService -> Broker -> WorkerPool -> Autoscaler -> ResultLake ->
StudyStore`` — no mocks anywhere — under a traffic model and a chaos
schedule, interleaving cohort arrivals, pool scheduling rounds, and fault
injections at exact sim-times on the shared :class:`SimClock`.

Determinism contract: everything a run does is a pure function of
(:class:`FleetConfig`, traffic schedule, chaos schedule). Two runs with the
same seed produce byte-identical event logs (``report.log_digest``) and
metrics — the conformance suite enforces this, and it is what makes a chaos
failure from CI replayable on a laptop from one integer.

Event kinds in the log: ``ingest``, ``cohort``, ``query``, ``tick``,
``chaos``, ``chaos_restore``, ``cohort_done``, ``drain_done``, ``slo_alert``
(when the SLO engine is on), and — when the change feed is enabled —
``feed_commit``, ``feed_poll``, ``feed_restore``, ``feed_drained``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.audit.ledger import NULL_LEDGER, AuditLedger
from repro.audit.records import POLICY_EDIT
from repro.catalog import CohortSelection, StudyCatalog
from repro.catalog.columns import rows_from_study
from repro.core.pipeline import DeidPipeline
from repro.detect import DetectorPolicy
from repro.core.pseudonym import TrustMode
from repro.core import scripts as default_scripts
from repro.dicom.generator import StudyGenerator, SyntheticStudy
from repro.ingest.checkpoint import Checkpoint
from repro.ingest.feed import PacsFeed, seeded_mutations
from repro.ingest.pooler import ChangePooler, IngestApplier, PoolerCrash
from repro.lake.store import ResultLake
from repro.obs.health import HealthController
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import CriticalPathProfiler
from repro.obs.slo import SloEngine, SloSpec, default_burn_rules
from repro.obs.trace import NULL_TRACER, Tracer
from repro.queueing.autoscaler import Autoscaler, AutoscalerConfig
from repro.queueing.broker import Broker
from repro.queueing.journal import Journal
from repro.queueing.server import DeidService
from repro.queueing.worker import DeidWorker, FailureInjector, WorkerPool
from repro.sim.chaos import ChaosSchedule
from repro.sim.events import EventLog, EventQueue
from repro.sim.invariants import DEFAULT_CHECKERS, Violation
from repro.sim.traffic import CohortArrival, QueryArrival
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


@dataclass
class FleetConfig:
    seed: int = 0
    n_studies: int = 8
    images_per_study: int = 3
    modality: Optional[str] = "CT"   # None = draw the paper's modality mix
    delivery_window: float = 1800.0      # per-cohort SLA (seconds)
    # modeled de-id compute rate, applied to BOTH the workers and the
    # autoscaler's sizing estimate (a fleet whose planner disagrees with its
    # workers about throughput is a different experiment)
    worker_throughput: float = 160e6
    max_instances: int = 16
    visibility_timeout: float = 60.0
    max_deliveries: int = 5
    tick_seconds: float = 5.0
    straggler_age: float = 120.0
    lake_bytes: int = 1 << 30
    recompress: bool = False             # cheap pixels by default; sim is about the fleet
    max_events: int = 100_000
    # burned-in pixel-PHI detector (DESIGN.md §9): fraction of ingests drawn
    # from novel (manufacturer, model) variants outside the registry, and the
    # DetectorPolicy mode the fleet's pipelines run under ("off" is the
    # registry-only negative control the PHI invariant is tested against)
    unknown_device_rate: float = 0.0
    detector_mode: str = "registry_first"
    # continuous change-feed ingest (DESIGN.md §10): number of PACS mutations
    # committed during the run (0 = feed disabled, legacy batch-loaded lake),
    # the pooler's poll cadence, and its fault-handling knobs
    feed_mutations: int = 0
    feed_poll_interval: float = 25.0
    feed_create_fraction: float = 0.25
    feed_delete_fraction: float = 0.15
    pooler_batch: int = 16
    pooler_base_backoff: float = 5.0
    pooler_breaker_threshold: int = 3
    pooler_breaker_cooldown: float = 60.0
    # stale-byte fencing in the workers (False = the freshness invariant's
    # negative control: pre-mutation bytes may be delivered)
    fence_stale_reads: bool = True
    # observability plane (DESIGN.md §11): deterministic tracing on the sim
    # clock plus the telemetry negative-control knobs. ``trace=False`` swaps
    # in the NULL_TRACER (zero clock reads, zero behavior change);
    # ``telemetry_redact=False`` + ``plant_telemetry_phi=True`` is the
    # TelemetryPhiBoundary checker's negative control
    trace: bool = True
    telemetry_redact: bool = True
    plant_telemetry_phi: bool = False
    # streaming SLO engine + burn-rate alerting (DESIGN.md §13). ``slo=False``
    # removes the engine entirely (zero behavior change: same log minus
    # ``slo_alert`` records, same metrics). ``slo_autoscale`` opts the
    # autoscaler into the burn-rate pressure signal — the one SLO feature
    # that deliberately DOES change fleet behavior, so it defaults off.
    # Burn windows are the production 5m/1h + 6h/3d pairs scaled by
    # ``slo_window_scale`` to fit a ~600 s sim horizon.
    slo: bool = True
    slo_autoscale: bool = False
    slo_window_scale: float = 1.0 / 60.0
    slo_cold_threshold: float = 60.0     # cold-serve latency objective (s)
    slo_freshness_lag: float = 32.0      # ingest lag objective (feed events)
    # tamper-evident audit ledger (DESIGN.md §14). ``audit=False`` swaps in
    # NULL_LEDGER (provably zero behavior change: same event-log digest,
    # metrics, and trace digest). ``audit_drop_provenance=True`` is the
    # AuditCompleteness checker's negative control: completions stop emitting
    # their delivery/provenance records, so the ledger↔journal cross-check
    # must fire.
    audit: bool = True
    audit_drop_provenance: bool = False


@dataclass
class FleetReport:
    seed: int
    log_digest: str
    metrics: Dict[str, float]
    violations: List[Violation]
    # digest over the finished-span stream (repro.obs.Tracer.digest): the
    # trace-layer half of the replayability contract. Kept out of ``metrics``
    # so metric-equality assertions stay about fleet behavior.
    trace_digest: str = ""
    # SLO plane summary (states, alert counts, budgets, alert/profile
    # digests) — also kept out of ``metrics``: turning the SLO engine on
    # must not move any metric-equality assertion.
    slo: Dict[str, object] = field(default_factory=dict)
    # audit-ledger summary (chain digest, record counts by kind) — same
    # isolation rule: the ledger must not move metrics or either digest.
    audit: Dict[str, object] = field(default_factory=dict)

    def ok(self) -> bool:
        return not self.violations


class FleetSim:
    def __init__(
        self,
        config: FleetConfig,
        traffic: Sequence[CohortArrival],
        journal_path,
        chaos: Optional[ChaosSchedule] = None,
    ) -> None:
        self.config = config
        self.traffic = sorted(traffic, key=lambda a: (a.t, a.study_id))
        self.chaos = chaos or ChaosSchedule.quiet()
        self.clock = SimClock()
        self.log = EventLog()
        # --- observability plane: one tracer (sim clock) + one metrics
        # registry shared by every component, parallel to the event log —
        # spans never feed the log, so enabling tracing cannot move the
        # log digest
        self.registry = MetricsRegistry()
        self.tracer = Tracer(self.clock) if config.trace else NULL_TRACER
        # --- audit plane (DESIGN.md §14): one hash-chained ledger shared by
        # every PHI-touching component. Parallel to the event log like the
        # tracer: appends never feed the log or metrics, so enabling the
        # ledger cannot move either digest.
        self.ledger = (
            AuditLedger(f"{journal_path}.audit", clock=self.clock)
            if config.audit else NULL_LEDGER
        )
        # --- SLO plane (DESIGN.md §13): engine + critical-path profiler +
        # health controller. Observations are fed from the same hooks that
        # write the event log, so the alert stream is a pure function of the
        # run; evaluation happens on pool ticks and once at drain.
        self.slo_engine: Optional[SloEngine] = None
        self.profiler: Optional[CriticalPathProfiler] = None
        self.health: Optional[HealthController] = None
        self._slo_cold_spec: Optional[SloSpec] = None
        self._slo_last_dlq = 0
        self._slo_last_ack = 0
        if config.slo:
            s = config.slo_window_scale
            rules = default_burn_rules(s)
            budget_window = 86400.0 * s
            self._slo_cold_spec = SloSpec(
                "cold_serve", objective=0.9, threshold=config.slo_cold_threshold,
                kind="latency", rules=rules, budget_window=budget_window,
            )
            specs = [
                SloSpec("warm_hit", objective=0.99, threshold=1.0,
                        kind="latency", rules=rules, budget_window=budget_window),
                SloSpec("cohort_e2e", objective=0.9,
                        threshold=config.delivery_window, kind="latency",
                        rules=rules, budget_window=budget_window),
                SloSpec("dlq_rate", objective=0.95, kind="rate",
                        rules=rules, budget_window=budget_window),
            ]
            if config.feed_mutations > 0:
                specs.append(SloSpec(
                    "ingest_freshness", objective=0.9,
                    threshold=config.slo_freshness_lag, unit="events",
                    kind="freshness", rules=rules, budget_window=budget_window,
                ))
            self.slo_engine = SloEngine(specs, registry=self.registry)
            self.profiler = CriticalPathProfiler()
            self.health = HealthController(self.slo_engine, self.profiler)

        # --- corpus: the identified data lake, with PHI ground truth retained
        self.gen = StudyGenerator(config.seed)
        self.source = StudyStore("lake", key=b"sim-at-rest-key")
        # metadata catalog indexes every ingest (incl. chaos re-ingests)
        self.catalog = StudyCatalog(tracer=self.tracer)
        self.source.attach_catalog(self.catalog)
        self.mrns: Dict[str, str] = {}
        self._versions: List[SyntheticStudy] = []  # every ingest, incl. re-ingests
        self._etag_study: Dict[str, SyntheticStudy] = {}  # source etag -> version
        self._hit_etag: Dict[Tuple[int, str], str] = {}   # (cohort, acc) at serve time
        self._reingests = 0
        # freshness ledger: one global order over source mutations and
        # researcher-visible deliveries (same-sim-time events keep a definite
        # order), plus the per-mutation row budget the no-full-reingest
        # invariant counter-asserts against the catalog's own counters
        self._order_seq = 0
        self.mutation_log: List[Dict] = []
        self.delivery_log: List[Dict] = []
        self._acc_rows: Dict[str, int] = {}
        self._expected_catalog_rows = 0
        self._expected_tombstones = 0
        # --- change-feed ingest plane (feed_mutations > 0)
        self.feed: Optional[PacsFeed] = None
        self.pooler: Optional[ChangePooler] = None
        self.applier: Optional[IngestApplier] = None
        self._ckpt_path = f"{journal_path}.ckpt"
        self._pooler_crash_after: Optional[int] = None
        self._pooler_crashes = 0
        self._pooler_crashed_at: Optional[float] = None
        self._recovery_times: List[float] = []
        self._feed_totals: Dict[str, int] = {}
        if config.feed_mutations > 0:
            self.feed = PacsFeed(
                config.seed + 500_000, config.modality, config.images_per_study
            )
        for i in range(config.n_studies):
            acc = f"SIM{i:04d}"
            self._ingest(self.gen, acc)
        if config.plant_telemetry_phi and self._versions:
            # TelemetryPhiBoundary negative control: a debug span carrying
            # real PHI under a NON-allowlisted key. With redaction on, the
            # exporter drops it; with redaction off, the checker must catch it
            planted = self._versions[0]
            self.tracer.event(
                "debug.dump",
                note=f"patient={planted.patient_name} mrn={planted.mrn}",
                accession=planted.accession,
            )

        # --- the real control/data plane, wired exactly like production
        self.broker = Broker(
            self.clock,
            visibility_timeout=config.visibility_timeout,
            max_deliveries=config.max_deliveries,
            tracer=self.tracer,
            registry=self.registry,
            ledger=self.ledger,
        )
        self.journal = Journal(journal_path)
        # the ingest plane gets its own queue: feed events and de-id work are
        # separate streams in production (different consumers, different SLAs)
        self.ingest_broker: Optional[Broker] = None
        if self.feed is not None:
            self.ingest_broker = Broker(
                self.clock, visibility_timeout=config.visibility_timeout,
                tracer=self.tracer, registry=self.registry,
            )
            self._build_ingest_process()
        self.lake = ResultLake(
            max_bytes=config.lake_bytes, registry=self.registry, ledger=self.ledger
        )
        self.policy = DetectorPolicy(mode=config.detector_mode)
        self.pipeline = DeidPipeline(
            recompress=config.recompress, lake=self.lake,
            detector_policy=self.policy,
            tracer=self.tracer, registry=self.registry, ledger=self.ledger,
        )
        # genesis policy record: the ruleset/detector identity this fleet
        # deployed with — every later edit chains after it
        self.ledger.append(
            POLICY_EDIT,
            action="deploy",
            ruleset=self.pipeline.ruleset_fingerprint().digest,
            detector_sha=self.policy.fingerprint_identity,
        )
        self.dest = StudyStore("researcher")
        self.service = DeidService(
            self.broker, self.source, self.journal,
            result_lake=self.lake, pipeline=self.pipeline,
            catalog=self.catalog,
            tracer=self.tracer, registry=self.registry, ledger=self.ledger,
        )
        for arr in self.traffic:
            if arr.study_id not in self.service._studies:
                self.service.register_study(arr.study_id, TrustMode.POST_IRB)
        self.injector = FailureInjector()
        self.pool = WorkerPool(
            self.broker,
            Autoscaler(
                self.broker,
                AutoscalerConfig(
                    delivery_window=config.delivery_window,
                    per_instance_throughput=config.worker_throughput,
                    max_instances=config.max_instances,
                ),
                self.clock,
            ),
            # factory object (not a closure over self.pipeline): workers spawned
            # after a ruleset_edit chaos event get the edited pipeline
            DeidWorkerProxyFactory(self),
            self.injector,
            straggler_age=config.straggler_age,
            tick_seconds=config.tick_seconds,
            registry=self.registry,
        )
        if self.health is not None:
            self.service.attach_health(self.health)
            if config.slo_autoscale:
                # closed loop: burning latency SLOs boost the scale-up target
                self.pool.autoscaler.pressure_fn = self.health.pressure

        self.tickets: List[Tuple[object, object]] = []  # (arrival, ticket)
        # (arrival, serve-time selection, serve-time accession->etag map) per
        # query — what the QueryConsistency checker replays brute-force
        self.query_log: List[Tuple[QueryArrival, CohortSelection, Dict[str, str]]] = []
        self._submitted: Set[str] = set()
        self._cohort_arrival_t: Dict[int, float] = {}
        self._cohort_done_t: Dict[int, float] = {}
        self._tick_scheduled = False
        self._ruleset_edits = 0
        self._storm_depth = 0  # nested/overlapping lease storms (see _on_chaos)
        # ruleset digest -> the pipeline that minted it, so the warm-replay
        # checker can rebuild the exact cold oracle a hit was served under
        self._pipelines: Dict[str, DeidPipeline] = {
            self.pipeline.ruleset_fingerprint().digest: self.pipeline
        }
        self._ticket_digest: Dict[int, str] = {}

    # ------------------------------------------------------------- corpus ops
    def _ingest(self, gen: StudyGenerator, accession: str) -> None:
        device = None
        if self.config.unknown_device_rate > 0.0:
            # deterministic per (generator seed, accession): re-ingests under
            # a chaos generator may re-roll, which is realistic (device swap)
            u = gen._rng("unknown-device?", accession).random()
            if u < self.config.unknown_device_rate:
                device = gen.unknown_device(accession, self.config.modality)
        study = gen.gen_study(
            accession, modality=self.config.modality,
            n_images=self.config.images_per_study,
            device=device,
        )
        self.source.put_study(accession, study)
        self.mrns[accession] = study.mrn
        self._versions.append(study)
        self._etag_study[self.source.study_etag(accession)] = study
        self._account_rows(accession, len(rows_from_study(study)))
        self._log_mutation(accession, self.source.study_etag(accession))
        if self.feed is not None:
            # initial corpus predates the feed: version 0, no change event
            self.feed.adopt(accession, study)

    # ------------------------------------------------- freshness + row budget
    def _log_mutation(self, accession: str, etag: Optional[str]) -> None:
        """Source-visible mutation (put or delete) in the global order the
        Freshness checker compares deliveries against."""
        self._order_seq += 1
        self.mutation_log.append(
            {
                "seq": self._order_seq,
                "t": self.clock.now(),
                "accession": accession,
                "etag": etag,
            }
        )

    def _log_delivery(self, key: str, accession: str, etag: Optional[str]) -> None:
        """Researcher-visible delivery, tagged with the source etag the bytes
        were de-identified from (warm hits: the etag pinned at admission)."""
        self._order_seq += 1
        self.delivery_log.append(
            {
                "seq": self._order_seq,
                "t": self.clock.now(),
                "key": key,
                "accession": accession,
                "etag": etag,
            }
        )

    # ------------------------------------------------------------- SLO plane
    def _slo_observe(self, name: str, value: float) -> None:
        if self.slo_engine is not None:
            self.slo_engine.observe(name, t=self.clock.now(), value=value)

    def _slo_delivery(self, msg) -> None:
        """Cold-serve latency observation for one processed delivery:
        now − first publish time (``Message.publish_time`` survives
        redelivery and speculative cloning), bucketed per modality. This is
        the same quantity ``derive_serve_observations`` reconstructs from
        the span stream — SloConformance asserts the two streams are equal."""
        if self.slo_engine is None:
            return
        study = self._etag_study.get(self.journal.etag_for(msg.key))
        modality = getattr(study, "modality", None) or "NA"
        spec = self.slo_engine.ensure(
            replace(self._slo_cold_spec, name=f"cold_serve_{modality}")
        )
        self.slo_engine.observe(
            spec.name, t=self.clock.now(),
            value=self.clock.now() - msg.publish_time,
        )

    def _slo_evaluate(self) -> None:
        """Feed the per-tick DLQ/ack deltas, run the burn-rate state machine,
        and append any fire/resolve transitions to the event log."""
        if self.slo_engine is None:
            return
        now = self.clock.now()
        dlq = len(self.broker.dead_letter)
        acked = self.broker.total_acked
        d_bad, d_good = dlq - self._slo_last_dlq, acked - self._slo_last_ack
        self._slo_last_dlq, self._slo_last_ack = dlq, acked
        if d_bad or d_good:
            self.slo_engine.observe_counts("dlq_rate", t=now, good=d_good, bad=d_bad)
        for ev in self.slo_engine.evaluate(now):
            self.log.append(
                now, "slo_alert",
                slo=ev.slo, rule=ev.rule, action=ev.action,
                severity=ev.severity,
                burn_long=ev.burn_long, burn_short=ev.burn_short,
            )

    def _account_rows(self, accession: str, rows: int) -> None:
        """Maintain the exact catalog row budget this mutation is allowed to
        cost: a re-put tombstones the accession's prior live rows and appends
        ``rows`` new ones. NoFullReingest counter-asserts these totals against
        the catalog's own counters — any hidden rebuild breaks the equality."""
        self._expected_tombstones += self._acc_rows.get(accession, 0)
        self._expected_catalog_rows += rows
        self._acc_rows[accession] = rows

    # ------------------------------------------------------ change-feed plane
    def _build_ingest_process(self) -> None:
        cfg = self.config
        ckpt = Checkpoint(self._ckpt_path)
        self.pooler = ChangePooler(
            self.feed,
            self.ingest_broker,
            ckpt,
            self.clock,
            seed=cfg.seed,
            batch=cfg.pooler_batch,
            base_backoff=cfg.pooler_base_backoff,
            breaker_threshold=cfg.pooler_breaker_threshold,
            breaker_cooldown=cfg.pooler_breaker_cooldown,
            tracer=self.tracer,
            registry=self.registry,
        )
        self.applier = IngestApplier(
            self.ingest_broker, self.feed, self.source, ckpt,
            tracer=self.tracer, registry=self.registry, ledger=self.ledger,
        )

    def _rebuild_ingest_process(self) -> None:
        """Pooler crash recovery: every in-memory cursor dies with the
        process; the replacement replays the durable checkpoint. This is the
        crash-safety claim the conformance suite exercises."""
        for name, val in (
            ("polls", self.pooler.stats.polls),
            ("handed", self.pooler.stats.handed),
            ("duplicates", self.pooler.stats.duplicates),
            ("outages", self.pooler.stats.outages),
            ("breaker_opens", self.pooler.stats.breaker_opens),
            ("applied", self.applier.stats.applied),
            ("deletes", self.applier.stats.deletes),
            ("effect_deduped", self.applier.stats.effect_deduped),
            ("stale_skipped", self.applier.stats.stale_skipped),
            ("redelivered", self.applier.stats.redelivered),
        ):
            self._feed_totals[name] = self._feed_totals.get(name, 0) + val
        self.pooler.checkpoint.close()
        self._build_ingest_process()

    def _absorb_applied(self, ops) -> None:
        """Fold applier effects into the sim's ground truth: PHI oracles see
        the new source versions, mrn routing learns feed-created studies, and
        the freshness/row-budget ledgers advance."""
        for op in ops:
            if op.op == "put":
                etag = self.source.study_etag(op.accession)
                self._versions.append(op.study)
                self._etag_study[etag] = op.study
                self.mrns[op.accession] = op.study.mrn
                self._account_rows(op.accession, op.rows)
                self._log_mutation(op.accession, etag)
            else:  # delete
                self._expected_tombstones += self._acc_rows.pop(op.accession, 0)
                self._log_mutation(op.accession, None)

    def _on_feed_poll(self, eq: Optional[EventQueue]) -> None:
        now = self.clock.now()
        try:
            status = self.pooler.poll_once(crash_after=self._pooler_crash_after)
        except PoolerCrash:
            self._pooler_crashes += 1
            self._pooler_crashed_at = now
            self._pooler_crash_after = None
            self._rebuild_ingest_process()
            status = {"crashed": True}
        else:
            # an armed crash stays armed until a non-empty batch fires it
            if self._pooler_crashed_at is not None and "handed" in status:
                self._recovery_times.append(now - self._pooler_crashed_at)
                self._pooler_crashed_at = None
        applied = self.applier.drain()
        self._absorb_applied(applied)
        self.log.append(now, "feed_poll", applied=len(applied), **status)
        # ingest freshness = how far the durable checkpoint trails the PACS
        # head, in feed events, sampled at every poll
        self._slo_observe(
            "ingest_freshness",
            float(self.feed.last_seq - self.pooler.checkpoint.floor()),
        )
        if eq is not None and not self.broker.empty():
            self._schedule_tick(eq, now)

    def _drain_feed(self) -> None:
        """End-of-run catch-up: clear any standing outage, then poll/apply —
        jumping the clock over backoff/breaker windows — until the checkpoint
        floor reaches the feed head and the ingest queue is empty. The lake
        must not finish the run behind the PACS."""
        self.feed.outage = False
        for _ in range(1000):
            if not self.pooler.behind() and self.ingest_broker.empty():
                break
            wake = max(
                self.pooler.next_poll_at, self.pooler.breaker_open_until or 0.0
            )
            if wake > self.clock.now():
                self.clock.advance(wake - self.clock.now())
            self._on_feed_poll(None)
        self.log.append(
            self.clock.now(), "feed_drained",
            floor=self.pooler.checkpoint.floor(), head=self.feed.last_seq,
        )

    def study_versions(self) -> List[SyntheticStudy]:
        """Every source version ever ingested (re-ingests included) — the PHI
        checker scans outputs against ALL of them."""
        return list(self._versions)

    def submitted_keys(self) -> set:
        """Every study-scoped key admitted so far. Accession-list arrivals
        contribute their full lists at admission; query arrivals contribute
        whatever the catalog resolved at serve time (tracked live — the
        traffic schedule alone cannot know a query's cohort)."""
        return set(self._submitted)

    def cold_pipeline_for(self, ticket) -> DeidPipeline:
        """Lake-less clone of the pipeline whose ruleset served ``ticket``'s
        warm hits — the oracle the warm-replay checker compares against.
        (After a ruleset edit, earlier hits replay under the old scripts.)"""
        src = self._pipelines[self._ticket_digest[ticket.cohort_id]]
        return DeidPipeline(
            filter_script=src.filter.script_text,
            anonymizer_script=src.anonymizer.script_text,
            scrub_script=src.scrub.script_text,
            recompress=src.scrub.recompress,
            detector_policy=src.scrub.policy,
        )

    # --------------------------------------------------------------- main loop
    def run(self, checkers=DEFAULT_CHECKERS) -> FleetReport:
        eq = EventQueue()
        horizon = 600.0
        for arr in self.traffic:
            kind = "query" if isinstance(arr, QueryArrival) else "cohort"
            eq.push(arr.t, kind, arrival=arr)
            horizon = max(horizon, arr.t)
        for ce in self.chaos.sorted():
            eq.push(ce.t, "chaos", event=ce)
            horizon = max(horizon, ce.t)
        self._horizon = horizon
        if self.feed is not None:
            cfg = self.config
            for mut in seeded_mutations(
                cfg.seed,
                horizon,
                [f"SIM{i:04d}" for i in range(cfg.n_studies)],
                cfg.feed_mutations,
                create_fraction=cfg.feed_create_fraction,
                delete_fraction=cfg.feed_delete_fraction,
            ):
                eq.push(mut.t, "feed_commit", mutation=mut)
            # poll cadence outlives the last scheduled event so the tail of
            # the change sequence is picked up inside the loop when possible
            t = cfg.feed_poll_interval
            while t <= horizon + 4.0 * cfg.feed_poll_interval:
                eq.push(t, "feed_poll")
                t += cfg.feed_poll_interval

        n_events = 0
        while eq:
            n_events += 1
            if n_events > self.config.max_events:
                self.log.append(self.clock.now(), "aborted", reason="max_events")
                break
            ev = eq.pop()
            if ev.t > self.clock.now():
                self.clock.advance(ev.t - self.clock.now())
            if ev.kind == "cohort":
                self._on_cohort(eq, ev.payload["arrival"])
            elif ev.kind == "query":
                self._on_query(eq, ev.payload["arrival"])
            elif ev.kind == "tick":
                self._on_tick(eq)
            elif ev.kind == "chaos":
                self._on_chaos(eq, ev.payload["event"])
            elif ev.kind == "feed_commit":
                mut = ev.payload["mutation"]
                event = self.feed.commit(mut.op, mut.accession)
                self.log.append(
                    self.clock.now(), "feed_commit",
                    op=mut.op, accession=mut.accession,
                    seq=event.seq if event is not None else -1,
                )
            elif ev.kind == "feed_poll":
                self._on_feed_poll(eq)
            elif ev.kind == "feed_restore":
                self.feed.outage = False
                self.log.append(self.clock.now(), "feed_restore")
            elif ev.kind == "chaos_restore":
                # storms may overlap: only the last restore standing brings the
                # baseline timeout back (a restore must never resurrect another
                # storm's shrunken value)
                self._storm_depth -= 1
                if self._storm_depth == 0:
                    self.broker.visibility_timeout = self.config.visibility_timeout
                self.log.append(
                    self.clock.now(), "chaos_restore",
                    visibility_timeout=self.broker.visibility_timeout,
                    storm_depth=self._storm_depth,
                )

        if self.feed is not None:
            self._drain_feed()
        self.pool.finish()
        self._resolve_and_log_done()
        self._slo_evaluate()  # final burn evaluation at drain time
        self.log.append(
            self.clock.now(), "drain_done",
            processed=sum(w.processed for w in self.pool._all_workers),
            outstanding=self.broker.stats().outstanding,
        )
        return self._report(checkers)

    # ---------------------------------------------------------------- handlers
    def _schedule_tick(self, eq: EventQueue, t: float) -> None:
        if not self._tick_scheduled:
            eq.push(t, "tick")
            self._tick_scheduled = True

    def _admit_ticket(self, arr, ticket) -> None:
        """Bookkeeping shared by accession-list and query admissions."""
        self.tickets.append((arr, ticket))
        self._ticket_digest[ticket.cohort_id] = self.service.planner.ruleset_digest
        for acc in ticket.hits:  # pin the source version each hit replayed
            etag = self.source.study_etag(acc)
            self._hit_etag[(ticket.cohort_id, acc)] = etag
            # a warm hit is a researcher-visible delivery at admission time
            self._log_delivery(f"{arr.study_id}/{acc}", acc, etag)
            # ... served synchronously from the lake: zero queueing latency
            self._slo_observe("warm_hit", 0.0)
        self._cohort_arrival_t[ticket.cohort_id] = self.clock.now()
        if ticket.done():
            self._cohort_done_t[ticket.cohort_id] = self.clock.now()

    def _on_cohort(self, eq: EventQueue, arr: CohortArrival) -> None:
        ticket = self.service.submit_cohort(
            arr.study_id, list(arr.accessions), self.mrns
        )
        self._submitted |= {f"{arr.study_id}/{acc}" for acc in arr.accessions}
        self._admit_ticket(arr, ticket)
        self.log.append(
            self.clock.now(), "cohort",
            cohort_id=ticket.cohort_id, study_id=arr.study_id,
            n=len(arr.accessions), hits=len(ticket.hits),
            coalesced=len(ticket.coalesced), cold=len(ticket.cold),
            rejected=len(ticket.rejected),
        )
        if not self.broker.empty():
            self._schedule_tick(eq, self.clock.now())

    def _on_query(self, eq: EventQueue, arr: QueryArrival) -> None:
        selection, ticket = self.service.submit_query(
            arr.study_id, arr.query, self.mrns
        )
        # serve-time snapshot: which source version of each accession the
        # catalog had indexed when it answered — the consistency checker
        # replays the query brute-force against exactly these versions
        self.query_log.append((arr, selection, self.catalog.accession_etags()))
        self._submitted |= {
            f"{arr.study_id}/{acc}" for acc in selection.accessions
        }
        self._admit_ticket(arr, ticket)
        self.log.append(
            self.clock.now(), "query",
            cohort_id=ticket.cohort_id, study_id=arr.study_id,
            query=selection.query, selection_digest=selection.digest,
            matched=len(selection.accessions),
            instances=selection.total_instances,
            matched_bytes=selection.total_bytes,
            blocks_scanned=selection.blocks_scanned,
            blocks_pruned=selection.blocks_pruned,
            hits=len(ticket.hits), coalesced=len(ticket.coalesced),
            cold=len(ticket.cold), rejected=len(ticket.rejected),
        )
        if not self.broker.empty():
            self._schedule_tick(eq, self.clock.now())

    def _on_tick(self, eq: EventQueue) -> None:
        self._tick_scheduled = False
        busy = self.pool.step()
        self._resolve_and_log_done()
        stats = self.broker.stats()
        self.log.append(
            self.clock.now(), "tick",
            workers=len(self.pool.workers), busy=busy,
            available=stats.available, leased=stats.leased,
            dead_lettered=stats.dead_lettered,
            backlog_bytes=stats.backlog_bytes,
        )
        self._slo_evaluate()
        if not self.broker.empty():
            self._schedule_tick(
                eq, self.clock.now() + max(busy, self.config.tick_seconds)
            )

    def _on_chaos(self, eq: EventQueue, ce) -> None:
        now = self.clock.now()
        if ce.kind == "set_crash_rate":
            self.injector.crash_rate = ce.payload["rate"]
        elif ce.kind == "crash_keys":
            keys = {
                f"{sid}/{acc}"
                for sid in self.service._studies
                for acc in ce.payload["accessions"]
            }
            self.injector.crash_once_keys = frozenset(
                self.injector.crash_once_keys | keys
            )
        elif ce.kind == "set_straggler":
            self.injector.straggler_rate = ce.payload["rate"]
            self.injector.slow_factor = ce.payload.get("slow_factor", 10.0)
        elif ce.kind == "lease_storm":
            self._storm_depth += 1
            eq.push(now + ce.payload["duration"], "chaos_restore")
            self.broker.visibility_timeout = ce.payload["visibility_timeout"]
        elif ce.kind == "reingest":
            self._reingests += 1
            # re-acquisition: same accession, different bytes -> new etag; the
            # planner's etag-keyed study records go stale, never stale-served
            if self.feed is not None:
                # single-writer rule: once the ingest plane is live the feed
                # owns source mutations — route the re-acquisition through it
                self.feed.commit("update", ce.payload["accession"])
            else:
                self._ingest(
                    StudyGenerator(self.config.seed + 1000 + self._reingests),
                    ce.payload["accession"],
                )
        elif ce.kind == "pooler_crash":
            if self.feed is not None:
                self._pooler_crash_after = ce.payload["after"]
        elif ce.kind == "feed_outage":
            if self.feed is not None:
                self.feed.outage = True
                eq.push(now + ce.payload["duration"], "feed_restore")
        elif ce.kind == "feed_faults":
            if self.feed is not None:
                self.feed.dup_rate = ce.payload["dup_rate"]
                self.feed.shuffle = bool(ce.payload.get("shuffle", True))
        elif ce.kind == "ruleset_edit":
            self._ruleset_edits += 1
            edited = (
                default_scripts.DEFAULT_ANONYMIZER_SCRIPT
                + f"\n# chaos ruleset edit {self._ruleset_edits}\nempty PatientAge\n"
            )
            self.pipeline = DeidPipeline(
                anonymizer_script=edited,
                recompress=self.config.recompress,
                lake=self.lake,
                detector_policy=self.policy,
                tracer=self.tracer,
                registry=self.registry,
                ledger=self.ledger,
            )
            # planner admissions and new workers move to the edited ruleset
            # atomically; in-flight workers finish under the old one (their
            # lake keys embed the old digest, so results never cross over)
            digest = self.pipeline.ruleset_fingerprint().digest
            self._pipelines[digest] = self.pipeline
            self.service.planner.ruleset_digest = digest
            self.ledger.append(
                POLICY_EDIT, action="edit", ruleset=digest,
                detector_sha=self.policy.fingerprint_identity,
            )
        self.log.append(now, "chaos", chaos_kind=ce.kind, **ce.payload)
        if not self.broker.empty():
            self._schedule_tick(eq, now)

    def _resolve_and_log_done(self) -> None:
        self.service.planner.resolve()
        for _, ticket in self.tickets:
            if ticket.done() and ticket.cohort_id not in self._cohort_done_t:
                self._cohort_done_t[ticket.cohort_id] = self.clock.now()
                latency = self.clock.now() - self._cohort_arrival_t[ticket.cohort_id]
                self.log.append(
                    self.clock.now(), "cohort_done",
                    cohort_id=ticket.cohort_id,
                    latency=latency,
                    failed=len(ticket.failed),
                )
                self._slo_observe("cohort_e2e", latency)

    # ----------------------------------------------------------------- report
    def _report(self, checkers) -> FleetReport:
        cfg = self.config
        latencies = {
            cid: self._cohort_done_t[cid] - self._cohort_arrival_t[cid]
            for cid in self._cohort_done_t
        }
        n_cohorts = len(self.tickets)
        within = sum(1 for v in latencies.values() if v <= cfg.delivery_window)
        a = self.pool.autoscaler
        metrics = {
            "cohorts": n_cohorts,
            "cohorts_done": len(latencies),
            "sla_attainment": within / n_cohorts if n_cohorts else 1.0,
            "processed": sum(w.processed for w in self.pool._all_workers),
            "deduped": sum(w.deduped for w in self.pool._all_workers),
            "crashes": self.pool.crashes,
            "redeliveries": self.broker.total_redelivered,
            "speculative": self.pool.speculative,
            "dead_lettered": len(self.broker.dead_letter),
            "published": self.broker.total_published,
            "lake_hit_rate": round(self.lake.stats.hit_rate(), 6),
            "planner_lake_hits": self.service.planner.stats.lake_hits,
            "planner_coalesced": self.service.planner.stats.coalesced,
            "instance_seconds": round(a.instance_seconds, 6),
            "cost_usd": round(a.cost_usd(), 6),
            "sim_minutes": round(self.clock.now() / 60.0, 6),
            "max_latency_s": round(max(latencies.values()), 6) if latencies else 0.0,
            "queries": len(self.query_log),
            "query_matched_accessions": sum(
                len(sel.accessions) for _, sel, _ in self.query_log
            ),
            "catalog_rows": self.catalog.stats.rows,
            "catalog_blocks_pruned": self.catalog.stats.blocks_pruned,
            # burned-in pixel-PHI detector surface (DESIGN.md §9): unknown
            # (manufacturer, model) lookups are a first-class fleet signal
            "unknown_device_lookups": sum(
                w.unknown_devices for w in self.pool._all_workers
            ),
            "detector_runs": sum(w.detector_runs for w in self.pool._all_workers),
            "detector_detected": sum(
                p.scrub.detect_stats.detected for p in self._pipelines.values()
            ),
            # stale-byte fencing + incremental re-deid surface (DESIGN.md §10)
            "fenced": sum(w.fenced for w in self.pool._all_workers),
            "zombie_aborts": sum(w.zombie_aborts for w in self.pool._all_workers),
            "evicted_stale": sum(w.evicted_stale for w in self.pool._all_workers),
            "supersessions": self.journal.supersessions,
            "stale_refreshes": self.service.planner.stats.stale_refreshes,
            "catalog_tombstoned": self.catalog.stats.tombstoned,
            "catalog_deletes": self.catalog.stats.deletes,
        }
        if self.feed is not None:
            t = self._feed_totals
            ps, ap = self.pooler.stats, self.applier.stats
            metrics.update(
                {
                    "feed_events": self.feed.last_seq,
                    "feed_polls": t.get("polls", 0) + ps.polls,
                    "feed_handed": t.get("handed", 0) + ps.handed,
                    "feed_duplicates": t.get("duplicates", 0) + ps.duplicates,
                    "feed_outage_polls": t.get("outages", 0) + ps.outages,
                    "feed_breaker_opens": t.get("breaker_opens", 0)
                    + ps.breaker_opens,
                    "feed_applied": t.get("applied", 0) + ap.applied,
                    "feed_deletes": t.get("deletes", 0) + ap.deletes,
                    "feed_effect_deduped": t.get("effect_deduped", 0)
                    + ap.effect_deduped,
                    "feed_stale_skipped": t.get("stale_skipped", 0)
                    + ap.stale_skipped,
                    "feed_redelivered": t.get("redelivered", 0) + ap.redelivered,
                    "pooler_crashes": self._pooler_crashes,
                    "pooler_recovery_s": round(
                        sum(self._recovery_times) / len(self._recovery_times), 6
                    )
                    if self._recovery_times
                    else 0.0,
                }
            )
        slo_summary: Dict[str, object] = {}
        if self.slo_engine is not None:
            eng = self.slo_engine
            now = self.clock.now()
            # fold whatever the tracer saw (empty under trace=False — the
            # profile then reports zero traces, deterministically)
            self.profiler.fold(self.tracer.spans())
            fired = sum(1 for a in eng.alerts if a.action == "fire")
            slo_summary = {
                "alerts_fired": fired,
                "alerts_resolved": len(eng.alerts) - fired,
                "states": eng.states(),
                "budget_remaining": {
                    name: round(eng.budget_remaining(name, now), 6)
                    for name in eng.specs
                },
                "alert_digest": eng.digest(),
                "profile_digest": self.profiler.digest(),
                "traces_folded": self.profiler.traces_folded,
            }
        # snapshot the ledger BEFORE the checkers run: several checkers
        # re-materialize lake entries / replay pipelines, which appends more
        # (legitimate) records — the reported digest is the digest of the
        # *run*, identical across same-seed replays regardless of checker set
        audit_summary: Dict[str, object] = {"enabled": bool(self.ledger.enabled)}
        if self.ledger.enabled:
            self.ledger.flush()
            audit_summary.update(
                digest=self.ledger.digest(),
                records=len(self.ledger),
                head=self.ledger.head(),
                by_kind=self.ledger.kind_counts(),
            )
        violations: List[Violation] = []
        for checker in checkers:
            violations.extend(checker.check(self))
        return FleetReport(
            seed=cfg.seed,
            log_digest=self.log.digest(),
            metrics=metrics,
            violations=violations,
            trace_digest=self.tracer.digest(),
            slo=slo_summary,
            audit=audit_summary,
        )


class _LoggingWorker(DeidWorker):
    """DeidWorker that reports each researcher-visible delivery (a processed
    message, not a dedup ack) into the sim's freshness ledger, tagged with the
    source etag the journal pinned at read time."""

    def process(self, broker, msg, injector=None) -> float:
        before = self.processed
        spent = super().process(broker, msg, injector)
        if self.processed > before:
            self._sim._log_delivery(
                msg.key, msg.payload["accession"], self.journal.etag_for(msg.key)
            )
            self._sim._slo_delivery(msg)
        return spent


class DeidWorkerProxyFactory:
    """Worker factory that reads ``sim.pipeline`` at spawn time, so workers
    created after a ``ruleset_edit`` chaos event pick up the edited pipeline
    while already-running workers keep the old one (a rolling deploy)."""

    def __init__(self, sim: FleetSim) -> None:
        self.sim = sim

    def __call__(self, wid: str) -> DeidWorker:
        w = _LoggingWorker(
            wid, self.sim.pipeline, self.sim.source, self.sim.dest,
            self.sim.journal, throughput=self.sim.config.worker_throughput,
            fence_stale_reads=self.sim.config.fence_stale_reads,
            tracer=self.sim.tracer,
            ledger=self.sim.ledger,
            audit_emit_provenance=not self.sim.config.audit_drop_provenance,
        )
        w._sim = self.sim
        return w
