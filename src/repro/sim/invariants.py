"""Invariant checkers: the conformance contract of the fleet simulator.

Each checker inspects the *real* post-run state of a :class:`FleetSim` — the
journal file, the researcher bucket's bytes, the result lake, the autoscaler's
accounting — and returns :class:`Violation`\\ s. Checkers never consult the
event log for truth (the log is evidence for humans; the stores are the
ground truth), and they are read-only except for ``NoWedgedSubscribers``,
which runs a final ``planner.resolve()`` the way any live deployment would.

The contract (DESIGN.md §7):

* a checker returns ``[]`` iff the invariant held for the whole run;
* every violation carries enough detail to reproduce (key / path / numbers);
* checkers must themselves be deterministic — same sim state, same report.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List

from repro.dicom.devices import DeviceKey, registry

if TYPE_CHECKING:  # pragma: no cover - type-only
    from repro.sim.harness import FleetSim


@dataclass(frozen=True)
class Violation:
    checker: str
    detail: str


class InvariantChecker:
    name = "base"

    def check(self, sim: "FleetSim") -> List[Violation]:
        raise NotImplementedError

    def _v(self, detail: str) -> Violation:
        return Violation(self.name, detail)


class ExactlyOnceDelivery(InvariantChecker):
    """At-least-once transport + journal dedup must net out to exactly-once
    effect: worker `processed` counters equal unique journal completions, and
    every completion maps to a submitted key with its outputs in the bucket."""

    name = "exactly_once"

    def check(self, sim: "FleetSim") -> List[Violation]:
        out: List[Violation] = []
        completed = sim.journal.completed_keys()
        processed = sum(w.processed for w in sim.pool._all_workers)
        # a supersession is a legitimate second completion of the same key —
        # the source mutated and the key was incrementally re-de-identified
        expected = len(completed) + sim.journal.supersessions
        if processed != expected:
            out.append(
                self._v(
                    f"worker processed counters ({processed}) != unique journal "
                    f"completions + supersessions ({expected}): some study was "
                    "processed more than once or a completion was never journaled"
                )
            )
        unknown = completed - sim.submitted_keys()
        if unknown:
            out.append(self._v(f"journal holds never-submitted keys: {sorted(unknown)}"))
        for key in sorted(completed):
            manifest = sim.journal.manifest_for(key)
            if manifest is None:
                out.append(self._v(f"{key}: done-record without a manifest"))
                continue
            rid = manifest.request_id
            n_out = len(sim.dest.store.list(f"out/{rid}/"))
            n_anon = manifest.counts()["anonymized"]
            if n_out != n_anon:
                out.append(
                    self._v(
                        f"{key}: manifest says {n_anon} anonymized instances but the "
                        f"researcher bucket holds {n_out} under out/{rid}/"
                    )
                )
        return out


class PhiBoundary(InvariantChecker):
    """No researcher-visible byte may contain PHI: original MRNs, patient
    names, accessions (of any source version ever ingested) must not appear in
    any bucket blob or warm-served output, and every delivered image must have
    its device's burn-in regions blanked (checked from the output's own kept
    equipment tags, so re-ingested device swaps are covered — the registry
    synthesizes geometry for *any* key, so novel unknown-device variants are
    held to the same standard). On top of the geometry check, every delivered
    frame is scanned by the text-band detector oracle (DESIGN.md §9): a
    detectable band surviving in researcher-visible pixels is a violation
    regardless of what any registry believes — this is what fails when the
    detector is disabled while unknown-device traffic carries burned-in text
    (the subsystem's negative control)."""

    name = "phi_boundary"

    def _scan_text_bands(self, ds, where: str) -> List[Violation]:
        """Detector-oracle audit of delivered pixels (default policy knobs —
        the auditor's own standard, independent of the fleet's config)."""
        if ds.pixels is None or ds.pixels.ndim != 2:
            return []
        from repro.detect import DetectorPolicy, detect_bands_for

        bands, _ = detect_bands_for(ds, DetectorPolicy())
        if not bands:
            return []
        return [
            self._v(
                f"{where}: delivered pixels still contain detectable text "
                f"band(s) {bands} (burned-in PHI survived the scrub)"
            )
        ]

    def _forbidden(self, sim: "FleetSim") -> Dict[bytes, str]:
        bad: Dict[bytes, str] = {}
        for study in sim.study_versions():
            bad[study.mrn.encode()] = f"MRN of {study.accession}"
            bad[study.patient_name.encode()] = f"patient name of {study.accession}"
        return bad

    def _scan_blob(self, blob: bytes, where: str, bad: Dict[bytes, str]) -> List[Violation]:
        return [
            self._v(f"{where}: contains {what} ({token!r})")
            for token, what in bad.items()
            if token in blob
        ]

    def _scan_pixels(self, ds, where: str) -> List[Violation]:
        if ds.pixels is None:
            return []
        key = DeviceKey(
            str(ds.get("Modality", "")),
            str(ds.get("Manufacturer", "")),
            str(ds.get("ManufacturerModelName", "")),
            int(ds.get("Rows", 0) or 0),
            int(ds.get("Columns", 0) or 0),
        )
        if not registry().known(key):
            # unknown variant: registry geometry is synthesized, not a
            # contract — the device never had a scrub rule, so clean slices
            # legitimately keep anatomy in those rows. The pixel-truth
            # standard (_scan_text_bands: no detectable band survives)
            # covers these instances instead.
            return []
        out: List[Violation] = []
        for x, y, w, h in registry().scrub_rects(key):
            region = ds.pixels[y : y + h, x : x + w]
            if region.size and int(region.max()) != 0:
                out.append(
                    self._v(
                        f"{where}: device region ({x},{y},{w},{h}) of "
                        f"{key.id()} not blanked (max={int(region.max())})"
                    )
                )
        return out

    def check(self, sim: "FleetSim") -> List[Violation]:
        bad = self._forbidden(sim)
        out: List[Violation] = []
        for path in sim.dest.store.list("out/"):
            blob = sim.dest.store.get(path)
            ds = pickle.loads(blob)
            out.extend(self._scan_blob(blob, f"bucket:{path}", bad))
            out.extend(self._scan_pixels(ds, f"bucket:{path}"))
            out.extend(self._scan_text_bands(ds, f"bucket:{path}"))
        for _, ticket in sim.tickets:
            for acc, datasets in ticket.outputs.items():
                for i, ds in enumerate(datasets):
                    where = f"ticket{ticket.cohort_id}:{acc}[{i}]"
                    out.extend(self._scan_blob(pickle.dumps(ds), where, bad))
                    out.extend(self._scan_pixels(ds, where))
                    out.extend(self._scan_text_bands(ds, where))
        return out


class WarmReplayIdentity(InvariantChecker):
    """Results served warm from the result lake must be byte-identical to
    what the cold path computes right now — re-runs every warm-served study
    through a lake-less clone of the current pipeline and compares pickles."""

    name = "warm_replay"

    def check(self, sim: "FleetSim") -> List[Violation]:
        from repro.core.pipeline import build_request

        out: List[Violation] = []
        for _, ticket in sim.tickets:
            for acc in ticket.hits:
                if acc not in ticket.outputs:
                    continue  # journal-hit: manifest replayed, no lake bytes
                # replay against the exact source version the hit was served
                # from (a later re-ingest must not shift the oracle)
                study = sim._etag_study[sim._hit_etag[(ticket.cohort_id, acc)]]
                pseudo = sim.service._studies[ticket.study_id]
                request = build_request(pseudo, acc, study.mrn)
                cold = sim.cold_pipeline_for(ticket).run_study(
                    study, request, "oracle"
                )
                warm_bytes = [pickle.dumps(ds) for ds in ticket.outputs[acc]]
                cold_bytes = [pickle.dumps(ds) for ds in cold.delivered]
                if warm_bytes != cold_bytes:
                    out.append(
                        self._v(
                            f"ticket{ticket.cohort_id}:{acc}: warm replay differs "
                            f"from cold path ({len(warm_bytes)} vs "
                            f"{len(cold_bytes)} instances or byte mismatch)"
                        )
                    )
        return out


class AutoscalerAccounting(InvariantChecker):
    """`instance_seconds` must equal the piecewise-constant integral of the
    pool size over the tick log, and the dollar cost must be that integral
    times the configured hourly rate."""

    name = "autoscaler_accounting"

    def check(self, sim: "FleetSim") -> List[Violation]:
        a = sim.pool.autoscaler
        log = a.tick_log
        integral = sum(
            n * (log[i + 1][0] - log[i][0]) for i, (_, n) in enumerate(log[:-1])
        )
        out: List[Violation] = []
        if abs(integral - a.instance_seconds) > 1e-6 * max(1.0, integral):
            out.append(
                self._v(
                    f"instance_seconds={a.instance_seconds:.6f} but tick-log "
                    f"integral={integral:.6f} over {len(log)} ticks"
                )
            )
        want_cost = a.instance_seconds / 3600.0 * a.config.instance_cost_per_hour
        if abs(a.cost_usd() - want_cost) > 1e-9:
            out.append(self._v(f"cost_usd()={a.cost_usd()} != {want_cost}"))
        return out


class NoWedgedSubscribers(InvariantChecker):
    """After a final resolve, no cohort ticket may be waiting on work that no
    longer exists: every pending accession must map to a live in-flight
    registration, and the planner must report no wedged registrations."""

    name = "no_wedged_subscribers"

    def check(self, sim: "FleetSim") -> List[Violation]:
        planner = sim.service.planner
        planner.resolve()
        out = [
            self._v(f"in-flight registration {key} can never resolve")
            for key in planner.audit_wedged()
        ]
        inflight = set(planner.inflight_keys())
        for _, ticket in sim.tickets:
            # match on the full study-scoped key: another IRB's registration
            # for the same accession must not mask this ticket's wedge
            stuck = {
                acc for acc in ticket.pending
                if f"{ticket.study_id}/{acc}" not in inflight
            }
            if stuck:
                out.append(
                    self._v(
                        f"ticket{ticket.cohort_id} pending on {sorted(stuck)} "
                        "with no in-flight registration (subscriber wedged)"
                    )
                )
        return out


class LakeConsistency(InvariantChecker):
    """The result lake's byte accounting must match its index, stay within
    budget, and every indexed key must still have backing bytes."""

    name = "lake_consistency"

    def check(self, sim: "FleetSim") -> List[Violation]:
        lake = sim.lake
        out: List[Violation] = []
        indexed = sum(lake._lru.values())
        if indexed != lake.stored_bytes():
            out.append(
                self._v(f"stored_bytes={lake.stored_bytes()} != index sum {indexed}")
            )
        if lake.stored_bytes() > lake.max_bytes:
            out.append(
                self._v(f"stored {lake.stored_bytes()} bytes > budget {lake.max_bytes}")
            )
        for key in lake.keys():
            if lake.backend.get_bytes(key) is None:
                out.append(self._v(f"indexed key {key} has no backing blob"))
        return out


class JournalDurability(InvariantChecker):
    """A fresh replay of the journal file must reconstruct exactly the
    completions the live journal reports (fsync'd, torn-tail tolerant)."""

    name = "journal_durability"

    def check(self, sim: "FleetSim") -> List[Violation]:
        from repro.queueing.journal import Journal

        replayed = Journal(sim.journal.path)
        try:
            if replayed.completed_keys() != sim.journal.completed_keys():
                missing = sim.journal.completed_keys() - replayed.completed_keys()
                extra = replayed.completed_keys() - sim.journal.completed_keys()
                return [
                    self._v(
                        f"journal replay mismatch: missing={sorted(missing)} "
                        f"extra={sorted(extra)}"
                    )
                ]
            return []
        finally:
            replayed.close()


class QueryConsistency(InvariantChecker):
    """Every query-served selection must equal a brute-force scan: the query
    is re-evaluated row by row in pure python (``catalog.query.matches_row``
    — no dictionary codes, no bitmaps, no zone-map pruning, no jax) over the
    exact source versions the catalog had indexed at serve time, and the
    selection's accessions, per-accession instance counts, and byte totals
    must all agree."""

    name = "query_consistency"

    def check(self, sim: "FleetSim") -> List[Violation]:
        from repro.catalog.columns import rows_from_study
        from repro.catalog.query import matches_row

        out: List[Violation] = []
        for qi, (arr, selection, snapshot) in enumerate(sim.query_log):
            where = f"query{qi} ({selection.query})"
            counts: Dict[str, int] = {}
            total_bytes = 0
            for acc, etag in snapshot.items():
                study = sim._etag_study.get(etag)
                if study is None:
                    out.append(
                        self._v(f"{where}: no retained source version for "
                                f"{acc} etag={etag}")
                    )
                    continue
                n = 0
                for row in rows_from_study(study):
                    if matches_row(arr.query, row):
                        n += 1
                        total_bytes += row["nbytes"]
                if n:
                    counts[acc] = n
            if list(selection.accessions) != sorted(counts):
                out.append(
                    self._v(
                        f"{where}: selection accessions "
                        f"{list(selection.accessions)} != brute-force "
                        f"{sorted(counts)}"
                    )
                )
                continue
            if dict(selection.instance_counts) != counts:
                out.append(
                    self._v(
                        f"{where}: instance counts {selection.instance_counts} "
                        f"!= brute-force {counts}"
                    )
                )
            if selection.total_instances != sum(counts.values()):
                out.append(
                    self._v(
                        f"{where}: total_instances={selection.total_instances} "
                        f"!= brute-force {sum(counts.values())}"
                    )
                )
            if selection.total_bytes != total_bytes:
                out.append(
                    self._v(
                        f"{where}: total_bytes={selection.total_bytes} "
                        f"!= brute-force {total_bytes}"
                    )
                )
        return out


class CheckpointMonotonicity(InvariantChecker):
    """The pooler checkpoint must account for every committed feed event
    exactly once after the final drain: no event lost across crashes (every
    committed seq was checkpointed as seen AND reached a terminal outcome),
    no event double-applied (two outcome records for one seq), and per
    accession the *applied* outcomes never regress in seq order. Verified
    against a fresh replay of the durable checkpoint file — the same
    durability standard the journal is held to."""

    name = "checkpoint_monotonicity"

    def check(self, sim: "FleetSim") -> List[Violation]:
        if getattr(sim, "feed", None) is None:
            return []
        from repro.ingest.checkpoint import Checkpoint

        ck = Checkpoint(sim.pooler.checkpoint.path)
        try:
            out: List[Violation] = []
            committed = {e.seq for e in sim.feed.events}
            lost = committed - ck.seen
            if lost:
                out.append(
                    self._v(f"feed events never checkpointed as seen: {sorted(lost)}")
                )
            unapplied = committed - set(ck.outcomes)
            if unapplied:
                out.append(
                    self._v(
                        "feed events with no terminal outcome after drain "
                        f"(lost work): {sorted(unapplied)}"
                    )
                )
            phantom = set(ck.outcomes) - committed
            if phantom:
                out.append(
                    self._v(f"outcomes for never-committed seqs: {sorted(phantom)}")
                )
            if ck.double_applied:
                out.append(
                    self._v(
                        f"seqs with more than one outcome record (double-applied "
                        f"after crash): {sorted(set(ck.double_applied))}"
                    )
                )
            last_applied: Dict[str, int] = {}
            for rec in ck.outcome_log:
                if rec.get("outcome") != "applied":
                    continue
                acc = rec.get("accession", "")
                if rec["seq"] < last_applied.get(acc, 0):
                    out.append(
                        self._v(
                            f"{acc}: applied seq {rec['seq']} after newer seq "
                            f"{last_applied[acc]} (out-of-order apply regressed "
                            "the lake)"
                        )
                    )
                last_applied[acc] = max(last_applied.get(acc, 0), rec["seq"])
            return out
        finally:
            ck.close()


class Freshness(InvariantChecker):
    """No delivered frame may be older than its source's last acked mutation:
    for every delivery (worker completion or warm serve), the source etag the
    content was computed from must equal the etag of the newest mutation
    acked *before* that delivery. Ordering is by the sim's global handoff
    sequence, not timestamps — two events at the same sim-time still have a
    definite order."""

    name = "freshness"

    def check(self, sim: "FleetSim") -> List[Violation]:
        out: List[Violation] = []
        mutations = getattr(sim, "mutation_log", [])
        for d in getattr(sim, "delivery_log", []):
            last = None
            for m in mutations:
                if m["accession"] == d["accession"] and m["seq"] < d["seq"]:
                    last = m
            if last is None:
                continue
            if last["etag"] is None:
                out.append(
                    self._v(
                        f"{d['key']}: delivered after the source study was "
                        f"deleted (mutation seq {last['seq']})"
                    )
                )
            elif d["etag"] is not None and d["etag"] != last["etag"]:
                out.append(
                    self._v(
                        f"{d['key']}: delivered content from etag "
                        f"{d['etag'][:12]} but the last acked mutation "
                        f"(seq {last['seq']}) committed {last['etag'][:12]} "
                        "— stale bytes delivered"
                    )
                )
        return out


class NoFullReingest(InvariantChecker):
    """Catalog delta work must be proportional to changed rows, counter-
    asserted: the catalog's cumulative row/tombstone counters must equal
    exactly what the harness's applied mutations account for. A hidden full
    rebuild (re-indexing unchanged studies) inflates the counters past the
    per-mutation budget and fails here."""

    name = "no_full_reingest"

    def check(self, sim: "FleetSim") -> List[Violation]:
        expected_rows = getattr(sim, "_expected_catalog_rows", None)
        if expected_rows is None:
            return []
        out: List[Violation] = []
        if sim.catalog.stats.rows != expected_rows:
            out.append(
                self._v(
                    f"catalog ingested {sim.catalog.stats.rows} rows but the "
                    f"applied mutations account for {expected_rows} — delta "
                    "ingest did more work than the changed rows"
                )
            )
        expected_tombs = sim._expected_tombstones
        if sim.catalog.stats.tombstoned != expected_tombs:
            out.append(
                self._v(
                    f"catalog tombstoned {sim.catalog.stats.tombstoned} rows "
                    f"but the applied mutations account for {expected_tombs}"
                )
            )
        return out


class TraceIntegrity(InvariantChecker):
    """The trace layer must be structurally sound and complete: no span left
    open at end of run, every timestamp within [0, final sim time] with
    ``t1 >= t0``, every ``parent_id`` resolving to an earlier-started span of
    the *same* trace, and every journal-completed key carrying at least one
    ``worker.process`` span (a completion that left no trace is untraceable
    work). Skipped when the run was configured with ``trace=False`` — the
    NULL_TRACER records nothing by design."""

    name = "trace_integrity"

    def check(self, sim: "FleetSim") -> List[Violation]:
        tracer = getattr(sim, "tracer", None)
        if tracer is None or not getattr(tracer, "enabled", False):
            return []
        out: List[Violation] = []
        if tracer.open_count != 0:
            open_names = [s.name for s in tracer._stack]
            out.append(
                self._v(
                    f"{tracer.open_count} span(s) still open at end of run: "
                    f"{open_names}"
                )
            )
        now = sim.clock.now()
        spans = tracer.spans()
        by_trace: Dict[str, Dict[str, object]] = {}
        for s in spans:
            by_trace.setdefault(s.trace_id, {})[s.span_id] = s
        for s in spans:
            if s.t1 is None:
                out.append(self._v(f"{s.span_id} ({s.name}): finished without t1"))
                continue
            if not (0.0 <= s.t0 <= s.t1 <= now + 1e-9):
                out.append(
                    self._v(
                        f"{s.span_id} ({s.name}): timestamps [{s.t0}, {s.t1}] "
                        f"outside the run's clock range [0, {now}]"
                    )
                )
            if s.parent_id is not None:
                parent = by_trace[s.trace_id].get(s.parent_id)
                if parent is None:
                    out.append(
                        self._v(
                            f"{s.span_id} ({s.name}): parent {s.parent_id} not "
                            f"in trace {s.trace_id} (dangling parent)"
                        )
                    )
                elif parent.seq >= s.seq:
                    out.append(
                        self._v(
                            f"{s.span_id} ({s.name}): parent {s.parent_id} "
                            "started after its child (inverted parentage)"
                        )
                    )
        traced_keys = {
            s.attrs.get("key") for s in spans if s.name == "worker.process"
        }
        untraced = sim.journal.completed_keys() - traced_keys
        if untraced:
            out.append(
                self._v(
                    "journal-completed keys with no worker.process span: "
                    f"{sorted(untraced)}"
                )
            )
        return out


class TelemetryPhiBoundary(InvariantChecker):
    """PHI must never cross the telemetry exporters: every span/metric export
    surface (JSONL spans, JSONL metrics, Chrome trace), rendered through the
    run's configured redaction, must be free of any MRN or patient name of
    any source version ever ingested. This is the *export* analogue of
    :class:`PhiBoundary` — the trace may internally reference study keys (the
    fleet's own identifiers), but identified-patient tokens in exported bytes
    are a violation. With ``telemetry_redact=False`` and planted PHI this
    checker must fire (its negative control)."""

    name = "telemetry_phi_boundary"

    def check(self, sim: "FleetSim") -> List[Violation]:
        tracer = getattr(sim, "tracer", None)
        if tracer is None:
            return []
        import json

        from repro.obs.export import (
            Redactor,
            export_metrics_jsonl,
            export_spans_jsonl,
            to_chrome_trace,
        )

        redactor = Redactor(enabled=getattr(sim.config, "telemetry_redact", True))
        spans = tracer.spans()
        exported = export_spans_jsonl(spans, redactor)
        registry = getattr(sim, "registry", None)
        if registry is not None:
            exported += export_metrics_jsonl(registry.snapshot(), redactor)
        exported += json.dumps(to_chrome_trace(spans, redactor), sort_keys=True)
        out: List[Violation] = []
        for token, what in PhiBoundary()._forbidden(sim).items():
            text = token.decode()
            if text and text in exported:
                out.append(
                    self._v(f"exported telemetry contains {what} ({text!r})")
                )
        return out


class MetricsConservation(InvariantChecker):
    """Flow counters must balance exactly — work is neither minted nor lost
    between subsystems:

    * planner admission: every admitted accession lands in exactly one bin
      (``accessions == lake_hits + journal_hits + coalesced + published +
      rejected``), and every publish reaches exactly one terminal state
      (``published == resolved + dead_lettered + still-in-flight``);
    * broker copy conservation (both queues): every message copy entering a
      broker (``published + speculative_clones``) is acked, dead-lettered, or
      still outstanding;
    * delivery accounting: every serve-queue delivery the broker handed out
      was terminally handled by a worker (processed / deduped / fenced /
      zombie-aborted) or died in a crash;
    * registry aggregation: the shared registry's summed series must agree
      with the per-instance counters it aggregates.
    """

    name = "metrics_conservation"

    def _balance(self, what: str, lhs: int, rhs: int, detail: str) -> List[Violation]:
        if lhs != rhs:
            return [self._v(f"{what}: {lhs} != {rhs} ({detail})")]
        return []

    def check(self, sim: "FleetSim") -> List[Violation]:
        out: List[Violation] = []
        ps = sim.service.planner.stats
        out += self._balance(
            "planner admission",
            ps.accessions,
            ps.lake_hits + ps.journal_hits + ps.coalesced + ps.published + ps.rejected,
            "accessions vs lake_hits+journal_hits+coalesced+published+rejected",
        )
        out += self._balance(
            "planner in-flight lifecycle",
            ps.published,
            ps.resolved + ps.dead_lettered + len(sim.service.planner._inflight),
            "published vs resolved+dead_lettered+in_flight",
        )
        brokers = [("serve broker", sim.broker)]
        if getattr(sim, "ingest_broker", None) is not None:
            brokers.append(("ingest broker", sim.ingest_broker))
        for label, broker in brokers:
            c, st = broker.counters, broker.stats()
            out += self._balance(
                f"{label} copy conservation",
                c.published + c.speculative_clones,
                c.acked + c.dead_lettered + st.available + st.leased,
                "published+speculative vs acked+dead_lettered+outstanding",
            )
        handled = (
            sum(
                w.processed + w.deduped + w.fenced + w.zombie_aborts
                for w in sim.pool._all_workers
            )
            + sim.pool.crashes
        )
        out += self._balance(
            "serve delivery accounting",
            sim.broker.counters.deliveries,
            handled,
            "broker deliveries vs worker processed+deduped+fenced+zombie+crashes",
        )
        registry = getattr(sim, "registry", None)
        if registry is not None:
            want = sum(b.counters.published for _, b in brokers)
            out += self._balance(
                "registry aggregation",
                registry.value("repro_broker_published"),
                want,
                "summed repro_broker_published vs per-broker counters",
            )
            # executor batch accounting: the executor-side instance counter
            # (now registry-backed via ExecutorStats/StatsShim) against the
            # worker pool's independently kept per-run dispatch deltas —
            # every batched instance must have been driven by some worker
            want = sum(w.batched_instances for w in sim.pool._all_workers)
            out += self._balance(
                "executor batch accounting",
                registry.value("repro_executor_instances"),
                want,
                "summed repro_executor_instances vs worker batched deltas",
            )
        return out


class SloConformance(InvariantChecker):
    """The SLO plane's outputs must be recomputable from their inputs
    (DESIGN.md §13):

    * **replay equality** — rebuilding a fresh engine from the recorded
      observation log + evaluation times must reproduce the alert sequence
      bit-for-bit (alerts are a pure function of the run, with no hidden
      state);
    * **log conformance** — the ``slo_alert`` records in the event log match
      the engine's alert list one-to-one, in order;
    * **trace cross-check** — when tracing is on, the engine's cold-serve
      observation stream must equal the latencies independently re-derived
      from the span stream (``derive_serve_observations``): every latency
      alert is recomputable from the trace digest's underlying spans.

    With the engine disabled the only requirement is that no ``slo_alert``
    records exist.
    """

    name = "slo_conformance"

    def check(self, sim: "FleetSim") -> List[Violation]:
        logged = sim.log.by_kind("slo_alert")
        eng = getattr(sim, "slo_engine", None)
        if eng is None:
            if logged:
                return [self._v(f"{len(logged)} slo_alert records with no engine")]
            return []
        out: List[Violation] = []
        replayed = eng.replay()
        if replayed.alerts != eng.alerts:
            out.append(self._v(
                f"alert replay mismatch: {len(replayed.alerts)} replayed vs "
                f"{len(eng.alerts)} recorded"
            ))
        want = [(round(a.t, 9), a.slo, a.rule, a.action) for a in eng.alerts]
        got = [(r["t"], r["slo"], r["rule"], r["action"]) for r in logged]
        if want != got:
            out.append(self._v(
                f"event-log alerts diverge from engine: {len(got)} logged vs "
                f"{len(want)} recorded"
            ))
        tracer = getattr(sim, "tracer", None)
        if tracer is not None and getattr(tracer, "enabled", False):
            from repro.obs.slo import derive_serve_observations

            derived = sorted(
                (round(t, 9), round(v, 9))
                for t, _key, v in derive_serve_observations(tracer.spans())
            )
            observed = sorted(
                (round(rec["t"], 9), round(rec["value"], 9))
                for rec in eng.obs_log
                if rec["slo"].startswith("cold_serve") and rec["value"] is not None
            )
            if derived != observed:
                out.append(self._v(
                    f"cold-serve observations diverge from the span stream: "
                    f"{len(observed)} observed vs {len(derived)} derived"
                ))
        return out


class AuditCompleteness(InvariantChecker):
    """The audit ledger must be a tamper-evident, *complete* account of the
    run, cross-checked against every other source of truth:

    1. **chain** — ``verify()`` recomputes the hash chain from disk bytes:
       any mutation, insertion, or reordering is a violation;
    2. **durability** — a fresh replay of the ledger file reproduces the
       live digest (nothing unflushed, nothing lost to a torn tail);
    3. **journal** — every journal-completed key has exactly one cold
       provenance record whose source etag matches the journal's, under a
       ruleset this fleet actually deployed; the total cold-provenance count
       equals the pool's processed count (this is the truncation bound:
       chopping the ledger's tail breaks the equality);
    4. **traces** — every cold provenance trace id resolves to a
       ``worker.process`` span (skipped under ``trace=False``);
    5. **event log** — the (key, etag) multiset of delivery records equals
       the sim's researcher-visible delivery ledger;
    6. **lake bytes** — every byte served out of / written into the lake has
       a ledger record: summed ``lake_hit``/``lake_write`` sizes equal the
       lake's own counters, and ``lru`` evictions match the eviction count;
    7. **DLQ** — dead-letter records match the broker's DLQ exactly;
    8. **ingest** — ``(feed_seq, outcome)`` of ingest records equals the
       durable checkpoint's outcome map (survives pooler crash rebuilds).

    Skipped when the run was configured with ``audit=False`` — NULL_LEDGER
    records nothing by design. Negative controls: ``audit_drop_provenance``
    (clauses 3+5), a mid-file byte flip (clause 1), and test-side counter /
    DLQ tampering (clauses 6+7)."""

    name = "audit_completeness"

    def check(self, sim: "FleetSim") -> List[Violation]:
        ledger = getattr(sim, "ledger", None)
        if ledger is None or not getattr(ledger, "enabled", False):
            return []
        from collections import Counter

        from repro.audit.ledger import AuditLedger
        from repro.audit.records import (
            DEAD_LETTER,
            DELIVERY,
            INGEST_APPLY,
            LAKE_EVICT,
            LAKE_HIT,
            LAKE_WRITE,
            PROVENANCE,
        )

        out: List[Violation] = []
        # 1. hash chain intact on disk
        for problem in ledger.verify():
            out.append(self._v(f"chain: {problem}"))
        # 2. durable replay reproduces the live chain
        replayed = AuditLedger(ledger.path)
        try:
            if replayed.digest() != ledger.digest():
                out.append(
                    self._v(
                        f"durability: replayed digest {replayed.digest()[:12]} != "
                        f"live {ledger.digest()[:12]}"
                    )
                )
        finally:
            replayed.close()
        # 3. ledger <-> journal: every completion left exactly one matching
        # cold provenance record, and nothing was chopped off the tail
        provs = ledger.records(PROVENANCE)
        cold = [p for p in provs if p.get("temp") == "cold"]
        by_key_etag = Counter((p.get("key"), p.get("etag")) for p in cold)
        deployed = set(sim._pipelines)
        for key in sorted(sim.journal.completed_keys()):
            etag = sim.journal.etag_for(key)
            n = by_key_etag.get((key, etag), 0)
            if n != 1:
                out.append(
                    self._v(
                        f"journal: completed {key} (etag {str(etag)[:12]}) has "
                        f"{n} cold provenance record(s), want exactly 1"
                    )
                )
        for p in cold:
            if p.get("ruleset") not in deployed:
                out.append(
                    self._v(
                        f"journal: provenance for {p.get('key')} names ruleset "
                        f"{str(p.get('ruleset'))[:12]} this fleet never deployed"
                    )
                )
        processed = sum(w.processed for w in sim.pool._all_workers)
        if len(cold) != processed:
            out.append(
                self._v(
                    f"journal: {len(cold)} cold provenance records != "
                    f"{processed} processed completions (ledger truncated?)"
                )
            )
        # 4. ledger <-> trace spans
        tracer = getattr(sim, "tracer", None)
        if tracer is not None and getattr(tracer, "enabled", False):
            roots = {
                s.trace_id for s in tracer.spans() if s.name == "worker.process"
            }
            for p in cold:
                if p.get("trace_id") not in roots:
                    out.append(
                        self._v(
                            f"traces: provenance for {p.get('key')} trace id "
                            f"{p.get('trace_id')} has no worker.process span"
                        )
                    )
        # 5. ledger <-> event log: delivery multisets agree
        led = Counter(
            (r.get("key"), r.get("etag")) for r in ledger.records(DELIVERY)
        )
        logged = Counter((d["key"], d["etag"]) for d in sim.delivery_log)
        if led != logged:
            missing = logged - led
            extra = led - logged
            out.append(
                self._v(
                    "event log: delivery multiset mismatch "
                    f"(unledgered={sorted(missing, key=str)} "
                    f"phantom={sorted(extra, key=str)})"
                )
            )
        # 6. every lake byte in/out/evicted is accounted
        hit_bytes = sum(r.get("nbytes", 0) for r in ledger.records(LAKE_HIT))
        write_bytes = sum(r.get("nbytes", 0) for r in ledger.records(LAKE_WRITE))
        lru_evicts = sum(
            1 for r in ledger.records(LAKE_EVICT) if r.get("reason") == "lru"
        )
        if hit_bytes != sim.lake.stats.bytes_out:
            out.append(
                self._v(
                    f"lake: ledgered hit bytes {hit_bytes} != "
                    f"bytes_out {sim.lake.stats.bytes_out}"
                )
            )
        if write_bytes != sim.lake.stats.bytes_in:
            out.append(
                self._v(
                    f"lake: ledgered write bytes {write_bytes} != "
                    f"bytes_in {sim.lake.stats.bytes_in}"
                )
            )
        if lru_evicts != sim.lake.stats.evictions:
            out.append(
                self._v(
                    f"lake: {lru_evicts} ledgered lru evictions != "
                    f"{sim.lake.stats.evictions} counted"
                )
            )
        # 7. dead-letter records mirror the broker's DLQ
        led_dlq = sorted(r.get("key") for r in ledger.records(DEAD_LETTER))
        broker_dlq = sorted(m.key for m in sim.broker.dead_letter)
        if led_dlq != broker_dlq:
            out.append(
                self._v(
                    f"dlq: ledgered {led_dlq} != broker {broker_dlq}"
                )
            )
        # 8. ingest outcomes mirror the durable checkpoint
        if sim.feed is not None and sim.applier is not None:
            led_ops = Counter(
                (r.get("feed_seq"), r.get("outcome"))
                for r in ledger.records(INGEST_APPLY)
            )
            ckpt_ops = Counter(
                (seq, rec.get("outcome"))
                for seq, rec in sim.applier.checkpoint.outcomes.items()
            )
            if led_ops != ckpt_ops:
                out.append(
                    self._v(
                        "ingest: ledgered outcomes disagree with checkpoint "
                        f"(missing={sorted(ckpt_ops - led_ops)} "
                        f"extra={sorted(led_ops - ckpt_ops)})"
                    )
                )
        return out


DEFAULT_CHECKERS = (
    ExactlyOnceDelivery(),
    PhiBoundary(),
    WarmReplayIdentity(),
    AutoscalerAccounting(),
    NoWedgedSubscribers(),
    LakeConsistency(),
    JournalDurability(),
    QueryConsistency(),
    CheckpointMonotonicity(),
    Freshness(),
    NoFullReingest(),
    TraceIntegrity(),
    TelemetryPhiBoundary(),
    MetricsConservation(),
    SloConformance(),
    AuditCompleteness(),
)
