"""Traffic models: seeded cohort-arrival schedules (DESIGN.md §7).

A traffic model turns (seed, corpus) into a flat, time-sorted list of
:class:`CohortArrival`\\ s before the simulation starts — arrivals are *data*,
not code, so the same seed always yields the same schedule and the event loop
never consults randomness at run time.

Three shapes, matching the operational patterns the paper's fleet must absorb:

* :class:`BurstyTraffic` — clustered cohort submissions (a lab submits its
  whole project at once), exponential gaps between bursts;
* :class:`DiurnalTraffic` — researcher-working-hours load over multiple
  simulated days, thinned at night;
* :class:`ReplayStorm` — one seeding cohort, then a storm of mostly-warm
  re-requests (the DESIGN.md §6 repeat-traffic regime, default 90% warm);
* :class:`QueryMix` — query-driven arrivals (DESIGN.md §8): researchers
  submit metadata *predicates*, not accession lists, and the catalog
  resolves the cohort at serve time. Selectivity knobs shape the mix from
  scan-everything sweeps to single-modality-single-year slivers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.catalog.query import And, Eq, Not, Or, Predicate, Range
from repro.sim.events import HashRng


@dataclass(frozen=True)
class CohortArrival:
    t: float
    study_id: str           # research study (IRB protocol) submitting
    accessions: tuple       # imaging accessions requested (tuple: hashable/frozen)


@dataclass(frozen=True)
class QueryArrival:
    """A cohort request expressed as a metadata query. Predicates are frozen
    dataclasses, so arrivals stay hashable/replayable data just like
    accession tuples."""

    t: float
    study_id: str
    query: Predicate


class TrafficModel:
    """Base: subclasses implement :meth:`schedule`."""

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        raise NotImplementedError


@dataclass
class BurstyTraffic(TrafficModel):
    """Bursts of cohorts with exponential inter-burst gaps."""

    n_bursts: int = 3
    cohorts_per_burst: int = 2
    cohort_size: int = 4
    mean_gap: float = 600.0          # seconds between bursts
    intra_gap: float = 10.0          # seconds between cohorts inside a burst
    study_ids: Sequence[str] = ("IRB-A", "IRB-B")

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "bursty")
        out: List[CohortArrival] = []
        t = 0.0
        for b in range(self.n_bursts):
            if b:
                t += rng.exp(self.mean_gap, "gap", b)
            for c in range(self.cohorts_per_burst):
                accs = rng.sample(list(corpus), self.cohort_size, "cohort", b, c)
                out.append(
                    CohortArrival(
                        t=t + c * self.intra_gap,
                        study_id=rng.choice(list(self.study_ids), "study", b, c),
                        accessions=tuple(accs),
                    )
                )
        return sorted(out, key=lambda a: (a.t, a.study_id))


@dataclass
class DiurnalTraffic(TrafficModel):
    """Cohorts spread over ``days`` with a day/night density cycle: a cohort
    drawn for hour ``h`` survives with probability prop. to the diurnal
    weight, peaking mid-workday."""

    days: int = 2
    cohorts_per_day: int = 6
    cohort_size: int = 3
    study_ids: Sequence[str] = ("IRB-DAY",)

    @staticmethod
    def _weight(hour: float) -> float:
        # smooth bump centred on 13:00, near-zero at night
        return max(0.05, math.sin(math.pi * max(0.0, min(1.0, (hour - 7.0) / 12.0))))

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "diurnal")
        out: List[CohortArrival] = []
        for d in range(self.days):
            placed = 0
            slot = 0
            # draw candidate slots until the day's quota is placed (bounded)
            while placed < self.cohorts_per_day and slot < self.cohorts_per_day * 8:
                hour = 24.0 * rng.u("hour", d, slot)
                if rng.u("keep", d, slot) < self._weight(hour):
                    t = (d * 24.0 + hour) * 3600.0
                    accs = rng.sample(list(corpus), self.cohort_size, "cohort", d, slot)
                    out.append(
                        CohortArrival(
                            t=t,
                            study_id=rng.choice(list(self.study_ids), "study", d, slot),
                            accessions=tuple(accs),
                        )
                    )
                    placed += 1
                slot += 1
        return sorted(out, key=lambda a: (a.t, a.study_id))


@dataclass
class ReplayStorm(TrafficModel):
    """One seeding cohort over a base set, then ``n_replays`` cohorts drawing
    ``warm_fraction`` of their accessions from the (now warm) base set and
    the rest from the cold remainder — the 90%-warm storm regime."""

    warm_fraction: float = 0.9
    base_size: int = 6
    n_replays: int = 4
    cohort_size: int = 5
    gap: float = 120.0
    study_id: str = "IRB-STORM"

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "storm")
        corpus = list(corpus)
        base = rng.sample(corpus, min(self.base_size, len(corpus)), "base")
        cold_pool = [a for a in corpus if a not in set(base)]
        out = [CohortArrival(t=0.0, study_id=self.study_id, accessions=tuple(base))]
        for r in range(self.n_replays):
            n_warm = min(int(round(self.warm_fraction * self.cohort_size)), len(base))
            accs = rng.sample(base, n_warm, "warm", r)
            n_cold = self.cohort_size - n_warm
            if n_cold and cold_pool:
                accs = accs + rng.sample(cold_pool, n_cold, "cold", r)
            out.append(
                CohortArrival(
                    t=(r + 1) * self.gap, study_id=self.study_id, accessions=tuple(accs)
                )
            )
        return out


@dataclass
class QueryMix(TrafficModel):
    """Seeded mix of metadata queries with selectivity knobs.

    Five shapes, drawn per arrival: ``broad`` (a StudyDate range spanning the
    whole archive — selects ~everything), ``modality`` (one modality),
    ``year`` (one acquisition year), ``and`` (modality ∧ year — the narrow
    sliver), and ``negate`` (¬modality ∨ second modality — exercises NOT/OR
    through the bitmap path). The fractions are the selectivity knobs; they
    are weights over shapes, renormalized, so any subset can be zeroed.
    """

    n_queries: int = 6
    mean_gap: float = 240.0
    study_ids: Sequence[str] = ("IRB-Q",)
    modalities: Sequence[str] = ("CT", "MR", "DX", "CR", "US", "PT")
    years: Sequence[int] = (2015, 2016, 2017, 2018, 2019)
    broad_fraction: float = 0.2
    modality_fraction: float = 0.25
    year_fraction: float = 0.2
    and_fraction: float = 0.2
    negate_fraction: float = 0.15

    def _make_query(self, rng: HashRng, q: int) -> Predicate:
        mods = list(self.modalities)
        years = list(self.years)
        mod = rng.choice(mods, "mod", q)
        year = rng.choice(years, "year", q)
        year_range = Range("study_date", year * 10000 + 101, year * 10000 + 1231)
        weights = [
            ("broad", self.broad_fraction),
            ("modality", self.modality_fraction),
            ("year", self.year_fraction),
            ("and", self.and_fraction),
            ("negate", self.negate_fraction),
        ]
        total = sum(w for _, w in weights) or 1.0
        u = rng.u("shape", q) * total
        acc = 0.0
        shape = weights[-1][0]
        for name, w in weights:
            acc += w
            if u < acc:
                shape = name
                break
        if shape == "broad":
            lo, hi = min(years), max(years)
            return Range("study_date", lo * 10000 + 101, hi * 10000 + 1231)
        if shape == "modality":
            return Eq("modality", mod)
        if shape == "year":
            return year_range
        if shape == "and":
            return And(Eq("modality", mod), year_range)
        other = rng.choice(mods, "mod2", q)
        return Or(Not(Eq("modality", mod)), Eq("modality", other))

    def schedule(self, corpus: Sequence[str], seed: int) -> List[QueryArrival]:
        rng = HashRng(seed, "querymix")
        out: List[QueryArrival] = []
        t = 0.0
        for q in range(self.n_queries):
            if q:
                t += rng.exp(self.mean_gap, "gap", q)
            out.append(
                QueryArrival(
                    t=t,
                    study_id=rng.choice(list(self.study_ids), "study", q),
                    query=self._make_query(rng, q),
                )
            )
        return sorted(out, key=lambda a: (a.t, a.study_id))
