"""Traffic models: seeded cohort-arrival schedules (DESIGN.md §7).

A traffic model turns (seed, corpus) into a flat, time-sorted list of
:class:`CohortArrival`\\ s before the simulation starts — arrivals are *data*,
not code, so the same seed always yields the same schedule and the event loop
never consults randomness at run time.

Three shapes, matching the operational patterns the paper's fleet must absorb:

* :class:`BurstyTraffic` — clustered cohort submissions (a lab submits its
  whole project at once), exponential gaps between bursts;
* :class:`DiurnalTraffic` — researcher-working-hours load over multiple
  simulated days, thinned at night;
* :class:`ReplayStorm` — one seeding cohort, then a storm of mostly-warm
  re-requests (the DESIGN.md §6 repeat-traffic regime, default 90% warm).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.sim.events import HashRng


@dataclass(frozen=True)
class CohortArrival:
    t: float
    study_id: str           # research study (IRB protocol) submitting
    accessions: tuple       # imaging accessions requested (tuple: hashable/frozen)


class TrafficModel:
    """Base: subclasses implement :meth:`schedule`."""

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        raise NotImplementedError


@dataclass
class BurstyTraffic(TrafficModel):
    """Bursts of cohorts with exponential inter-burst gaps."""

    n_bursts: int = 3
    cohorts_per_burst: int = 2
    cohort_size: int = 4
    mean_gap: float = 600.0          # seconds between bursts
    intra_gap: float = 10.0          # seconds between cohorts inside a burst
    study_ids: Sequence[str] = ("IRB-A", "IRB-B")

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "bursty")
        out: List[CohortArrival] = []
        t = 0.0
        for b in range(self.n_bursts):
            if b:
                t += rng.exp(self.mean_gap, "gap", b)
            for c in range(self.cohorts_per_burst):
                accs = rng.sample(list(corpus), self.cohort_size, "cohort", b, c)
                out.append(
                    CohortArrival(
                        t=t + c * self.intra_gap,
                        study_id=rng.choice(list(self.study_ids), "study", b, c),
                        accessions=tuple(accs),
                    )
                )
        return sorted(out, key=lambda a: (a.t, a.study_id))


@dataclass
class DiurnalTraffic(TrafficModel):
    """Cohorts spread over ``days`` with a day/night density cycle: a cohort
    drawn for hour ``h`` survives with probability prop. to the diurnal
    weight, peaking mid-workday."""

    days: int = 2
    cohorts_per_day: int = 6
    cohort_size: int = 3
    study_ids: Sequence[str] = ("IRB-DAY",)

    @staticmethod
    def _weight(hour: float) -> float:
        # smooth bump centred on 13:00, near-zero at night
        return max(0.05, math.sin(math.pi * max(0.0, min(1.0, (hour - 7.0) / 12.0))))

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "diurnal")
        out: List[CohortArrival] = []
        for d in range(self.days):
            placed = 0
            slot = 0
            # draw candidate slots until the day's quota is placed (bounded)
            while placed < self.cohorts_per_day and slot < self.cohorts_per_day * 8:
                hour = 24.0 * rng.u("hour", d, slot)
                if rng.u("keep", d, slot) < self._weight(hour):
                    t = (d * 24.0 + hour) * 3600.0
                    accs = rng.sample(list(corpus), self.cohort_size, "cohort", d, slot)
                    out.append(
                        CohortArrival(
                            t=t,
                            study_id=rng.choice(list(self.study_ids), "study", d, slot),
                            accessions=tuple(accs),
                        )
                    )
                    placed += 1
                slot += 1
        return sorted(out, key=lambda a: (a.t, a.study_id))


@dataclass
class ReplayStorm(TrafficModel):
    """One seeding cohort over a base set, then ``n_replays`` cohorts drawing
    ``warm_fraction`` of their accessions from the (now warm) base set and
    the rest from the cold remainder — the 90%-warm storm regime."""

    warm_fraction: float = 0.9
    base_size: int = 6
    n_replays: int = 4
    cohort_size: int = 5
    gap: float = 120.0
    study_id: str = "IRB-STORM"

    def schedule(self, corpus: Sequence[str], seed: int) -> List[CohortArrival]:
        rng = HashRng(seed, "storm")
        corpus = list(corpus)
        base = rng.sample(corpus, min(self.base_size, len(corpus)), "base")
        cold_pool = [a for a in corpus if a not in set(base)]
        out = [CohortArrival(t=0.0, study_id=self.study_id, accessions=tuple(base))]
        for r in range(self.n_replays):
            n_warm = min(int(round(self.warm_fraction * self.cohort_size)), len(base))
            accs = rng.sample(base, n_warm, "warm", r)
            n_cold = self.cohort_size - n_warm
            if n_cold and cold_pool:
                accs = accs + rng.sample(cold_pool, n_cold, "cold", r)
            out.append(
                CohortArrival(
                    t=(r + 1) * self.gap, study_id=self.study_id, accessions=tuple(accs)
                )
            )
        return out
