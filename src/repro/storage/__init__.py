from repro.storage.object_store import ObjectStore, StudyStore

__all__ = ["ObjectStore", "StudyStore"]
