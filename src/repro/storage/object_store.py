"""Object storage stand-in (paper: "encrypted and distributed cloud object
storage service").

Two layers:

* :class:`ObjectStore` — a key/value blob store with byte accounting and
  optional at-rest obfuscation. The obfuscation is a keyed XOR keystream —
  explicitly NOT real cryptography (offline container, no AES available);
  it exists so tests can assert the at-rest representation differs from the
  plaintext and that reads require the key, i.e. the *interface* of an
  encrypted store is honored end to end.
* :class:`StudyStore` — typed façade holding identified studies (the data
  lake) or de-identified outputs (the researcher bucket), with egress
  accounting used by the Table-1 cost model.
"""
from __future__ import annotations

import hashlib
import io
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class StudyChange:
    """One entry in a :class:`StudyStore`'s change sequence: a monotonically
    numbered record of a study-level mutation (``put`` or ``delete``). This is
    the surface downstream consumers (catalog delta ingest, change pooler
    conformance checks) diff against instead of rescanning the lake."""

    seq: int
    op: str              # "put" | "delete"
    accession: str
    etag: Optional[str]  # at-rest content etag after the op (None for delete)


def _keystream(key: bytes, n: int) -> bytes:
    out = io.BytesIO()
    counter = 0
    while out.tell() < n:
        out.write(hashlib.sha256(key + counter.to_bytes(8, "big")).digest())
        counter += 1
    return out.getvalue()[:n]


class ObjectStore:
    def __init__(self, name: str, key: Optional[bytes] = None) -> None:
        self.name = name
        self._key = key
        self._blobs: Dict[str, bytes] = {}
        self._etags: Dict[str, str] = {}
        self.bytes_written = 0
        self.bytes_read = 0

    def put(self, path: str, data: bytes) -> None:
        if self._key is not None:
            data = bytes(a ^ b for a, b in zip(data, _keystream(self._key, len(data))))
        # content etag recorded at write time so readers (e.g. the cohort
        # planner) can version objects without fetching them. Hashed over the
        # *at-rest* bytes: a plaintext digest beside an encrypted blob would
        # leak content equality (known-plaintext confirmation without the key)
        self._etags[path] = hashlib.sha256(data).hexdigest()
        self._blobs[path] = data
        self.bytes_written += len(data)

    def get(self, path: str) -> bytes:
        data = self._blobs[path]
        self.bytes_read += len(data)
        if self._key is not None:
            data = bytes(a ^ b for a, b in zip(data, _keystream(self._key, len(data))))
        return data

    def raw(self, path: str) -> bytes:
        """At-rest bytes (for tests asserting encryption actually applied)."""
        return self._blobs[path]

    def exists(self, path: str) -> bool:
        return path in self._blobs

    def etag(self, path: str) -> Optional[str]:
        """At-rest content digest recorded at put time (no blob read)."""
        return self._etags.get(path)

    def nbytes(self, path: str) -> Optional[int]:
        """Stored size without a read (no decrypt, no egress accounting)."""
        b = self._blobs.get(path)
        return None if b is None else len(b)

    def list(self, prefix: str = "") -> List[str]:
        return sorted(p for p in self._blobs if p.startswith(prefix))

    def delete(self, path: str) -> None:
        self._blobs.pop(path, None)
        self._etags.pop(path, None)

    def total_bytes(self) -> int:
        return sum(len(b) for b in self._blobs.values())


class StudyStore:
    """Typed store: pickles study/dataset objects through an ObjectStore."""

    def __init__(self, name: str, key: Optional[bytes] = None) -> None:
        self.store = ObjectStore(name, key)
        self.catalog = None  # optional metadata index (repro.catalog)
        self._change_seq = 0
        self._change_log: List[StudyChange] = []

    def _record_change(self, op: str, accession: str, etag: Optional[str]) -> None:
        self._change_seq += 1
        self._change_log.append(StudyChange(self._change_seq, op, accession, etag))

    def change_seq(self) -> int:
        """Monotonic sequence number of the latest study-level mutation."""
        return self._change_seq

    def changes(self, after: int = 0) -> List[StudyChange]:
        """Study-level mutations with ``seq > after``, oldest first."""
        return [c for c in self._change_log if c.seq > after]

    def attach_catalog(self, catalog) -> None:
        """Route every ``put_study`` through the metadata catalog so the
        index stays in lockstep with the lake. Studies already stored are
        backfilled immediately (one read each — metadata indexing is the one
        consumer allowed to read the lake besides the workers)."""
        self.catalog = catalog
        for accession in self.accessions():
            catalog.ingest_study(
                accession, self.get_study(accession), etag=self.study_etag(accession)
            )

    def put_study(self, accession: str, study: Any) -> int:
        blob = pickle.dumps(study, protocol=pickle.HIGHEST_PROTOCOL)
        self.store.put(f"studies/{accession}", blob)
        if self.catalog is not None:
            # re-puts (re-acquisition) tombstone the old rows in the catalog,
            # keyed by the fresh at-rest etag recorded by the put above
            self.catalog.ingest_study(accession, study, etag=self.study_etag(accession))
        self._record_change("put", accession, self.study_etag(accession))
        return len(blob)

    def delete_study(self, accession: str) -> bool:
        """Remove a study from the lake (source deletion propagated by the
        change feed). Tombstones the catalog rows and appends a delete entry
        to the change sequence; returns False when the accession was absent."""
        if not self.has_study(accession):
            return False
        self.store.delete(f"studies/{accession}")
        if self.catalog is not None:
            self.catalog.remove_study(accession)
        self._record_change("delete", accession, None)
        return True

    def get_study(self, accession: str) -> Any:
        return pickle.loads(self.store.get(f"studies/{accession}"))

    def has_study(self, accession: str) -> bool:
        return self.store.exists(f"studies/{accession}")

    def study_etag(self, accession: str) -> Optional[str]:
        return self.store.etag(f"studies/{accession}")

    def study_nbytes(self, accession: str) -> Optional[int]:
        """Stored blob size — the metadata-only backlog estimate used at
        admission (the worker is the one that actually reads the study)."""
        return self.store.nbytes(f"studies/{accession}")

    def put_output(self, request_id: str, sop_uid: str, dataset: Any) -> int:
        blob = pickle.dumps(dataset, protocol=pickle.HIGHEST_PROTOCOL)
        self.store.put(f"out/{request_id}/{sop_uid}", blob)
        return len(blob)

    def outputs(self, request_id: str) -> Iterator[Any]:
        for path in self.store.list(f"out/{request_id}/"):
            yield pickle.loads(self.store.get(path))

    def put_manifest(self, request_id: str, manifest_json: str) -> None:
        self.store.put(f"manifests/{request_id}.json", manifest_json.encode())

    def accessions(self) -> List[str]:
        return [p.split("/", 1)[1] for p in self.store.list("studies/")]
