from repro.training.optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule, clip_by_global_norm
from repro.training.train_step import TrainState, make_train_step, train_state_init
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticTokenPipeline

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "clip_by_global_norm",
    "TrainState",
    "make_train_step",
    "train_state_init",
    "CheckpointManager",
    "SyntheticTokenPipeline",
]
