"""Checkpoint/restart for the training plane (DESIGN.md §5).

Chunked-npz layout, crash-safe by construction:

  step_000123/
    meta.json        # step, tree structure, dtypes, shapes, config digest
    arrays.npz       # flat leaves keyed by tree path
  LATEST             # atomic pointer file, written last

Writes go to a temp dir + fsync + atomic rename; the LATEST pointer flips
only after the payload is durable, so a crash mid-write can never corrupt the
restore path (the previous checkpoint stays live). keep_n retention. On
multi-host TPU this would shard-save per host; here the host gathers (noted
in DESIGN.md §5 — the layout is already per-leaf so the swap is local).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    # jax.tree.flatten_with_path landed after 0.4.x; fall back to tree_util
    flatten_with_path = getattr(
        jax.tree, "flatten_with_path", jax.tree_util.tree_flatten_with_path
    )
    flat, treedef = flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep_n: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[dict] = None) -> Path:
        leaves, treedef = _flatten_with_paths(state)
        arrays = {}
        dtypes = {}
        for k, v in leaves.items():
            arr = np.asarray(v)
            dtypes[k] = str(arr.dtype)
            if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                                 np.uint8, np.uint16, np.uint32, np.int8, np.int16, np.bool_):
                # npz can't round-trip ml_dtypes (bfloat16 etc.): store raw bits
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            arrays[k] = arr
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "keys": sorted(arrays.keys()),
            "dtypes": dtypes,
            "extra": extra or {},
        }

        final = self.dir / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=self.dir))
        try:
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "meta.json").write_text(json.dumps(meta))
            for f in tmp.iterdir():  # fsync payload before the rename
                with open(f, "rb") as fh:
                    os.fsync(fh.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._write_latest(final.name)
        self._gc()
        return final

    def _write_latest(self, name: str) -> None:
        tmp = self.dir / ".LATEST.tmp"
        tmp.write_text(name)
        with open(tmp) as fh:
            os.fsync(fh.fileno())
        os.replace(tmp, self.dir / "LATEST")

    def _gc(self) -> None:
        ckpts = sorted(p for p in self.dir.iterdir() if p.name.startswith("step_"))
        for old in ckpts[: -self.keep_n]:
            shutil.rmtree(old, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.dir / name / "meta.json").exists():
            return None
        return int(name.split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int, dict]:
        """Restore into the structure of ``template`` (shapes/dtypes checked)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "arrays.npz") as npz:
            arrays = {k: npz[k] for k in npz.files}
        leaves, treedef = _flatten_with_paths(template)
        restored = {}
        saved_dtypes = meta.get("dtypes", {})
        for key, tmpl in leaves.items():
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = arrays[key]
            t = jnp.asarray(tmpl)
            if tuple(arr.shape) != tuple(t.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {t.shape}")
            saved = saved_dtypes.get(key, str(arr.dtype))
            if str(arr.dtype) != saved:
                # raw-bits roundtrip (e.g. bfloat16 stored as uint16): the
                # saved dtype must match the template's for exact restore
                if saved != str(t.dtype):
                    raise ValueError(f"dtype mismatch for {key}: ckpt {saved} vs template {t.dtype}")
                arr = arr.view(np.dtype(t.dtype))  # ml_dtypes registers with numpy
            restored[key] = jnp.asarray(arr, t.dtype)
        flat_t, td = jax.tree.flatten(template)
        keys_in_order = list(_flatten_with_paths(template)[0].keys())
        new_leaves = [restored[k] for k in keys_in_order]
        return jax.tree.unflatten(td, new_leaves), meta["step"], meta.get("extra", {})
