"""Training data pipeline.

Two sources, one interface (an iterator of per-step batch dicts):

* :class:`SyntheticTokenPipeline` — deterministic seeded token streams per
  family (LM tokens/labels, encoder frames/masks, VLM patches+text), sharded
  by (host_index, host_count) exactly like a multi-host input pipeline would
  shard a file set;
* :class:`DeidImagePipeline` — the platform integration: consumes
  de-identified studies from a researcher :class:`StudyStore` bucket and
  yields VLM patch-embedding batches (the paper's downstream-AI use case;
  see examples/deid_to_training.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config.model import ModelConfig


@dataclass
class SyntheticTokenPipeline:
    cfg: ModelConfig
    batch: int                 # per-host batch
    seq: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1

    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        # Zipfian marginals (natural-language-like): learnable structure so
        # example training runs demonstrably beat the uniform ln(V) baseline
        z = rng.zipf(1.3, size=shape)
        return np.minimum(z - 1, self.cfg.vocab_size - 1).astype(np.int32)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        # per-(host, step) stream: hosts never overlap, restarts reproduce
        rng = np.random.default_rng((self.seed, self.host_index, step))
        cfg, B, S = self.cfg, self.batch, self.seq
        if cfg.family == "encoder":
            return {
                "frame_embeds": rng.normal(size=(B, S, cfg.d_model)).astype(np.float32),
                "mask": rng.random((B, S)) < 0.3,
                "labels": self._tokens(rng, (B, S)),
            }
        if cfg.family == "vlm":
            si = S // 2
            tokens = self._tokens(rng, (B, S - si + 1))
            return {
                "tokens": tokens[:, :-1],
                "patch_embeds": rng.normal(size=(B, si, cfg.d_model)).astype(np.float32),
                "labels": tokens[:, 1:],
            }
        toks = self._tokens(rng, (B, S + 1))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DeidImagePipeline:
    """De-identified pixels -> patch embeddings for the VLM backbone.

    Patches are cut from scrubbed images (16x16), normalized, and projected
    to d_model with a fixed random (seeded) projection standing in for the
    frozen vision tower the assignment stubs out.
    """

    def __init__(self, cfg: ModelConfig, patch: int = 16, seed: int = 0) -> None:
        self.cfg = cfg
        self.patch = patch
        rng = np.random.default_rng(seed)
        self.proj = rng.normal(size=(patch * patch, cfg.d_model)).astype(np.float32) / patch

    def patches_from_image(self, pixels: np.ndarray, max_patches: int) -> np.ndarray:
        p = self.patch
        H, W = pixels.shape[:2]
        img = pixels[: H // p * p, : W // p * p].astype(np.float32)
        maxv = float(img.max()) or 1.0
        img = img / maxv
        tiles = img.reshape(H // p, p, W // p, p).transpose(0, 2, 1, 3).reshape(-1, p * p)
        return (tiles[:max_patches] @ self.proj).astype(np.float32)

    def batch_from_datasets(self, datasets, batch: int, seq: int, rng: Optional[np.random.Generator] = None):
        rng = rng or np.random.default_rng(0)
        cfg = self.cfg
        si = seq // 2
        st = seq - si
        embeds = np.zeros((batch, si, cfg.d_model), np.float32)
        for b in range(batch):
            ds = datasets[b % len(datasets)]
            pt = self.patches_from_image(ds.pixels, si)
            embeds[b, : len(pt)] = pt
        tokens = rng.integers(0, cfg.vocab_size, (batch, st + 1), dtype=np.int32)
        return {"tokens": tokens[:, :-1], "patch_embeds": embeds, "labels": tokens[:, 1:]}
