"""AdamW + schedules, pure-jnp (pjit-safe, shardable states).

States mirror param pytree structure; m/v ride in f32 with bf16 params (the
f32 master copy lives in the optimizer state — standard mixed-precision).
ZeRO-1 sharding of these states is applied by launch/shardings.py rules.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any       # f32, param-tree
    v: Any       # f32, param-tree
    master: Any  # f32 master copy of params


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(
    grads,
    state: AdamWState,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    param_dtype=jnp.bfloat16,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * master)
        return m, v, new_master

    flat_g, td = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_ma = jax.tree.leaves(state.master)
    new_m, new_v, new_ma = [], [], []
    for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma):
        m2, v2, ma2 = upd(g, m, v, ma)
        new_m.append(m2)
        new_v.append(v2)
        new_ma.append(ma2)
    params = jax.tree.unflatten(td, [ma.astype(param_dtype) for ma in new_ma])
    return params, AdamWState(
        step=step,
        m=jax.tree.unflatten(td, new_m),
        v=jax.tree.unflatten(td, new_v),
        master=jax.tree.unflatten(td, new_ma),
    )


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr_at(step: jax.Array) -> jax.Array:
        t = step.astype(jnp.float32)
        warm = base_lr * t / jnp.maximum(warmup, 1)
        prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(t < warmup, warm, cos)

    return lr_at
