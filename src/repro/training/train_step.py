"""Train step factory: loss -> grads -> (optional compression) -> AdamW.

Distribution knobs (DESIGN.md §5):
  * **microbatching** — grad accumulation via lax.scan over microbatches; each
    microbatch's backward overlaps the previous one's gradient all-reduce
    (XLA schedules the psum of chunk i during compute of chunk i+1, the
    standard compute/comm overlap);
  * **gradient compression** — int8 + error feedback on the cross-pod path
    (hook point; state rides in TrainState);
  * **donate** — the caller jits with donate_argnums so params/opt buffers
    are reused in place.

Under pjit, collectives are inserted by GSPMD from the shardings; this module
stays mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.compression import CompressionState, int8_compress, int8_decompress
from repro.models.model import Model
from repro.training.optimizer import AdamWState, adamw_init, adamw_update, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    comp: Optional[Any]  # CompressionState tree or None


def train_state_init(model: Model, key: jax.Array, compression: bool = False) -> TrainState:
    params = model.init(key)
    comp = None
    if compression:
        comp = jax.tree.map(lambda p: CompressionState.init(p.shape), params)
    return TrainState(params, adamw_init(params), comp)


def _split_microbatches(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def split(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    model: Model,
    lr_schedule: Callable[[jax.Array], jax.Array],
    *,
    microbatches: int = 1,
    grad_clip: float = 1.0,
    compression: bool = False,
    weight_decay: float = 0.1,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(state.params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}

        comp_state = state.comp
        if compression and comp_state is not None:
            # int8 + error feedback on the DP gradient path (cross-pod wire
            # bytes /= 4; see EXPERIMENTS.md §Perf collective modeling)
            def comp_one(g, cs):
                q, scale, cs2 = int8_compress(g, cs)
                return int8_decompress(q, scale), cs2

            flat_g, td = jax.tree.flatten(grads)
            flat_c = jax.tree.leaves(comp_state, is_leaf=lambda x: isinstance(x, CompressionState))
            outs = [comp_one(g, c) for g, c in zip(flat_g, flat_c)]
            grads = jax.tree.unflatten(td, [o[0] for o in outs])
            comp_state = jax.tree.unflatten(td, [o[1] for o in outs])

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(state.opt.step)
        params, opt = adamw_update(grads, state.opt, lr, weight_decay=weight_decay)
        out_metrics = {"loss": loss, "gnorm": gnorm, "lr": lr, "step": opt.step}
        return TrainState(params, opt, comp_state), out_metrics

    return train_step
