from repro.utils.bytesize import human_bytes, parse_bytes
from repro.utils.timing import Timer, SimClock
from repro.utils.logging import get_logger

__all__ = ["human_bytes", "parse_bytes", "Timer", "SimClock", "get_logger"]
