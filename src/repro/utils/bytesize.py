"""Byte-size formatting/parsing helpers used across benchmarks and reports."""
from __future__ import annotations

_UNITS = ["B", "KB", "MB", "GB", "TB", "PB"]


def human_bytes(n: float) -> str:
    """Format a byte count with a binary-ish (1000-based, like the paper) unit."""
    n = float(n)
    for unit in _UNITS:
        if abs(n) < 1000.0 or unit == _UNITS[-1]:
            if unit == "B":
                return f"{int(n)} {unit}"
            return f"{n:.2f} {unit}"
        n /= 1000.0
    raise AssertionError("unreachable")


def parse_bytes(s: str) -> int:
    """Parse '3 TB' / '512MB' / '1024' into a byte count."""
    s = s.strip()
    for i, unit in enumerate(_UNITS):
        if s.upper().endswith(unit) and (unit != "B" or not s.upper().endswith(("KB", "MB", "GB", "TB", "PB"))):
            num = s[: -len(unit)].strip()
            return int(float(num) * (1000 ** i))
    return int(float(s))
