"""Thin logging shim: consistent formatting, env-controlled verbosity.

Configuration is idempotent *per level*: every ``get_logger`` call re-reads
``REPRO_LOG`` and reapplies the level if the env var changed, but the stream
handler is attached exactly once (guarded by a marker attribute, so parallel
first-calls can never double-configure the ``repro`` root logger).

Structured extras: pass ``extra=kv(key=value, ...)`` to any log call and the
formatter appends sorted ``key=value`` pairs — the tracer reuses this to log
span boundaries without bespoke string formatting.
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Any, Dict

_HANDLER_MARK = "_repro_kv_handler"


class KvFormatter(logging.Formatter):
    """Standard formatter plus sorted ``k=v`` pairs from ``record.kv``."""

    def format(self, record: logging.LogRecord) -> str:
        base = super().format(record)
        pairs = getattr(record, "kv", None)
        if pairs:
            tail = " ".join(f"{k}={pairs[k]}" for k in sorted(pairs))
            return f"{base} {tail}"
        return base


def kv(**pairs: Any) -> Dict[str, Any]:
    """Build the ``extra=`` dict for a structured log call."""
    return {"kv": pairs}


def _ensure_configured() -> logging.Logger:
    root = logging.getLogger("repro")
    if not any(getattr(h, _HANDLER_MARK, False) for h in root.handlers):
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(KvFormatter("%(asctime)s %(name)s %(levelname)s %(message)s", "%H:%M:%S"))
        setattr(handler, _HANDLER_MARK, True)
        root.addHandler(handler)
        root.propagate = False
    # Re-read the env var every call: level changes are applied idempotently
    # instead of latching whatever the first caller saw.
    level = getattr(logging, os.environ.get("REPRO_LOG", "INFO").upper(), logging.INFO)
    if root.level != level:
        root.setLevel(level)
    return root


def get_logger(name: str) -> logging.Logger:
    _ensure_configured()
    return logging.getLogger(f"repro.{name}" if not name.startswith("repro") else name)
