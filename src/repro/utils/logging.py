"""Thin logging shim: consistent formatting, env-controlled verbosity."""
from __future__ import annotations

import logging
import os
import sys

_CONFIGURED = False


def get_logger(name: str) -> logging.Logger:
    global _CONFIGURED
    if not _CONFIGURED:
        level = os.environ.get("REPRO_LOG", "INFO").upper()
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter("%(asctime)s %(name)s %(levelname)s %(message)s", "%H:%M:%S"))
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(getattr(logging, level, logging.INFO))
        root.propagate = False
        _CONFIGURED = True
    return logging.getLogger(f"repro.{name}" if not name.startswith("repro") else name)
