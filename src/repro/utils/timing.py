"""Wall-clock timing and a deterministic simulated clock.

The broker/autoscaler layers accept any object with a ``now()`` method; tests
and benchmarks use :class:`SimClock` so queue/lease/scaling behaviour is fully
deterministic, while production wiring would pass a wall clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class Timer:
    """Context-manager stopwatch: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0


@dataclass
class SimClock:
    """Deterministic manually-advanced clock (seconds)."""

    t: float = 0.0
    history: list = field(default_factory=list)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0, "time cannot go backwards"
        self.t += dt
        self.history.append(self.t)
        return self.t


class WallClock:
    """Real clock with the same interface as SimClock."""

    def now(self) -> float:
        return time.time()

    def advance(self, dt: float) -> float:  # pragma: no cover - real sleep
        time.sleep(dt)
        return self.now()
