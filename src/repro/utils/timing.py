"""Wall-clock timing and a deterministic simulated clock.

The broker/autoscaler/tracer layers accept any object satisfying the
:class:`Clock` protocol (``now()`` + ``advance(dt)``); tests and benchmarks use
:class:`SimClock` so queue/lease/scaling/trace behaviour is fully
deterministic, while production wiring passes :class:`WallClock`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Structural interface every clock-consuming component relies on."""

    def now(self) -> float: ...

    def advance(self, dt: float) -> float: ...


class Timer:
    """Context-manager stopwatch: ``with Timer() as t: ...; t.seconds``.

    Re-entrant: nested ``with`` blocks on the same instance each time their
    own region (a LIFO stack of start times), so an inner use never clobbers
    the outer region's start. ``seconds`` always reflects the most recently
    *exited* region. An optional ``clock`` makes the stopwatch deterministic
    under a :class:`SimClock`.
    """

    def __init__(self, clock: Clock | None = None) -> None:
        self._clock = clock
        self._starts: list[float] = []
        self.seconds = 0.0

    def _now(self) -> float:
        return self._clock.now() if self._clock is not None else time.perf_counter()

    def __enter__(self) -> "Timer":
        self._starts.append(self._now())
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = self._now() - self._starts.pop()


@dataclass
class SimClock:
    """Deterministic manually-advanced clock (seconds)."""

    t: float = 0.0
    history: list = field(default_factory=list)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0, "time cannot go backwards"
        self.t += dt
        self.history.append(self.t)
        return self.t


class WallClock:
    """Real clock with the same interface as SimClock."""

    def now(self) -> float:
        return time.time()

    def advance(self, dt: float) -> float:  # pragma: no cover - real sleep
        time.sleep(dt)
        return self.now()
