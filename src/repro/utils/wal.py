"""Shared write-ahead-log (JSONL) replay + repair.

Three subsystems keep crash-durable state as append-only JSONL files —
the processing journal (``repro.queueing.journal``), the ingest checkpoint
(``repro.ingest.checkpoint``), and the audit ledger (``repro.audit.ledger``).
All three need the same replay semantics:

* a **torn tail** (crash mid-append left a partial final line) must be
  *repaired* — truncated away — not merely skipped, because appending after
  a partial line would concatenate the next record onto the garbage and
  corrupt both;
* a complete final record that is merely missing its trailing newline is
  absorbed and the newline finished, so future appends stay line-aligned;
* a malformed line that is NOT the tail was fully written and then damaged —
  it is tolerated (skipped) but surfaced via a counter so invariant checkers
  can prove nothing was silently dropped.

:func:`replay_jsonl` implements that contract once; the callers keep their
own ``_absorb`` logic and counter surfaces.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import IO, List


@dataclass
class WalReplay:
    """Result of replaying (and repairing) one JSONL WAL file."""

    records: List[dict] = field(default_factory=list)
    torn_tail: int = 0      # truncated partial final records (repaired in place)
    corrupt_lines: int = 0  # malformed non-final lines skipped


def _parse(line: bytes) -> dict:
    rec = json.loads(line)
    if not isinstance(rec, dict):
        raise ValueError("not a record")
    return rec


def replay_jsonl(path: str | os.PathLike) -> WalReplay:
    """Replay ``path``, repairing a torn tail in place.

    Returns every fully-written dict record in file order. A missing file
    yields an empty replay (no repair performed).
    """
    out = WalReplay()
    p = Path(path)
    if not p.exists():
        return out
    with open(p, "rb") as fh:
        raw = fh.read()
    body, sep, tail = raw.rpartition(b"\n")
    for line in body.split(b"\n") if sep else []:
        stripped = line.strip()
        if not stripped:
            continue
        try:
            out.records.append(_parse(stripped))
        except ValueError:
            out.corrupt_lines += 1
    if tail.strip():
        try:
            rec = _parse(tail)
        except ValueError:
            # torn tail: the crash interrupted the final append. Recover
            # every fully-written record and truncate the fragment away.
            out.torn_tail += 1
            with open(p, "r+b") as fh:
                fh.truncate(len(raw) - len(tail))
        else:
            # complete record, missing only its newline: finish the line
            out.records.append(rec)
            with open(p, "ab") as fh:
                fh.write(b"\n")
    return out


def append_jsonl(fh: IO[str], rec: dict, fsync: bool = True) -> None:
    """Append one record as a JSON line. ``fsync=True`` makes it durable
    before returning (the journal/checkpoint default); ``fsync=False``
    leaves it in the OS buffer for a later explicit flush (the audit
    ledger's non-durable record kinds)."""
    fh.write(json.dumps(rec) + "\n")
    if fsync:
        fh.flush()
        os.fsync(fh.fileno())
