"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 real device;
only launch/dryrun.py forces 512 host devices (see system DESIGN.md §5)."""
import numpy as np
import pytest

from repro.dicom.generator import StudyGenerator


@pytest.fixture(scope="session")
def gen() -> StudyGenerator:
    return StudyGenerator(seed=1234)


@pytest.fixture()
def rng() -> np.random.Generator:
    return np.random.default_rng(0)
