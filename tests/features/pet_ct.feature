# Paper Figure 2b: PET/CT regression feature. Parsed by repro.core.scenarios
# and executed against the seeded generator ("If any of these tests fail,
# the regression test results in failure").
Feature: PET/CT de-identification regression

Background:
  Given the pipeline uses the anonymizer script, "stanford-anonymizer.script"
  And the pipeline uses the pixel script, "stanford-pixel.script"
  And the pipeline uses the filter script, "stanford-filter.script"
  And script parameter "accession" is "ACN123"
  And script parameter "mrn" is "MRN123"
  And script parameter "jitter" is "-6"

Scenario: PET metadata is anonymized
  Given the DICOM directory "dicom-phi/PT/Anonymize"
  When ran through the deid pipeline
  Then the images SHOULD be anonymized
  And the resulting images should have dates jittered

Scenario: GE Discovery fusion banners are scrubbed
  Given the DICOM directory "dicom-phi/PT/Scrub/GE/Discovery/512x512"
  When ran through the deid pipeline
  Then the resulting images should be scrubbed at 256,0,256,22
  And the resulting images should be scrubbed at 300,22,212,80
  And the resulting images should be scrubbed at 10,478,100,10

Scenario: problem objects are rejected
  Given the DICOM directory "dicom-phi/PT/Filter"
  When ran through the deid pipeline
  Then the images SHOULD NOT pass the filter
