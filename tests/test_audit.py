"""Audit ledger unit + adversarial suite (DESIGN.md §14).

Layers:
* shared WAL replay/repair (``repro.utils.wal``) — torn tail, corrupt middle
  line, empty/missing file;
* ledger chain mechanics — append/chain/digest/replay, structural-key guard;
* adversarial — byte-flip tamper, record deletion, reorder, truncation, and
  a crash-mid-append property (recovered ledger ≡ uninterrupted prefix);
* PHI boundary — planted free text can never survive a ledger/disclosure
  export, mirroring the telemetry redaction contract;
* disclosure accounting — per-project rollups from provenance records.
"""
import json

import pytest

from repro.audit.ledger import GENESIS_SHA, NULL_LEDGER, AuditLedger, NullLedger
from repro.audit.records import (
    DEAD_LETTER,
    DEID_EXECUTE,
    DELIVERY,
    DETECTOR_DECISION,
    LAKE_HIT,
    LAKE_WRITE,
    PROVENANCE,
    RECORD_KINDS,
    SOURCE_FETCH,
    canonical_json,
    record_sha,
)
from repro.audit.report import DisclosureReport, export_ledger_jsonl
from repro.obs.export import REDACTED, Redactor, export_spans_jsonl
from repro.utils.wal import append_jsonl, replay_jsonl

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ shared WAL
class TestWalReplay:
    def test_missing_file_is_empty_replay(self, tmp_path):
        replay = replay_jsonl(tmp_path / "nope.jsonl")
        assert replay.records == []
        assert replay.torn_tail == 0 and replay.corrupt_lines == 0

    def test_empty_file_is_empty_replay(self, tmp_path):
        p = tmp_path / "empty.jsonl"
        p.write_bytes(b"")
        replay = replay_jsonl(p)
        assert replay.records == []
        assert replay.torn_tail == 0 and replay.corrupt_lines == 0

    def test_torn_tail_is_truncated_away(self, tmp_path):
        p = tmp_path / "torn.jsonl"
        good = json.dumps({"a": 1}) + "\n" + json.dumps({"a": 2}) + "\n"
        p.write_bytes(good.encode() + b'{"a": 3, "b"')
        replay = replay_jsonl(p)
        assert [r["a"] for r in replay.records] == [1, 2]
        assert replay.torn_tail == 1
        # the repair is in place: the fragment is gone from disk
        assert p.read_bytes() == good.encode()
        # ...so a fresh append stays line-aligned
        with open(p, "a") as fh:
            append_jsonl(fh, {"a": 3})
        assert [r["a"] for r in replay_jsonl(p).records] == [1, 2, 3]

    def test_complete_tail_missing_newline_is_absorbed(self, tmp_path):
        p = tmp_path / "nolf.jsonl"
        p.write_bytes(json.dumps({"a": 1}).encode() + b"\n" + json.dumps({"a": 2}).encode())
        replay = replay_jsonl(p)
        assert [r["a"] for r in replay.records] == [1, 2]
        assert replay.torn_tail == 0
        assert p.read_bytes().endswith(b"\n")

    def test_corrupt_middle_line_is_skipped_and_counted(self, tmp_path):
        p = tmp_path / "mid.jsonl"
        p.write_bytes(
            json.dumps({"a": 1}).encode() + b"\n"
            + b"%%% damaged, not json %%%\n"
            + b"[1,2,3]\n"  # valid json, not a record
            + json.dumps({"a": 2}).encode() + b"\n"
        )
        replay = replay_jsonl(p)
        assert [r["a"] for r in replay.records] == [1, 2]
        assert replay.corrupt_lines == 2
        assert replay.torn_tail == 0


# ---------------------------------------------------------- chain mechanics
def _ledger(tmp_path, name="led") -> AuditLedger:
    return AuditLedger(tmp_path / f"{name}.audit")


def _populate(led: AuditLedger, n: int = 6) -> None:
    for i in range(n):
        led.append(SOURCE_FETCH, key=f"IRB/A{i:03d}", accession=f"A{i:03d}",
                   etag=f"e{i}", worker="w0", attempt=1, nbytes=100 + i)


class TestLedgerChain:
    def test_appends_chain_from_genesis(self, tmp_path):
        led = _ledger(tmp_path)
        r1 = led.append(SOURCE_FETCH, key="k1", nbytes=1)
        r2 = led.append(DELIVERY, key="k1", etag="e1")
        assert r1["prev_sha"] == GENESIS_SHA
        assert r2["prev_sha"] == r1["sha"]
        assert (r1["seq"], r2["seq"]) == (1, 2)
        assert led.head() == r2["sha"]
        assert led.verify() == []

    def test_sha_covers_the_whole_record(self, tmp_path):
        led = _ledger(tmp_path)
        rec = led.append(DELIVERY, key="k", etag="e")
        assert rec["sha"] == record_sha(rec)
        mutated = dict(rec, etag="forged")
        assert record_sha(mutated) != rec["sha"]

    def test_unknown_kind_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="unknown audit record kind"):
            _ledger(tmp_path).append("made_up_kind", key="k")

    def test_payload_cannot_shadow_structural_keys(self, tmp_path):
        with pytest.raises(ValueError, match="structural keys"):
            _ledger(tmp_path).append(DELIVERY, seq=99)

    def test_replay_restores_chain_and_digest(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 5)
        led.append(DELIVERY, key="k", etag="e")  # durable: fsyncs everything
        digest, head = led.digest(), led.head()
        led.close()
        back = AuditLedger(led.path)
        assert back.digest() == digest and back.head() == head
        assert len(back) == 6
        # the chain keeps extending from the replayed head
        nxt = back.append(DELIVERY, key="k2", etag="e2")
        assert nxt["prev_sha"] == head and nxt["seq"] == 7
        assert back.verify() == []
        back.close()

    def test_digest_commits_to_length_and_head(self, tmp_path):
        a, b = _ledger(tmp_path, "a"), _ledger(tmp_path, "b")
        _populate(a, 3)
        _populate(b, 3)
        assert a.digest() == b.digest()
        b.append(DELIVERY, key="k", etag="e")
        assert a.digest() != b.digest()

    def test_nondurable_records_flush_at_next_durable_append(self, tmp_path):
        led = _ledger(tmp_path)
        led.append(LAKE_HIT, lake_key="lk", nbytes=4)  # buffered
        led.append(DELIVERY, key="k", etag="e")        # durable barrier
        raw = led.path.read_text()
        assert raw.count("\n") == 2
        assert led.verify() == []

    def test_batch_group_commits_durable_appends(self, tmp_path):
        led = _ledger(tmp_path)
        led.append(DELIVERY, key="k0", etag="e")  # solo durable: own fsync
        assert led.syncs == 1
        with led.batch():
            led.append(DELIVERY, key="k1", etag="e")
            led.append(PROVENANCE, key="k1", project="IRB", accession="A1",
                       etag="e", temp="cold", lake_key="", ruleset="r",
                       detector_sha="", kernel_path="serial", batched=0,
                       trace_id="", instances=1, nbytes=1)
            assert led.syncs == 1  # deferred to batch exit
        assert led.syncs == 2  # the pair shared one group commit
        assert led.verify() == []
        # nested batches commit once, at the outermost exit
        with led.batch():
            with led.batch():
                led.append(DELIVERY, key="k2", etag="e")
            assert led.syncs == 2
        assert led.syncs == 3
        # a batch with no durable appends does not fsync
        with led.batch():
            led.append(LAKE_HIT, lake_key="lk", nbytes=1)
        assert led.syncs == 3

    def test_null_ledger_is_inert_and_digest_matches_empty(self, tmp_path):
        empty = _ledger(tmp_path, "empty")
        null = NullLedger()
        assert null.digest() == empty.digest()
        assert null.head() == GENESIS_SHA
        null.append(DELIVERY, key="k", etag="e")
        assert len(null) == 0 and null.records() == []
        assert null.verify() == []
        assert NULL_LEDGER.enabled is False


# -------------------------------------------------------------- adversarial
class TestLedgerTamper:
    def _flip_byte(self, path, offset):
        raw = bytearray(path.read_bytes())
        # flip inside a hex digest char so the line stays parseable JSON
        raw[offset] = ord("0") if raw[offset] != ord("0") else ord("1")
        path.write_bytes(bytes(raw))

    def test_byte_flip_fails_verify(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 8)
        led.flush()
        assert led.verify() == []
        # flip one byte inside record 4's payload etag value
        raw = led.path.read_text().splitlines()
        target = raw[3]
        idx = sum(len(l) + 1 for l in raw[:3]) + target.index('"etag":"e3"') + 9
        self._flip_byte(led.path, idx)
        problems = led.verify()
        assert any("sha mismatch" in p for p in problems), problems

    def test_record_deletion_breaks_chain(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 8)
        led.flush()
        lines = led.path.read_text().splitlines()
        del lines[3]
        led.path.write_text("\n".join(lines) + "\n")
        problems = led.verify()
        assert any("prev_sha break" in p for p in problems), problems
        assert any("seq" in p for p in problems)

    def test_record_reorder_breaks_chain(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 8)
        led.flush()
        lines = led.path.read_text().splitlines()
        lines[2], lines[5] = lines[5], lines[2]
        led.path.write_text("\n".join(lines) + "\n")
        problems = led.verify()
        assert any("prev_sha break" in p or "seq" in p for p in problems), problems

    def test_record_insertion_breaks_chain(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 5)
        led.flush()
        lines = led.path.read_text().splitlines()
        forged = {"kind": DELIVERY, "seq": 3, "t": 0.0,
                  "prev_sha": json.loads(lines[1])["sha"], "key": "forged"}
        forged["sha"] = record_sha(forged)
        lines.insert(2, canonical_json(forged))
        led.path.write_text("\n".join(lines) + "\n")
        problems = led.verify()
        assert problems  # downstream prev_sha/seq no longer line up

    def test_truncation_caught_by_live_head_comparison(self, tmp_path):
        """A chopped file is a valid shorter chain — verify() alone only sees
        it while the process that owns the live head is still up."""
        led = _ledger(tmp_path)
        _populate(led, 8)
        led.flush()
        lines = led.path.read_text().splitlines()
        led.path.write_text("\n".join(lines[:5]) + "\n")
        problems = led.verify()
        assert any("truncated" in p for p in problems), problems

    def test_truncation_after_restart_needs_the_cross_check(self, tmp_path):
        """After a restart the shorter chain verifies clean — exactly why
        AuditCompleteness cross-checks provenance counts against the journal
        (clause 3's truncation bound)."""
        led = _ledger(tmp_path)
        for i in range(6):
            led.append(PROVENANCE, key=f"IRB/A{i}", project="IRB",
                       accession=f"A{i}", etag=f"e{i}", temp="cold",
                       lake_key="", ruleset="r", detector_sha="",
                       kernel_path="serial", batched=0, trace_id="",
                       instances=1, nbytes=10)
        led.close()
        lines = led.path.read_text().splitlines()
        led.path.write_text("\n".join(lines[:3]) + "\n")
        back = AuditLedger(led.path)
        assert back.verify() == []  # tamper-evidence honestly ends here...
        # ...and the completeness cross-check picks it up: 6 completions in
        # the "journal", only 3 cold provenance records in the ledger
        completions = 6
        cold = back.records(PROVENANCE)
        assert len(cold) != completions
        back.close()


class TestCrashRecovery:
    def _build(self, tmp_path, n=10):
        led = AuditLedger(tmp_path / "crash.audit")
        _populate(led, n)
        led.close()
        return led.path, led.path.read_bytes()

    def _check_prefix(self, tmp_path, cut):
        """Recovered ledger after an arbitrary-offset torn write must equal
        the uninterrupted prefix, and keep verifying/appending cleanly."""
        path, raw = self._build(tmp_path)
        reference = replay_jsonl(path).records
        path.write_bytes(raw[:cut])
        recovered = AuditLedger(path)
        n = len(recovered)
        assert recovered.records() == reference[:n]
        assert recovered.verify() == []
        nxt = recovered.append(DELIVERY, key="post", etag="e")
        assert nxt["seq"] == n + 1
        assert recovered.verify() == []
        recovered.close()

    def test_torn_final_append_recovers_prefix(self, tmp_path):
        path, raw = self._build(tmp_path)
        self._check_prefix(tmp_path, len(raw) - 7)

    def test_cut_at_line_boundary_recovers_all(self, tmp_path):
        path, raw = self._build(tmp_path)
        head = raw.rpartition(b"\n")[0].rpartition(b"\n")[0] + b"\n"
        self._check_prefix(tmp_path, len(head))

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    def test_any_torn_offset_recovers_a_clean_prefix(self, tmp_path):
        path, raw = self._build(tmp_path)
        reference = replay_jsonl(path).records

        @settings(max_examples=60, deadline=None, database=None)
        @given(cut=st.integers(min_value=0, max_value=len(raw)))
        def prop(cut):
            path.write_bytes(raw[:cut])
            recovered = AuditLedger(path)
            try:
                n = len(recovered)
                assert recovered.records() == reference[:n]
                assert recovered.verify() == []
            finally:
                recovered.close()

        prop()


# ------------------------------------------------------------- PHI boundary
PLANTED_PHI = "DOE^JOHN 1961-04-11 MRN 555-0199"


class TestLedgerPhiBoundary:
    def test_planted_phi_never_survives_ledger_export(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 3)
        # a hostile/buggy call site stuffs free text into allowlisted keys
        led.append(DEAD_LETTER, key="IRB/A999", deliveries=3, reason=PLANTED_PHI)
        led.append(DELIVERY, key="IRB/A999", etag=PLANTED_PHI, temp="cold")
        out = export_ledger_jsonl(led, Redactor())
        assert PLANTED_PHI not in out
        assert "DOE" not in out and "555-0199" not in out
        assert REDACTED in out

    def test_non_allowlisted_keys_dropped_outright(self, tmp_path):
        led = _ledger(tmp_path)
        led.append(SOURCE_FETCH, key="k", patient_name=PLANTED_PHI)
        out = export_ledger_jsonl(led, Redactor())
        assert "patient_name" not in out and "DOE" not in out

    def test_disclosure_report_export_is_redacted(self, tmp_path):
        led = _ledger(tmp_path)
        led.append(PROVENANCE, key="IRB/A0", project=PLANTED_PHI,
                   accession=PLANTED_PHI, etag="e", temp="cold", lake_key="",
                   ruleset="r1", detector_sha="", kernel_path="serial",
                   batched=0, trace_id="", instances=1, nbytes=10)
        report = DisclosureReport.from_ledger(led)
        out = report.to_jsonl(Redactor())
        assert "DOE" not in out and "555-0199" not in out
        assert REDACTED in out

    def test_healthy_sim_fields_all_pass_the_allowlist(self, tmp_path):
        """Every field the real emit sites use must survive export without
        falling back to [redacted] — digests/keys are identifier-charset."""
        led = _ledger(tmp_path)
        _populate(led, 2)
        led.append(DETECTOR_DECISION, modality="CT", device="siemens/ct1",
                   registry_hit=True, detected=False, bands=0,
                   detector_sha="a" * 64)
        led.append(LAKE_WRITE, lake_key="b" * 64, nbytes=123)
        out = export_ledger_jsonl(led, Redactor())
        assert REDACTED not in out


class TestTelemetryExportRecords:
    def test_span_export_emits_audit_record(self, tmp_path):
        led = _ledger(tmp_path)
        export_spans_jsonl([], Redactor(), ledger=led)
        recs = led.records("telemetry_export")
        assert len(recs) == 1
        assert recs[0]["channel"] == "spans_jsonl" and recs[0]["records"] == 0

    def test_null_ledger_export_emits_nothing(self):
        export_spans_jsonl([], Redactor(), ledger=NULL_LEDGER)
        assert len(NULL_LEDGER) == 0


# ------------------------------------------------------ disclosure rollups
class TestDisclosureReport:
    def test_per_project_accounting(self, tmp_path):
        led = _ledger(tmp_path)
        for i, (proj, temp) in enumerate(
            [("IRB-A", "cold"), ("IRB-A", "warm"), ("IRB-A", "journal"),
             ("IRB-B", "cold")]
        ):
            led.append(PROVENANCE, key=f"{proj}/A{i}", project=proj,
                       accession=f"A{i}", etag=f"e{i}", temp=temp,
                       lake_key="", ruleset="r1", detector_sha="",
                       kernel_path="serial", batched=0, trace_id="",
                       instances=2, nbytes=50)
        led.append(DEID_EXECUTE, accession="A0", project="IRB-A", instances=2,
                   lake_hits=0, cold=2, ruleset="r1")
        led.append(LAKE_WRITE, lake_key="k", nbytes=100)
        led.append(LAKE_HIT, lake_key="k", nbytes=100)
        led.append(DEAD_LETTER, key="IRB-B/A9", deliveries=3, reason="nack")
        rep = DisclosureReport.from_ledger(led)
        a, b = rep.projects["IRB-A"], rep.projects["IRB-B"]
        assert (a.deliveries, a.cold, a.warm, a.journal) == (3, 1, 1, 1)
        assert a.instances == 6 and a.nbytes == 150
        assert sorted(a.accessions) == ["A0", "A1", "A2"]
        assert a.rulesets == {"r1"}
        assert (b.deliveries, b.cold) == (1, 1)
        assert rep.deid_executions == 1
        assert rep.lake_writes == 1 and rep.lake_bytes_in == 100
        assert rep.lake_hits == 1 and rep.lake_bytes_out == 100
        assert rep.dead_lettered == 1
        assert rep.ledger_digest == led.digest()
        # summary renders without touching PHI-bearing free text
        text = rep.summary()
        assert "IRB-A" in text and "3 deliveries" in text

    def test_to_dict_round_trips_json(self, tmp_path):
        led = _ledger(tmp_path)
        _populate(led, 2)
        rep = DisclosureReport.from_ledger(led)
        assert json.loads(json.dumps(rep.to_dict())) == rep.to_dict()


# ------------------------------------------------------------- completeness
def test_record_kinds_cover_the_taxonomy():
    """DESIGN §14's taxonomy is closed: every PHI-touching action named in
    the design doc has a kind, and nothing else can be appended."""
    assert RECORD_KINDS == {
        "source_fetch", "deid_execute", "detector_decision", "lake_write",
        "lake_hit", "lake_evict", "delivery", "provenance", "dead_letter",
        "ingest_apply", "policy_edit", "telemetry_export",
    }
