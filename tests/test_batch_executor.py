"""BatchedDeidExecutor + the batched study path: bucketing, padding, jit-cache
bounding, and end-to-end equivalence with the per-instance oracle."""
import json

import numpy as np
import pytest

from repro.core import (
    BatchedDeidExecutor,
    DeidPipeline,
    PseudonymService,
    TrustMode,
    build_request,
    numpy_blank,
)
from repro.core.batch import blank_inplace
from repro.dicom import codec
from repro.dicom.generator import StudyGenerator
from repro.kernels.scrub import ops as scrub_ops
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


@pytest.fixture(scope="module")
def pseudo():
    return PseudonymService("IRB-B", TrustMode.POST_IRB, key=b"x" * 32)


class TestBucketing:
    def test_groups_by_shape_dtype_and_rect_bucket(self, rng):
        ex = BatchedDeidExecutor()
        items = [
            ((rng.random((64, 64)) * 255).astype(np.uint8), [(0, 0, 8, 8)]),
            ((rng.random((64, 64)) * 255).astype(np.uint8), [(1, 1, 4, 4)]),
            ((rng.random((64, 64)) * 4095).astype(np.uint16), [(0, 0, 8, 8)]),   # dtype differs
            ((rng.random((32, 64)) * 255).astype(np.uint8), [(0, 0, 8, 8)]),     # H differs
            ((rng.random((64, 64)) * 255).astype(np.uint8), [(0, 0, 8, 8)] * 3), # rects 3 -> bucket 4
        ]
        buckets = ex.bucket(items)
        assert sorted(buckets.values()) == [[0, 1], [2], [3], [4]]
        assert (64, 64, "uint8", 4) in buckets

    def test_zero_rects_bucket_as_one(self, rng):
        ex = BatchedDeidExecutor()
        px = (rng.random((16, 16)) * 255).astype(np.uint8)
        buckets = ex.bucket([(px, []), (px.copy(), [(0, 0, 4, 4)])])
        assert len(buckets) == 1  # both pad to R=1

    def test_padded_shapes_are_powers_of_two(self, rng):
        ex = BatchedDeidExecutor(max_batch=8, use_kernel=True)
        items = [
            ((rng.random((32, 48) if i < 11 else (16, 48)) * 255).astype(np.uint8), [])
            for i in range(13)
        ]
        ex.run(items, recompress=False)
        # 11 same-shape items -> chunks of 8 and 3 (padded to 4); 2 odd items -> 2
        assert {s[0] for s in ex.stats.padded_shapes} <= {2, 4, 8}
        assert ex.stats.instances == 13
        assert ex.stats.dispatches == 3


class TestExecutorOutputs:
    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_recompress_matches_host_pair(self, rng, use_kernel):
        ex = BatchedDeidExecutor(use_kernel=use_kernel)
        imgs = (rng.random((5, 60, 80)) * 4095).astype(np.uint16)
        rls = [[(0, 0, 80, 10)], [], [(10, 10, 20, 20), (15, 15, 20, 20)], [(70, 50, 99, 99)], []]
        items = [(imgs[i].copy(), rls[i]) for i in range(5)]
        outs = ex.run(items, sv=3, recompress=True)
        for i, out in enumerate(outs):
            blanked = numpy_blank(imgs[i], rls[i])
            np.testing.assert_array_equal(out.pixels, blanked)
            assert out.payload == codec.encode(blanked, 3)

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_scrub_only_matches_host(self, rng, use_kernel):
        ex = BatchedDeidExecutor(use_kernel=use_kernel)
        imgs = (rng.random((3, 40, 52)) * 255).astype(np.uint8)
        rls = [[(2, 2, 10, 10)], [(0, 0, 52, 5)], []]
        outs = ex.run([(imgs[i].copy(), rls[i]) for i in range(3)], recompress=False)
        for i, out in enumerate(outs):
            np.testing.assert_array_equal(out.pixels, numpy_blank(imgs[i], rls[i]))
            assert out.payload is None

    def test_supports(self, rng):
        ex = BatchedDeidExecutor()
        u16 = np.zeros((8, 8), np.uint16)
        assert ex.supports(u16, recompress=True)
        assert not ex.supports(None, recompress=True)
        assert not ex.supports(np.zeros((8, 8, 3), np.uint8), recompress=True)  # multi-sample
        assert not ex.supports(np.zeros((8, 8), np.float32), recompress=True)   # no codec dtype
        assert ex.supports(np.zeros((8, 8), np.float32), recompress=False)

    def test_blank_inplace_matches_numpy_blank(self, rng):
        img = (rng.random((30, 40)) * 255).astype(np.uint8)
        rl = [(-5, 10, 20, 99), (35, 25, 99, 99)]
        expect = numpy_blank(img, rl)
        got = blank_inplace(img.copy(), rl)
        np.testing.assert_array_equal(got, expect)


class TestPipelineBatchedEqualsSerial:
    @pytest.mark.parametrize("recompress", [True, False])
    @pytest.mark.parametrize("modality,n,problem", [("CT", 20, "pdf"), ("US", 6, None)])
    def test_identical_outputs_and_manifest(self, gen, pseudo, recompress, modality, n, problem):
        s = gen.gen_study(f"BE-{modality}-{recompress}", modality=modality, n_images=n, problem=problem)
        req = build_request(pseudo, s.accession, s.mrn)
        batched = DeidPipeline(recompress=recompress)
        serial = DeidPipeline(recompress=recompress, batched=False)
        assert batched.executor is not None and serial.executor is None
        out_b, man_b = batched.process_study(s, req, "w0")
        out_s, man_s = serial.process_study(s, req, "w0")
        assert man_b.to_json() == man_s.to_json()
        assert len(out_b) == len(out_s)
        for a, b in zip(out_b, out_s):
            assert a.elements == b.elements
            if a.pixels is not None:
                np.testing.assert_array_equal(a.pixels, b.pixels)
        if recompress:
            assert batched.executor.stats.instances > 0

    def test_kernel_dispatch_equals_serial_end_to_end(self, gen, pseudo):
        """Forced fused-kernel dispatch (the accelerator path, interpret-mode
        here) produces the same delivered studies and manifest as serial."""
        s = gen.gen_study("BE-KD", modality="US", n_images=4)
        req = build_request(pseudo, s.accession, s.mrn)
        batched = DeidPipeline()
        batched.executor.use_kernel = True
        serial = DeidPipeline(batched=False)
        out_b, man_b = batched.process_study(s, req)
        out_s, man_s = serial.process_study(s, req)
        assert man_b.to_json() == man_s.to_json()
        for a, b in zip(out_b, out_s):
            np.testing.assert_array_equal(a.pixels, b.pixels)
        assert batched.executor.stats.padded_shapes  # the kernel path ran

    def test_us_fail_closed_survives_batching(self, gen, pseudo):
        from repro.dicom.devices import DeviceKey
        from repro.core import Outcome

        pipe = DeidPipeline(filter_script="# empty\n", recompress=True)
        s = gen.gen_study("BE-USX", device=DeviceKey("US", "UnknownMake", "Mystery-1", 480, 640), n_images=2)
        req = build_request(pseudo, s.accession, s.mrn)
        outs, manifest = pipe.process_study(s, req)
        assert outs == []
        assert all(e.outcome is Outcome.FAILED for e in manifest.entries)

    def test_custom_rect_semantics_blank_fn_batches(self, gen, pseudo):
        """The Pallas single-image adapter declares rect semantics, so the
        pipeline still batches; results match the numpy-blank pipeline."""
        s = gen.gen_study("BE-K", modality="US", n_images=4)
        req = build_request(pseudo, s.accession, s.mrn)
        kern = DeidPipeline(blank_fn=scrub_ops.blank_fn)
        base = DeidPipeline()
        out_k, man_k = kern.process_study(s, req)
        out_n, man_n = base.process_study(s, req)
        assert man_k.to_json() == man_n.to_json()
        assert kern.executor.stats.instances > 0

    def test_fallback_scrub_error_stays_per_instance(self, gen, pseudo):
        """A ScrubError from a non-batchable instance's blank_fn must yield
        one FAILED manifest entry, not abort the study (serial parity)."""
        from repro.core import Outcome
        from repro.core.scrub import ScrubError

        def exploding_blank(pixels, rects):
            if pixels.shape[0] % 2 == 1:  # fail on odd-height frames only
                raise ScrubError("refusing this frame")
            return numpy_blank(pixels, rects)

        s = gen.gen_study("BE-ERR", modality="US", n_images=3)
        s.datasets[1].pixels = s.datasets[1].pixels[:-1]  # odd height -> explodes
        req = build_request(pseudo, s.accession, s.mrn)
        results = {}
        for name, pipe in [("batched", DeidPipeline(blank_fn=exploding_blank)),
                           ("serial", DeidPipeline(blank_fn=exploding_blank, batched=False))]:
            outs, manifest = pipe.process_study(s, req)
            outcomes = [e.outcome for e in manifest.entries]
            results[name] = (len(outs), outcomes)
            assert outcomes.count(Outcome.FAILED) == 1
            assert outcomes.count(Outcome.ANONYMIZED) == 2
        assert results["batched"] == results["serial"]

    def test_opaque_blank_fn_falls_back_to_serial(self, gen, pseudo):
        """A blank_fn without declared rect semantics must not be bypassed by
        the fused kernel — its instances take the per-instance path."""
        calls = []

        def odd_blank(pixels, rects):
            calls.append(1)
            return numpy_blank(pixels, rects)

        pipe = DeidPipeline(blank_fn=odd_blank)
        s = gen.gen_study("BE-O", modality="US", n_images=3)
        req = build_request(pseudo, s.accession, s.mrn)
        pipe.process_study(s, req)
        assert calls  # the custom fn actually ran
        assert pipe.executor.stats.instances == 0


class TestBugfixSweep:
    def test_pow2_cap_rounds_down_to_power_of_two(self):
        from repro.core.batch import _pow2_at_least, _pow2_floor

        # regression: min(p, 24) used to return 24 — not a power of two —
        # silently growing the closed jit-cache shape set
        assert _pow2_at_least(20, 24) == 16
        assert _pow2_at_least(20, 32) == 32
        assert _pow2_at_least(5, 24) == 8
        assert _pow2_floor(24) == 16 and _pow2_floor(32) == 32

    def test_non_pow2_max_batch_normalized_and_shapes_stay_closed(self, rng):
        ex = BatchedDeidExecutor(max_batch=24, use_kernel=True)
        assert ex.max_batch == 16
        items = [((rng.random((16, 32)) * 255).astype(np.uint8), []) for _ in range(20)]
        ex.run(items, recompress=False)
        assert all(bin(s[0]).count("1") == 1 for s in ex.stats.padded_shapes)

    def test_max_batch_must_be_positive(self):
        with pytest.raises(ValueError):
            BatchedDeidExecutor(max_batch=0)

    def test_stats_buckets_counts_distinct_keys_across_runs(self, rng):
        ex = BatchedDeidExecutor(use_kernel=False)
        items = [((rng.random((24, 24)) * 255).astype(np.uint8), []) for _ in range(3)]
        ex.run(items)
        ex.run(items)  # same bucket key again
        assert ex.stats.buckets == 1          # distinct keys, not re-counted
        assert ex.stats.dispatch_groups == 2  # per-run tally still available
        other = [((rng.random((48, 24)) * 255).astype(np.uint8), [])]
        ex.run(other)
        assert ex.stats.buckets == 2
        assert ex.stats.dispatch_groups == 3

    def test_detect_rejects_non_finite_threshold(self, rng):
        ex = BatchedDeidExecutor(use_kernel=False)
        px = (rng.random((32, 128)) * 255).astype(np.uint8)
        for bad in (float("nan"), float("inf"), float("-inf")):
            with pytest.raises(ValueError, match="finite"):
                ex.detect_row_hits([(px, bad)])
        # a NaN would have put each instance in a private bucket; equal
        # finite thresholds share one dispatch
        ex.detect_row_hits([(px, 40.0), (px.copy(), 40.0)])
        assert ex.stats.detect_dispatches == 1


class TestWorkerBatchedPath:
    def test_worker_reports_batched_instances(self, tmp_path, gen):
        clock = SimClock()
        lake = StudyStore("lake-b")
        s = gen.gen_study("WRK-B", modality="US", n_images=5)
        lake.put_study(s.accession, s)
        broker = Broker(clock, visibility_timeout=60)
        journal = Journal(tmp_path / "j.jsonl")
        service = DeidService(broker, lake, journal)
        service.register_study("IRB-W", TrustMode.POST_IRB)
        dest = StudyStore("res-b")
        pipeline = DeidPipeline()  # recompress + batched defaults
        pool = WorkerPool(
            broker,
            Autoscaler(broker, AutoscalerConfig(), clock),
            lambda wid: DeidWorker(wid, pipeline, lake, dest, journal),
        )
        service.submit("IRB-W", [s.accession], {s.accession: s.mrn})
        report = pool.drain()
        assert report.processed == 1
        assert sum(w.batched_instances for w in pool._all_workers) == 5
