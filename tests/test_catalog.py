"""Catalog conformance: encode/decode round-trips, query-path equivalence
(vectorized jnp+Pallas vs numpy oracle vs brute-force row scan), zone-map
pruning safety, tombstoned re-ingest, selection digests, the bitmap kernel's
parity with its numpy reference, and the query-then-de-identify service path
(DESIGN.md §8). Seeded-random sweeps here mirror the hypothesis properties in
``test_catalog_properties.py`` so coverage survives without hypothesis."""
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.catalog import (
    And,
    Contains,
    Eq,
    In,
    Not,
    Or,
    Range,
    StudyCatalog,
    describe,
    matches_row,
    rows_from_study,
)
from repro.catalog.columns import COLUMN_KINDS, Dictionary, row_from_dataset
from repro.core import DeidPipeline, TrustMode
from repro.dicom.dataset import DicomDataset, normalize_cs
from repro.dicom.generator import StudyGenerator
from repro.kernels.bitmap.ops import combine_bitmaps, pack_mask
from repro.kernels.bitmap.ref import combine_bitmaps_ref, pack_mask_np, unpack_mask_np
from repro.lake import ResultLake
from repro.queueing import Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


# ----------------------------------------------------------- random fixtures
_MODALITIES = ["CT", "MR", "DX", "US", "CR", "PT"]
_PARTS = ["CHEST", "HEAD", "ABDOMEN", "KNEE", ""]
_MAKES = ["GE Medical", "Siemens", "Philips", "Vidar"]
_MODELS = ["Optima CT660", "MAGNETOM Aera", "Epiq 7", "DRX-1"]


def random_rows(rng: np.random.Generator, n: int) -> list:
    return [
        {
            "modality": str(rng.choice(_MODALITIES)),
            "body_part": str(rng.choice(_PARTS)),
            "manufacturer": str(rng.choice(_MAKES)),
            "model": str(rng.choice(_MODELS)),
            "study_date": 20150000 + int(rng.integers(1, 5)) * 10000
            + int(rng.integers(1, 13)) * 100 + int(rng.integers(1, 29)),
            "bits_stored": int(rng.choice([8, 12, 16])),
            "rows": int(rng.choice([256, 512, 1024])),
            "cols": int(rng.choice([256, 512, 1024])),
            "nbytes": int(rng.integers(1_000, 2_000_000)),
            "burned_in": int(rng.random() < 0.2),
        }
        for _ in range(n)
    ]


def random_pred(rng: np.random.Generator, depth: int = 2):
    kind = int(rng.integers(0, 5 if depth <= 0 else 8))
    if kind == 0:
        return Eq("modality", str(rng.choice(_MODALITIES + ["XX"])))
    if kind == 1:
        return Eq("body_part", str(rng.choice(_PARTS)))
    if kind == 2:
        lo = 20150101 + int(rng.integers(0, 4)) * 10000
        return Range("study_date", lo, lo + int(rng.integers(0, 3)) * 10000 + 1231 - 101)
    if kind == 3:
        return In("modality", tuple(rng.choice(_MODALITIES, size=int(rng.integers(1, 4)))))
    if kind == 4:
        return Contains("model", str(rng.choice(["ct", "MAG", "7", "zzz"])))
    if kind == 5:
        return Not(random_pred(rng, depth - 1))
    sub = [random_pred(rng, depth - 1) for _ in range(int(rng.integers(2, 4)))]
    return And(*sub) if kind == 6 else Or(*sub)


def build_catalog(rng: np.random.Generator, n_accessions: int, rows_per: int,
                  block_rows: int = 32) -> tuple:
    cat = StudyCatalog(block_rows=block_rows)
    all_rows = {}
    for i in range(n_accessions):
        acc = f"R{i:04d}"
        rows = random_rows(rng, rows_per)
        all_rows[acc] = rows
        cat.ingest_rows(acc, rows, etag=f"etag{i}")
    return cat, all_rows


def brute_force(all_rows: dict, pred) -> dict:
    out = {}
    for acc, rows in all_rows.items():
        n = sum(1 for r in rows if matches_row(pred, r))
        if n:
            out[acc] = n
    return out


# ------------------------------------------------------------------- columns
class TestColumns:
    def test_dictionary_roundtrip_and_normalization(self):
        d = Dictionary()
        a = d.encode("GE Medical")
        assert d.encode("  ge   medical ") == a  # CS-normalized interning
        b = d.encode("Siemens")
        assert d.decode(a) == "GE MEDICAL" and d.decode(b) == "SIEMENS"
        assert d.code_of("ge medical") == a
        assert d.code_of("nope") is None
        assert d.codes_containing("medic") == (a,)
        assert len(d) == 2

    def test_row_from_dataset(self):
        gen = StudyGenerator(0)
        study = gen.gen_study("A1", modality="CT", n_images=1)
        row = row_from_dataset(study.datasets[0])
        assert row["modality"] == "CT"
        assert row["study_date"] == int(study.study_date)
        assert row["rows"] == study.device.rows and row["cols"] == study.device.cols
        assert row["nbytes"] == study.datasets[0].nbytes()
        assert row["burned_in"] == 0
        assert set(row) == set(COLUMN_KINDS)

    def test_encode_decode_roundtrip_through_catalog(self):
        """Every ingested value must be recoverable from its code — the
        decode side of the dictionary is what Contains and selection
        reporting rely on."""
        rng = np.random.default_rng(7)
        cat, all_rows = build_catalog(rng, 4, 20)
        for col, kind in COLUMN_KINDS.items():
            if kind != "dict":
                continue
            d = cat.dicts[col]
            for rows in all_rows.values():
                for r in rows:
                    code = d.code_of(r[col])
                    assert code is not None
                    assert d.decode(code) == normalize_cs(r[col])


# ------------------------------------------------------------- query engine
class TestQueryEquivalence:
    @pytest.mark.parametrize("seed", range(6))
    def test_vectorized_equals_oracle_equals_bruteforce(self, seed):
        rng = np.random.default_rng(seed)
        cat, all_rows = build_catalog(rng, 6, 25, block_rows=16)
        for q in range(8):
            pred = random_pred(rng)
            mv, _, _ = cat.match_mask(pred, mode="auto", prune=False)
            mo, _, _ = cat.match_mask(pred, mode="oracle", prune=False)
            assert np.array_equal(mv, mo), (seed, q, describe(pred))
            sel = cat.select(pred, mode="auto")
            assert dict(sel.instance_counts) == brute_force(all_rows, pred), describe(pred)

    def test_pruning_never_changes_results(self):
        rng = np.random.default_rng(42)
        # date-sorted ingest gives blocks tight zone maps worth pruning
        cat = StudyCatalog(block_rows=16)
        all_rows = {}
        rows = sorted(random_rows(rng, 120), key=lambda r: r["study_date"])
        for i in range(6):
            acc = f"S{i:03d}"
            all_rows[acc] = rows[i * 20 : (i + 1) * 20]
            cat.ingest_rows(acc, all_rows[acc], etag=str(i))
        pred = Range("study_date", 20150101, 20151231)
        pruned_sel = cat.select(pred, prune=True)
        full_sel = cat.select(pred, prune=False)
        assert pruned_sel.blocks_pruned > 0
        assert pruned_sel.accessions == full_sel.accessions
        assert pruned_sel.instance_counts == full_sel.instance_counts
        assert pruned_sel.total_bytes == full_sel.total_bytes
        assert dict(pruned_sel.instance_counts) == brute_force(all_rows, pred)

    def test_statically_false_leaf_prunes_everything(self):
        rng = np.random.default_rng(3)
        cat, _ = build_catalog(rng, 4, 40, block_rows=16)
        sel = cat.select(Eq("manufacturer", "NEVER-INGESTED"))
        assert sel.total_instances == 0
        assert sel.blocks_scanned == 0 and sel.blocks_pruned > 0

    def test_not_under_pruning_is_conservative(self):
        """NOT must disable zone pruning for its subtree: a block whose zone
        map says 'no CT here' entirely MATCHES Not(Eq(CT))."""
        cat = StudyCatalog(block_rows=4)
        rows_ct = [dict(r, modality="CT") for r in random_rows(np.random.default_rng(1), 4)]
        rows_mr = [dict(r, modality="MR") for r in random_rows(np.random.default_rng(2), 4)]
        cat.ingest_rows("ACT", rows_ct, etag="a")
        cat.ingest_rows("AMR", rows_mr, etag="b")
        sel = cat.select(Not(Eq("modality", "CT")))
        assert dict(sel.instance_counts) == {"AMR": 4}

    def test_validation_errors(self):
        cat = StudyCatalog()
        with pytest.raises(KeyError):
            cat.select(Eq("no_such_column", 1))
        with pytest.raises(ValueError):
            cat.select(Range("modality", 0, 1))  # Range needs an int column
        with pytest.raises(ValueError):
            cat.select(Contains("study_date", "2015"))  # Contains needs dict
        with pytest.raises(ValueError):
            cat.select(And())

    def test_empty_catalog(self):
        cat = StudyCatalog()
        sel = cat.select(Eq("modality", "CT"))
        assert sel.accessions == () and sel.total_instances == 0


class TestTombstones:
    def test_reingest_replaces_rows(self):
        gen = StudyGenerator(5)
        cat = StudyCatalog(block_rows=4)
        s1 = gen.gen_study("A1", modality="CT", n_images=6)
        cat.ingest_study("A1", s1, etag="v1")
        d0 = cat.snapshot_digest()
        s2 = StudyGenerator(6).gen_study("A1", modality="MR", n_images=2)
        cat.ingest_study("A1", s2, etag="v2")
        assert cat.snapshot_digest() != d0
        assert cat.stats.tombstoned == 6
        sel = cat.select(Range("study_date", 0, 99999999))
        assert dict(sel.instance_counts) == {"A1": 2}
        # the dead CT rows must not resurface even under NOT
        assert cat.select(Not(Eq("modality", "MR"))).total_instances == 0
        assert cat.accession_etags() == {"A1": "v2"}

    def test_selection_digest_pins_catalog_state_and_query(self):
        rng = np.random.default_rng(9)
        cat, _ = build_catalog(rng, 3, 10)
        q1, q2 = Eq("modality", "CT"), Eq("modality", "MR")
        d1 = cat.select(q1).digest
        assert cat.select(q1).digest == d1          # same state+query -> same
        assert cat.select(q2).digest != d1          # query in the digest
        cat.ingest_rows("NEW", random_rows(rng, 3), etag="x")
        assert cat.select(q1).digest != d1          # catalog state in the digest


# ------------------------------------------------------------- bitmap kernel
class TestBitmapKernel:
    @pytest.mark.parametrize("seed", range(5))
    def test_kernel_equals_reference(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 700))
        k = int(rng.integers(1, 5))
        masks = [rng.random(n) < rng.random() for _ in range(k)]
        valid = rng.random(n) < 0.9
        leaves = np.stack([pack_mask_np(m) for m in masks + [valid]])
        # random balanced program over the k real leaves, then the valid AND
        prog = [("leaf", 0)]
        for i in range(1, k):
            prog.append(("leaf", i))
            if rng.random() < 0.3:
                prog.append(("not",))
            prog.append(("and",) if rng.random() < 0.5 else ("or",))
        prog += [("leaf", k), ("and",)]
        prog = tuple(prog)
        bm_ref, cnt_ref = combine_bitmaps_ref(leaves, prog)
        bm, cnt = combine_bitmaps(leaves, prog)
        assert np.array_equal(np.asarray(bm), bm_ref)
        assert cnt == cnt_ref

    def test_pack_parity_and_roundtrip(self):
        rng = np.random.default_rng(0)
        for n in (1, 31, 32, 33, 257):
            mask = rng.random(n) < 0.5
            packed = pack_mask_np(mask)
            assert np.array_equal(np.asarray(pack_mask(mask)), packed)
            assert np.array_equal(unpack_mask_np(packed, n), mask)

    def test_not_cannot_leak_padding_into_count(self):
        n = 5  # one word, 27 padding bits
        mask = np.zeros(n, bool)
        valid = np.ones(n, bool)
        leaves = np.stack([pack_mask_np(mask), pack_mask_np(valid)])
        prog = (("leaf", 0), ("not",), ("leaf", 1), ("and",))
        _, cnt = combine_bitmaps(leaves, prog)
        assert cnt == n


# ----------------------------------------------------- service integration
def _stack(tmp, source, catalog=None):
    clock = SimClock()
    broker = Broker(clock, visibility_timeout=300.0)
    journal = Journal(Path(tmp) / "j.jsonl")
    lake = ResultLake(max_bytes=1 << 30)
    pipeline = DeidPipeline(lake=lake)
    service = DeidService(
        broker, source, journal, result_lake=lake, pipeline=pipeline, catalog=catalog
    )
    service.register_study("IRB-C", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
    )
    return broker, service, pool


def _corpus(n=6, images=2):
    gen = StudyGenerator(21)
    source = StudyStore("lake")
    mrns = {}
    for i in range(n):
        acc = f"Q{i:03d}"
        s = gen.gen_study(acc, n_images=images)
        source.put_study(acc, s)
        mrns[acc] = s.mrn
    return source, mrns


class TestSubmitQuery:
    def test_query_then_deid_end_to_end(self, tmp_path):
        source, mrns = _corpus()
        catalog = StudyCatalog()
        source.attach_catalog(catalog)  # backfills the 6 studies
        assert catalog.n_rows() == 12
        broker, service, pool = _stack(tmp_path, source, catalog)
        query = Range("study_date", 0, 99999999)
        selection, ticket = service.submit_query("IRB-C", query, mrns)
        assert ticket.selection_digest == selection.digest
        assert sorted(ticket.cold) == list(selection.accessions)
        assert broker.total_published == len(selection.accessions)
        pool.drain()
        service.planner.resolve()
        assert ticket.done() and not ticket.failed
        # replay: same query is now fully warm — zero publishes
        pub0 = broker.total_published
        sel2, t2 = service.submit_query("IRB-C", query, mrns)
        assert sel2.digest == selection.digest
        assert not t2.cold and broker.total_published == pub0
        assert sorted(t2.hits) == list(sel2.accessions)

    def test_submit_query_without_catalog_raises(self, tmp_path):
        source, mrns = _corpus(2, 1)
        _, service, _ = _stack(tmp_path, source, catalog=None)
        with pytest.raises(RuntimeError):
            service.submit_query("IRB-C", Eq("modality", "CT"), mrns)

    def test_put_study_keeps_catalog_fresh(self):
        source, _ = _corpus(2, 1)
        catalog = StudyCatalog()
        source.attach_catalog(catalog)
        s = StudyGenerator(77).gen_study("QNEW", modality="CT", n_images=3)
        source.put_study("QNEW", s)
        assert "QNEW" in catalog.accessions()
        assert catalog.accession_etags()["QNEW"] == source.study_etag("QNEW")
        # re-put replaces rows under the fresh etag
        s2 = StudyGenerator(78).gen_study("QNEW", modality="MR", n_images=1)
        source.put_study("QNEW", s2)
        assert catalog.accession_etags()["QNEW"] == source.study_etag("QNEW")
        sel = catalog.select(Eq("modality", "MR"))
        assert dict(sel.instance_counts) == {"QNEW": 1}


class TestSubmitDedup:
    """Satellite: duplicated accessions within one request must neither
    double-publish nor double-count planner stats (stable first-occurrence
    order)."""

    def test_submit_cohort_dedupes(self, tmp_path):
        source, mrns = _corpus(3, 1)
        broker, service, pool = _stack(tmp_path, source)
        accs = list(mrns)
        dup = [accs[0], accs[1], accs[0], accs[2], accs[1], accs[0]]
        ticket = service.submit_cohort("IRB-C", dup, mrns)
        assert ticket.cold == accs  # first-occurrence order preserved
        assert broker.total_published == 3
        assert service.planner.stats.accessions == 3
        assert service.planner.stats.published == 3
        assert service.planner.stats.coalesced == 0
        pool.drain()
        service.planner.resolve()
        assert ticket.done()
        # one workflow record per unique accession
        assert len([r for r in service.records if r.research_study == "IRB-C"]) == 3

    def test_submit_dedupes(self, tmp_path):
        source, mrns = _corpus(3, 1)
        broker, service, _ = _stack(tmp_path, source)
        accs = list(mrns)
        records = service.submit("IRB-C", [accs[0]] * 3 + [accs[1]], mrns)
        assert [r.accession for r in records] == [accs[0], accs[1]]
        assert broker.total_published == 2


class TestMatchesHelper:
    """Satellite: shared CS normalization between dataset, filter, catalog."""

    def test_dataset_matches(self):
        ds = DicomDataset()
        ds["Modality"] = " ct "
        ds["BodyPartExamined"] = "CHEST  WALL"
        assert ds.matches("Modality", "CT")
        assert ds.matches("Modality", "ct")
        assert ds.matches("BodyPartExamined", "chest wall")
        assert not ds.matches("Modality", "MR")
        assert not ds.matches("StudyDate", "20200101")  # absent tag

    def test_filter_equals_is_case_insensitive(self):
        from repro.core.filter import FilterStage

        stage = FilterStage('reject Modality equals "RAW"\nreject Modality in "SR,KO"')
        raw = DicomDataset()
        raw["Modality"] = "raw"
        assert not stage(raw).accepted
        sr = DicomDataset()
        sr["Modality"] = " sr"
        assert not stage(sr).accepted
        ct = DicomDataset()
        ct["Modality"] = "CT"
        assert stage(ct).accepted
