"""Property-based tests (hypothesis) for the metadata catalog.

Skips cleanly where hypothesis isn't installed (the seeded-random sweeps in
test_catalog.py cover the same ground without it): encode/decode round-trips
through the dictionary, and query-vs-brute-force-scan equivalence across all
three evaluation paths on randomized catalogs."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.catalog import (
    And,
    Contains,
    Eq,
    In,
    Not,
    Or,
    Range,
    StudyCatalog,
    describe,
    matches_row,
)
from repro.catalog.columns import Dictionary
from repro.dicom.dataset import normalize_cs
from repro.kernels.bitmap.ops import combine_bitmaps
from repro.kernels.bitmap.ref import combine_bitmaps_ref, pack_mask_np, unpack_mask_np

_settings = settings(
    max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow]
)

_MODALITIES = ["CT", "MR", "DX", "US"]
_PARTS = ["CHEST", "HEAD", "ABDOMEN", ""]

row_st = st.fixed_dictionaries(
    {
        "modality": st.sampled_from(_MODALITIES),
        "body_part": st.sampled_from(_PARTS),
        "manufacturer": st.sampled_from(["GE Medical", "Siemens", "Philips"]),
        "model": st.sampled_from(["Optima CT660", "MAGNETOM Aera", "Epiq 7"]),
        "study_date": st.integers(20150101, 20191231),
        "bits_stored": st.sampled_from([8, 12, 16]),
        "rows": st.sampled_from([256, 512]),
        "cols": st.sampled_from([256, 512]),
        "nbytes": st.integers(100, 10**6),
        "burned_in": st.integers(0, 1),
    }
)

leaf_st = st.one_of(
    st.builds(Eq, st.just("modality"), st.sampled_from(_MODALITIES + ["XX"])),
    st.builds(Eq, st.just("body_part"), st.sampled_from(_PARTS)),
    st.builds(
        In,
        st.just("modality"),
        st.lists(st.sampled_from(_MODALITIES), min_size=1, max_size=3).map(tuple),
    ),
    st.builds(
        Range,
        st.just("study_date"),
        st.integers(20150101, 20181231),
        st.integers(20160101, 20191231),
    ),
    st.builds(Contains, st.just("model"), st.sampled_from(["ct", "MAG", "7", "zzz"])),
    st.builds(Eq, st.just("burned_in"), st.integers(0, 1)),
)

pred_st = st.recursive(
    leaf_st,
    lambda children: st.one_of(
        st.builds(lambda a, b: And(a, b), children, children),
        st.builds(lambda a, b: Or(a, b), children, children),
        st.builds(Not, children),
    ),
    max_leaves=6,
)


class TestDictionaryProperties:
    @given(values=st.lists(st.text(min_size=1, max_size=12), min_size=1, max_size=30))
    @_settings
    def test_encode_decode_roundtrip(self, values):
        d = Dictionary()
        for v in values:
            code = d.encode(v)
            assert d.decode(code) == normalize_cs(v)
            assert d.code_of(v) == code
        # codes are dense and stable
        assert sorted(d.codes.values()) == list(range(len(d)))


class TestQueryEquivalenceProperties:
    @given(
        rows=st.lists(row_st, min_size=1, max_size=60),
        pred=pred_st,
        block_rows=st.sampled_from([4, 16, 512]),
    )
    @_settings
    def test_all_paths_agree(self, rows, pred, block_rows):
        """Vectorized jnp+Pallas == numpy oracle == python brute force, with
        and without zone-map pruning, on arbitrary catalogs."""
        cat = StudyCatalog(block_rows=block_rows)
        per_acc = {}
        for i in range(0, len(rows), 10):
            acc = f"H{i:03d}"
            per_acc[acc] = rows[i : i + 10]
            cat.ingest_rows(acc, per_acc[acc], etag=str(i))
        mv, _, _ = cat.match_mask(pred, mode="auto", prune=False)
        mo, _, _ = cat.match_mask(pred, mode="oracle", prune=False)
        assert np.array_equal(mv, mo), describe(pred)
        sel_pruned = cat.select(pred, mode="auto", prune=True)
        sel_full = cat.select(pred, mode="oracle", prune=False)
        assert sel_pruned.accessions == sel_full.accessions
        assert sel_pruned.instance_counts == sel_full.instance_counts
        assert sel_pruned.total_bytes == sel_full.total_bytes
        expected = {
            acc: n
            for acc, n in (
                (a, sum(1 for r in rs if matches_row(pred, r)))
                for a, rs in per_acc.items()
            )
            if n
        }
        assert dict(sel_pruned.instance_counts) == expected, describe(pred)


class TestBitmapKernelProperties:
    @given(
        n=st.integers(1, 400),
        k=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    @_settings
    def test_kernel_equals_reference(self, n, k, seed):
        rng = np.random.default_rng(seed)
        masks = [rng.random(n) < rng.random() for _ in range(k)]
        valid = rng.random(n) < 0.9
        leaves = np.stack([pack_mask_np(m) for m in masks + [valid]])
        prog = [("leaf", 0)]
        for i in range(1, k):
            prog.append(("leaf", i))
            if rng.random() < 0.3:
                prog.append(("not",))
            prog.append(("and",) if rng.random() < 0.5 else ("or",))
        prog = tuple(prog) + (("leaf", k), ("and",))
        bm_ref, cnt_ref = combine_bitmaps_ref(leaves, prog)
        bm, cnt = combine_bitmaps(leaves, prog)
        assert np.array_equal(np.asarray(bm), bm_ref)
        assert cnt == cnt_ref
        assert cnt == int(unpack_mask_np(bm_ref, n).sum())
