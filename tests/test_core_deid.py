"""Unit + behaviour tests for the de-identification core (paper §Method)."""
import numpy as np
import pytest

from repro.core import (
    AnonymizerStage,
    DeidPipeline,
    FilterStage,
    Outcome,
    PseudonymService,
    TrustMode,
    build_request,
)
from repro.core.manifest import Manifest
from repro.core.rules import (
    parse_anonymizer_script,
    parse_filter_script,
    parse_scrub_script,
    emit_scrub_script,
)
from repro.core.scripts import DEFAULT_ANONYMIZER_SCRIPT, DEFAULT_FILTER_SCRIPT
from repro.dicom.devices import DeviceKey, registry
from repro.dicom.generator import PROBLEM_KINDS, StudyGenerator


@pytest.fixture(scope="module")
def pipe():
    return DeidPipeline(recompress=False)  # recompress covered separately


@pytest.fixture(scope="module")
def pseudo():
    return PseudonymService("IRB-1", TrustMode.POST_IRB, key=b"t" * 32)


class TestFilterStage:
    @pytest.mark.parametrize("kind", PROBLEM_KINDS)
    def test_problem_instances_rejected(self, gen, pipe, kind):
        s = gen.gen_study(f"F-{kind}", modality="CT", n_images=0, problem=kind)
        decision = pipe.filter(s.datasets[0])
        assert not decision.accepted, kind
        assert decision.rule is not None

    def test_normal_ct_accepted(self, gen, pipe):
        s = gen.gen_study("F-OK", modality="CT", n_images=1)
        assert pipe.filter(s.datasets[0]).accepted

    def test_us_whitelist_miss_rejected(self, gen, pipe):
        s = gen.gen_study("F-US", device=DeviceKey("US", "UnknownMake", "Mystery-1", 480, 640), n_images=1)
        d = pipe.filter(s.datasets[0])
        assert not d.accepted and "us_not_whitelisted" in d.rule

    def test_us_whitelist_hit_accepted(self, gen, pipe):
        key = registry().all_us_variants()[0]
        s = gen.gen_study("F-USOK", device=key, n_images=1)
        assert pipe.filter(s.datasets[0]).accepted

    def test_exemption_bypass(self, gen):
        # derived CT localizer is exempted from the DERIVED reject
        s = gen.gen_study("F-EX", modality="CT", n_images=1)
        ds = s.datasets[0]
        ds["ImageType"] = "DERIVED\\PRIMARY\\LOCALIZER"
        stage = FilterStage(DEFAULT_FILTER_SCRIPT)
        assert stage(ds).accepted

    def test_parse_rejects_bad_rules(self):
        with pytest.raises(ValueError):
            parse_filter_script("rejekt Modality equals \"CT\"")
        with pytest.raises(ValueError):
            parse_filter_script("reject Modality frobs \"CT\"")
        with pytest.raises(ValueError):
            parse_filter_script("reject builtin:nope")


class TestAnonymizer:
    def test_phi_fields_removed(self, gen, pipe, pseudo):
        s = gen.gen_study("A-1", modality="MR", n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        out, entry = pipe.process_instance(s.datasets[0], req)
        for kw in ("PatientBirthDate", "ReferringPhysicianName", "InstitutionName",
                   "OperatorsName", "PatientComments", "StudyDescription"):
            assert kw not in out, kw
        assert out["PatientID"] == req.anon_mrn
        assert out["AccessionNumber"] == req.anon_accession
        assert not out.private

    def test_uids_remapped_consistently(self, gen, pipe, pseudo):
        s = gen.gen_study("A-2", modality="CT", n_images=2)
        req = build_request(pseudo, s.accession, s.mrn)
        outs = [pipe.process_instance(d, req)[0] for d in s.datasets]
        # same study/series -> same remapped study/series UID; unique SOP UIDs
        assert outs[0]["StudyInstanceUID"] == outs[1]["StudyInstanceUID"]
        assert outs[0]["StudyInstanceUID"] != s.study_uid
        assert outs[0]["SOPInstanceUID"] != outs[1]["SOPInstanceUID"]

    def test_dates_jittered_uniformly(self, gen, pipe, pseudo):
        s = gen.gen_study("A-3", modality="CT", n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        out, _ = pipe.process_instance(s.datasets[0], req)
        assert out["StudyDate"] != s.study_date
        assert out["StudyDate"] == out["SeriesDate"] == out["AcquisitionDate"]
        assert req.jitter != 0

    def test_default_remove_policy(self):
        rules = parse_anonymizer_script("keep Modality\ndefault remove")
        stage = AnonymizerStage("keep Modality\ndefault remove")
        from repro.dicom.dataset import DicomDataset
        ds = DicomDataset()
        ds["Modality"] = "CT"
        ds["StationName"] = "STA1"
        res = stage(ds, {"jitter": "0"})
        assert "Modality" in res.dataset and "StationName" not in res.dataset


class TestPseudonymization:
    def test_codes_deterministic_and_distinct(self, pseudo):
        assert pseudo.accession("A1") == pseudo.accession("A1")
        assert pseudo.accession("A1") != pseudo.accession("A2")
        assert pseudo.accession("A1") != pseudo.mrn("A1")

    def test_post_irb_relink(self, pseudo):
        anon = pseudo.accession("ACC-REL")
        assert pseudo.relink(anon) == "ACC-REL"

    def test_pre_irb_is_irreversible(self):
        pre = PseudonymService("PRE", TrustMode.PRE_IRB)
        anon = pre.accession("ACC-X")
        with pytest.raises(PermissionError):
            pre.relink(anon)
        with pytest.raises(PermissionError):
            pre.linkage_table()

    def test_different_studies_different_codes(self):
        p1 = PseudonymService("IRB-A", TrustMode.POST_IRB, key=b"a" * 32)
        p2 = PseudonymService("IRB-B", TrustMode.POST_IRB, key=b"b" * 32)
        assert p1.accession("A1") != p2.accession("A1")
        assert p1.jitter_for("M1") != 0 and p2.jitter_for("M1") != 0

    def test_jitter_never_zero_and_bounded(self, pseudo):
        for i in range(200):
            j = pseudo.jitter_for(f"M{i}")
            assert j != 0 and -30 <= j <= 30

    def test_jitter_date_arithmetic(self):
        assert PseudonymService.jitter_date("20200301", -1) == "20200229"  # leap
        assert PseudonymService.jitter_date("20191231", 1) == "20200101"
        assert PseudonymService.jitter_date("", 5) == ""


class TestScrubStage:
    def test_regions_blanked_and_recorded(self, gen, pseudo):
        pipe = DeidPipeline(recompress=False)
        s = gen.gen_study("S-1", modality="US", n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        out, entry = pipe.process_instance(s.datasets[0], req)
        assert entry.scrub_rects
        for x, y, w, h in entry.scrub_rects:
            assert (out.pixels[y : y + h, x : x + w] == 0).all()

    def test_fail_closed_on_us_without_rule(self, gen, pseudo):
        # bypass the filter to prove scrub re-checks (defense in depth)
        pipe = DeidPipeline(filter_script="# empty\n", recompress=False)
        s = gen.gen_study("S-2", device=DeviceKey("US", "UnknownMake", "Mystery-1", 480, 640), n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        out, entry = pipe.process_instance(s.datasets[0], req)
        assert out is None and entry.outcome == Outcome.FAILED

    def test_recompression_flag_and_syntax(self, gen, pseudo):
        pipe = DeidPipeline(recompress=True)
        s = gen.gen_study("S-3", modality="CT", n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        out, entry = pipe.process_instance(s.datasets[0], req)
        assert entry.recompressed and entry.compressed_bytes > 0
        assert out["TransferSyntaxUID"] == "1.2.840.10008.1.2.4.70"


class TestManifest:
    def test_roundtrip_and_counts(self, gen, pseudo):
        pipe = DeidPipeline(recompress=False)
        s = gen.gen_study("M-1", modality="CT", n_images=2, problem="pdf")
        req = build_request(pseudo, s.accession, s.mrn)
        _, manifest = pipe.process_study(s, req, worker_id="w7")
        c = manifest.counts()
        assert c["anonymized"] == 2 and c["filtered"] == 1
        m2 = Manifest.from_json(manifest.to_json())
        assert m2.counts() == c
        assert all(e.worker_id == "w7" for e in m2.entries)

    def test_manifest_carries_no_phi(self, gen, pseudo):
        pipe = DeidPipeline(recompress=False)
        s = gen.gen_study("M-2", modality="CT", n_images=1)
        req = build_request(pseudo, s.accession, s.mrn)
        _, manifest = pipe.process_study(s, req)
        blob = manifest.to_json()
        assert s.mrn not in blob
        assert s.patient_name.split("^")[0] not in blob
        assert s.accession not in blob


class TestScrubScriptGeneration:
    def test_emit_parse_roundtrip(self):
        text = emit_scrub_script()
        rules = parse_scrub_script(text)
        reg = registry()
        assert len(rules) >= sum(v[1] for v in reg.table2_stats().values())
        # paper Fig 2b: GE PET/CT fusion regions survive the roundtrip
        key = ("PT", "GE", "Discovery", 512, 512)
        assert rules[key] == ((256, 0, 256, 22), (300, 22, 212, 80), (10, 478, 100, 10))
