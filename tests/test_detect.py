"""Burned-in pixel-PHI detection subsystem: policy, wiring, cache identity
(DESIGN.md §9).

Covers the registry-fallback contract end to end: unknown devices get
detector-blanked through both the serial and batched pipeline paths
(byte-identically), ultrasound stays whitelist-only, unknown lookups surface
as registry/worker/fleet metrics, the detector version + policy digest ride
the ruleset fingerprint (warm-hit before a policy edit, cold after), and the
catalog's ``burned_in_detected`` column reflects the detector oracle.
"""
import pickle
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core.pipeline import DeidPipeline, DeidRequest
from repro.core.scrub import ScrubError, ScrubStage, numpy_blank
from repro.core import scripts as default_scripts
from repro.detect import DETECTOR_VERSION, DetectorPolicy
from repro.dicom.devices import registry
from repro.dicom.generator import StudyGenerator
from repro.lake.fingerprint import RulesetFingerprint


def _request(acc="ACC1"):
    return DeidRequest("IRB-D", acc, "ANON1", "MRN1", 3)


@pytest.fixture(scope="module")
def dgen():
    return StudyGenerator(seed=77)


@pytest.fixture(scope="module")
def unknown_ct_study(dgen):
    dev = dgen.unknown_device("DET0001", "CT")
    return dgen.gen_study("DET0001", device=dev, n_images=3)


class TestDetectorPolicy:
    def test_mode_validation(self):
        with pytest.raises(ValueError, match="unknown detector mode"):
            DetectorPolicy(mode="sometimes")

    def test_wants_detection_matrix(self):
        rf = DetectorPolicy(mode="registry_first")
        assert rf.wants_detection(registry_hit=False)
        assert not rf.wants_detection(registry_hit=True)
        un = DetectorPolicy(mode="union")
        assert un.wants_detection(True) and un.wants_detection(False)
        off = DetectorPolicy(mode="off")
        assert not off.enabled and not off.wants_detection(False)

    def test_modality_thresholds(self):
        p = DetectorPolicy(modality_row_frac=(("DX", 0.08),))
        assert p.tau_for("DX") == 0.08
        assert p.tau_for("CT") == p.row_frac

    def test_digest_sensitive_to_knobs_and_version(self, monkeypatch):
        base_digest = DetectorPolicy().digest  # digest is computed lazily
        assert DetectorPolicy().digest == base_digest
        assert DetectorPolicy(row_frac=0.05).digest != base_digest
        assert DetectorPolicy(mode="union").digest != base_digest
        assert DetectorPolicy(pad_rows=3).digest != base_digest
        import repro.detect.policy as policy_mod

        monkeypatch.setattr(policy_mod, "DETECTOR_VERSION", "textdetect-v2")
        assert DetectorPolicy().digest != base_digest


class TestScrubStageFallback:
    def test_unknown_device_text_is_blanked(self, unknown_ct_study):
        pipe = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        delivered, manifest = pipe.process_study(unknown_ct_study, _request())
        assert len(delivered) == 3
        burned = unknown_ct_study.phi_rects
        assert burned, "generator must seed text on an unknown-device study"
        uid_to_out = {}
        for src, out in zip(unknown_ct_study.datasets, delivered):
            uid_to_out[src["SOPInstanceUID"]] = out
        for uid, rects in burned.items():
            out = uid_to_out[uid]
            for x, y, w, h in rects:
                assert int(out.pixels[y : y + h, x : x + w].max()) == 0

    def test_legacy_pipeline_leaks_unknown_device_text(self, unknown_ct_study):
        """The gap the subsystem closes: without a policy, a registry miss
        passes pixels through silently."""
        pipe = DeidPipeline(recompress=False)
        delivered, _ = pipe.process_study(unknown_ct_study, _request())
        uid, rects = next(iter(unknown_ct_study.phi_rects.items()))
        out = {s["SOPInstanceUID"]: d for s, d in zip(unknown_ct_study.datasets, delivered)}[uid]
        assert any(int(out.pixels[y : y + h, x : x + w].max()) > 0 for x, y, w, h in rects)

    def test_off_mode_matches_legacy_bytes(self, unknown_ct_study):
        a, _ = DeidPipeline(recompress=False).process_study(unknown_ct_study, _request())
        b, _ = DeidPipeline(
            recompress=False, detector_policy=DetectorPolicy(mode="off")
        ).process_study(unknown_ct_study, _request())
        assert [pickle.dumps(x) for x in a] == [pickle.dumps(x) for x in b]

    def test_serial_and_batched_byte_identical(self, unknown_ct_study):
        pol = DetectorPolicy()
        batched = DeidPipeline(recompress=False, detector_policy=pol)
        serial = DeidPipeline(recompress=False, detector_policy=pol, batched=False)
        d1, m1 = batched.process_study(unknown_ct_study, _request())
        d2, m2 = serial.process_study_serial(unknown_ct_study, _request())
        assert [pickle.dumps(x) for x in d1] == [pickle.dumps(x) for x in d2]
        assert m1.counts() == m2.counts()
        # detection rode the shape-bucketed executor, not per-instance calls
        assert batched.executor.stats.detect_dispatches >= 1
        assert batched.executor.stats.detect_instances == 3

    def test_us_whitelist_miss_still_fails_closed(self, dgen):
        """The detector complements the US whitelist; it never bypasses it."""
        study = dgen.gen_study("DET-US", modality="US", n_images=1)
        ds = study.datasets[0].copy()
        ds["ManufacturerModelName"] = "NotWhitelisted-9"
        stage = ScrubStage(
            default_scripts.DEFAULT_SCRUB_SCRIPT,
            recompress=False,
            policy=DetectorPolicy(),
        )
        with pytest.raises(ScrubError, match="no scrub rule for ultrasound"):
            stage(ds)

    def test_union_mode_merges_registry_and_detector(self, dgen):
        study = dgen.gen_study("DET-USU", modality="US", n_images=1)
        ds = study.datasets[0]
        stage = ScrubStage(
            default_scripts.DEFAULT_SCRUB_SCRIPT,
            recompress=False,
            policy=DetectorPolicy(mode="union"),
        )
        res = stage(ds)
        rep = res.detection
        assert rep is not None and rep.registry_hit and rep.detector_ran
        assert rep.detector_rects and rep.registry_rects
        # applied = merged union: no overlapping pair survives
        rects = res.rects
        assert rects == sorted(rects, key=lambda r: (r[1], r[0], r[3], r[2]))
        for i, (ax, ay, aw, ah) in enumerate(rects):
            for bx, by, bw, bh in rects[i + 1 :]:
                x_overlap = ax < bx + bw and bx < ax + aw
                y_overlap = ay < by + bh and by < ay + ah
                assert not (x_overlap and y_overlap), (res.rects, "overlap survived merge")
        # and the union still clears the seeded text
        clean = numpy_blank(ds.pixels, rects)
        from repro.detect import detect_bands_np

        assert detect_bands_np(clean, thresh=255 * 0.6, row_frac=0.04)[0] == []

    def test_detection_report_fields(self, unknown_ct_study):
        pipe = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        ds = unknown_ct_study.datasets[0]
        res = pipe.scrub(ds)
        rep = res.detection
        assert rep is not None
        assert rep.version == DETECTOR_VERSION
        assert not rep.registry_hit and rep.detector_ran
        assert rep.device.startswith("CT/Novel")
        assert rep.ceiling == 4095.0 and rep.thresh == 4095.0 * 0.6
        assert rep.bands and rep.applied_rects == res.rects
        assert rep.detected

    def test_stats_and_registry_counter(self, dgen):
        reg = registry()
        dev = dgen.unknown_device("DET-CNT", "MR")
        study = dgen.gen_study("DET-CNT", device=dev, n_images=2)
        before = reg.unknown_lookup_total()
        pipe = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        pipe.process_study(study, _request())
        assert reg.unknown_lookup_total() == before + 2
        assert reg.unknown_lookups[(dev.make, dev.model)] >= 2
        st = pipe.scrub.detect_stats
        assert st.unknown_lookups == 2 and st.detector_runs == 2
        assert st.instances == 2 and st.registry_hits == 0


class TestWorkerMetrics:
    def test_unknown_lookups_surface_in_worker_and_pool(self, dgen, tmp_path):
        from repro.core.pseudonym import TrustMode
        from repro.queueing import (
            Autoscaler,
            AutoscalerConfig,
            Broker,
            DeidWorker,
            Journal,
            WorkerPool,
        )
        from repro.queueing.server import DeidService
        from repro.storage.object_store import StudyStore
        from repro.utils.timing import SimClock

        source = StudyStore("lake")
        mrns = {}
        for i in range(3):
            acc = f"WM{i:03d}"
            dev = dgen.unknown_device(acc, "CT") if i % 2 == 0 else None
            s = dgen.gen_study(acc, modality="CT", n_images=2, device=dev)
            source.put_study(acc, s)
            mrns[acc] = s.mrn
        clock = SimClock()
        broker = Broker(clock)
        journal = Journal(tmp_path / "wm.jsonl")
        pipeline = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        service = DeidService(broker, source, journal)
        service.register_study("IRB-WM", TrustMode.POST_IRB)
        service.submit("IRB-WM", list(mrns), mrns)
        dest = StudyStore("res")
        pool = WorkerPool(
            broker,
            Autoscaler(broker, AutoscalerConfig(), clock),
            lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
        )
        report = pool.drain()
        assert report.processed == 3
        # studies WM000 and WM002 are unknown-device (2 instances each)
        assert report.unknown_devices == 4
        assert report.detector_runs == 4
        assert sum(w.unknown_devices for w in pool._all_workers) == 4


class TestFingerprintAndColdServe:
    def test_fingerprint_changes_with_policy_and_version(self, monkeypatch):
        base = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        none = DeidPipeline(recompress=False)
        edited = DeidPipeline(
            recompress=False, detector_policy=DetectorPolicy(row_frac=0.06)
        )
        digs = {
            none.ruleset_fingerprint().digest,
            base.ruleset_fingerprint().digest,
            edited.ruleset_fingerprint().digest,
        }
        assert len(digs) == 3
        # mode="off" delivers byte-identical results to the no-policy path
        # (tested above), so it must share its fingerprint: a fleet staging
        # the detector dark keeps serving its lake warm
        off = DeidPipeline(
            recompress=False, detector_policy=DetectorPolicy(mode="off")
        )
        assert off.ruleset_fingerprint().digest == none.ruleset_fingerprint().digest
        # same policy -> same fingerprint (cache keys are stable)
        again = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        assert again.ruleset_fingerprint().digest == base.ruleset_fingerprint().digest
        # a detector version bump alone forces new keys
        import repro.detect.policy as policy_mod

        monkeypatch.setattr(policy_mod, "DETECTOR_VERSION", "textdetect-v99")
        bumped = DeidPipeline(recompress=False, detector_policy=DetectorPolicy())
        assert bumped.ruleset_fingerprint().digest != base.ruleset_fingerprint().digest

    def test_detector_sha_field_rides_the_fingerprint(self):
        shas = {"filter": "f", "anonymizer": "a", "scrubber": "s"}
        fp0 = RulesetFingerprint.of(shas)
        fp1 = RulesetFingerprint.of(shas, detector=DetectorPolicy().digest)
        assert fp0.detector_sha == "" and fp1.detector_sha
        assert fp0.digest != fp1.digest

    def test_warm_hit_before_policy_change_miss_after(self, dgen, tmp_path):
        """Acceptance: policy edits force a cold serve. Three deployments
        against one persistent result lake: same policy serves warm across
        deployments, an edited policy serves nothing warm."""
        from repro.core.pseudonym import TrustMode
        from repro.lake import ResultLake
        from repro.queueing import (
            Autoscaler,
            AutoscalerConfig,
            Broker,
            DeidWorker,
            Journal,
            WorkerPool,
        )
        from repro.queueing.server import DeidService
        from repro.storage.object_store import StudyStore
        from repro.utils.timing import SimClock

        source = StudyStore("lake")
        mrns = {}
        for i in range(3):
            acc = f"CS{i:03d}"
            dev = dgen.unknown_device(acc, "CT") if i == 0 else None
            s = dgen.gen_study(acc, modality="CT", n_images=2, device=dev)
            source.put_study(acc, s)
            mrns[acc] = s.mrn
        lake = ResultLake(max_bytes=1 << 30)

        def deployment(name, policy):
            clock = SimClock()
            broker = Broker(clock)
            journal = Journal(tmp_path / f"{name}.jsonl")
            pipeline = DeidPipeline(
                recompress=False, lake=lake, detector_policy=policy
            )
            service = DeidService(
                broker, source, journal, result_lake=lake, pipeline=pipeline
            )
            service.register_study("IRB-CS", TrustMode.POST_IRB)
            dest = StudyStore("res")
            pool = WorkerPool(
                broker,
                Autoscaler(broker, AutoscalerConfig(), clock),
                lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
            )
            return service, pool

        p1 = DetectorPolicy()
        service, pool = deployment("d1", p1)
        t1 = service.submit_cohort("IRB-CS", list(mrns), mrns)
        assert len(t1.cold) == 3 and not t1.hits
        pool.drain()
        service.planner.resolve()
        t2 = service.submit_cohort("IRB-CS", list(mrns), mrns)
        assert len(t2.hits) == 3 and not t2.cold  # warm under the same policy

        service_b, _ = deployment("d2", DetectorPolicy())
        tb = service_b.submit_cohort("IRB-CS", list(mrns), mrns)
        assert len(tb.hits) == 3 and not tb.cold  # warm across deployments

        service_c, _ = deployment("d3", DetectorPolicy(row_frac=0.06))
        tc = service_c.submit_cohort("IRB-CS", list(mrns), mrns)
        assert len(tc.cold) == 3 and not tc.hits  # policy edit -> cold serve


class TestCatalogColumn:
    def test_burned_in_detected_reflects_detector_oracle(self, dgen):
        from repro.catalog import Eq, StudyCatalog
        from repro.catalog.columns import row_from_dataset
        from repro.storage.object_store import StudyStore

        source = StudyStore("lake")
        cat = StudyCatalog(block_rows=4)
        source.attach_catalog(cat)
        us = dgen.gen_study("CAT-US", modality="US", n_images=2)
        ct = dgen.gen_study("CAT-CT", modality="CT", n_images=3)
        source.put_study("CAT-US", us)
        source.put_study("CAT-CT", ct)
        sel = cat.select(Eq("burned_in_detected", 1))
        # every US instance is burned; CT only slice 0 (dose-screen cadence)
        assert sel.instance_counts == {"CAT-US": 2, "CAT-CT": 1}
        # row extraction matches the generator's seeded ground truth
        for ds in us.datasets:
            assert row_from_dataset(ds)["burned_in_detected"] == 1
        assert row_from_dataset(ct.datasets[1])["burned_in_detected"] == 0

    def test_rows_without_the_column_still_ingest(self):
        from repro.catalog import Eq, StudyCatalog

        cat = StudyCatalog(block_rows=2)
        rows = [
            {"modality": "CT", "body_part": "CHEST", "manufacturer": "GE",
             "model": "M", "study_date": 20200101, "bits_stored": 12,
             "rows": 512, "cols": 512, "nbytes": 1000, "burned_in": 0}
        ] * 3
        assert cat.ingest_rows("OLD1", rows, etag="e") == 3
        # legacy rows read as 0 on the new column, on both query paths
        assert cat.select(Eq("burned_in_detected", 0)).total_instances == 3
        assert cat.select(Eq("burned_in_detected", 1)).total_instances == 0
