"""Property-based tests (hypothesis) for the text-band detector.

Skips cleanly where hypothesis isn't installed (the seeded sweeps in
test_textdetect.py / test_detect.py cover the same surface without it).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.scrub import numpy_blank
from repro.detect import DetectorPolicy, detect_bands_np, merge_rects
from repro.dicom.generator import StudyGenerator
from repro.kernels.phi_detect.ops import stored_max_value

_settings = settings(
    max_examples=15, deadline=None, suppress_health_check=[HealthCheck.too_slow]
)

_MODALITIES = ["CT", "MR", "PT", "DX", "CR"]


def _detect(ds, policy=DetectorPolicy()):
    return detect_bands_np(
        ds.pixels,
        thresh=stored_max_value(ds) * policy.binarize_frac,
        row_frac=policy.tau_for(str(ds.get("Modality", ""))),
        tile=policy.tile,
        min_rows=policy.min_band_rows,
        pad_rows=policy.pad_rows,
    )


class TestDetectorCoverage:
    @given(
        seed=st.integers(0, 2**31 - 1),
        modality=st.sampled_from(_MODALITIES),
        salt=st.integers(0, 10_000),
    )
    @_settings
    def test_seeded_bands_always_covered_at_default_thresholds(
        self, seed, modality, salt
    ):
        """For any generator-seeded burned-in text on an unknown-device study,
        detector proposals cover every seeded row at the default policy."""
        gen = StudyGenerator(seed)
        dev = gen.unknown_device(f"P{salt}", modality)
        study = gen.gen_study(f"P{salt}", device=dev, n_images=1)
        ds = study.datasets[0]
        seeded = study.phi_rects.get(ds["SOPInstanceUID"], [])
        bands, rects = _detect(ds)
        H = ds.pixels.shape[0]
        covered = np.zeros(H, bool)
        for y0, y1 in bands:
            covered[y0:y1] = True
        for x, y, w, h in seeded:
            assert covered[max(0, y) : min(H, y + h)].all(), (seeded, bands)
        # and blanking the proposals reaches the detector's fixpoint
        if rects:
            clean = numpy_blank(ds.pixels, rects)
            ds2 = ds.copy()
            ds2.pixels = clean
            assert _detect(ds2)[0] == []


class TestMergeRectsProperties:
    @given(
        rects=st.lists(
            st.tuples(
                st.integers(-5, 60),
                st.integers(-5, 60),
                st.integers(-3, 40),
                st.integers(-3, 40),
            ),
            min_size=0,
            max_size=8,
        )
    )
    @_settings
    def test_merge_preserves_blanked_set_and_never_grows(self, rects):
        before = np.zeros((80, 80), bool)
        for x, y, w, h in rects:
            if w > 0 and h > 0:
                before[max(0, y) : max(0, y + h), max(0, x) : max(0, x + w)] = True
        merged = merge_rects(rects)
        after = np.zeros((80, 80), bool)
        for x, y, w, h in merged:
            assert w > 0 and h > 0
            after[max(0, y) : max(0, y + h), max(0, x) : max(0, x + w)] = True
        np.testing.assert_array_equal(before, after)
        assert len(merged) <= len([r for r in rects if r[2] > 0 and r[3] > 0])
        # idempotent
        assert merge_rects(merged) == merged
